"""Paper §3 Workload Processor: RDFS reformulation — union sizes, time,
and the completeness gain (answers recovered that plain evaluation
misses)."""
from __future__ import annotations

from benchmarks.bench_common import emit, time_us
from repro.core.reformulation import reformulate
from repro.query import ref_engine as R
from repro.rdf.generator import generate, lubm_workload
from repro.rdf.triples import TripleStore


def main(lines: list[str]) -> None:
    uni = generate(n_universities=2, seed=0)
    sat = TripleStore(
        uni.schema.saturate_instance(uni.store.triples, uni.type_id),
        uni.dictionary)
    for q in lubm_workload(uni.dictionary):
        us = time_us(lambda q=q: reformulate(q, uni.schema, uni.type_id),
                     iters=20)
        members = reformulate(q, uni.schema, uni.type_id)
        plain = R.evaluate_cq(q, uni.store).as_set()
        full = R.evaluate_ucq(members, uni.store)
        want = R.evaluate_cq(q, sat).as_set()
        assert full == want, q.name
        gain = len(full) - len(plain)
        lines.append(emit(
            f"reformulation.{q.name}", us,
            f"members={len(members)};plain={len(plain)};complete={len(full)};"
            f"recovered={gain}"))
