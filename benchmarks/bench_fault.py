"""Fault-tolerant serving under injected failures (repro.serve.chaos).

Scenario: a tuned LUBM session serves a streaming store (small update
batches between query batches) while the chaos harness injects one
fault class at a time at a fixed batch index — device-call failure,
capacity-overflow storm, compile failure on a fresh program, a failed
maintenance pass, a corrupted extent, and a crashed online retune.

Per fault class the stream measures what the degradation ladder
actually delivered: availability (batches answered vs
`ServiceUnavailable`), the fraction of batches served degraded/stale,
and the recovery time — batches from fault injection until the health
state machine reads HEALTHY again.  Every served batch is checked
against the host reference engine unless it was explicitly flagged
degraded/stale, so the numbers cannot hide silently wrong answers.
Lands in BENCH_fault.json with the acceptance assertions applied
(aggregate availability >= 99%, every class recovers to HEALTHY).
"""
from __future__ import annotations

import numpy as np

from benchmarks.bench_common import emit, quick_mode, write_bench_json
from repro.api import (MaintenanceConfig, QualityWeights, SearchConfig,
                       TuningSession, WizardConfig)
from repro.errors import ServiceUnavailable
from repro.rdf.generator import generate, lubm_workload
from repro.serve.chaos import FaultInjector, InjectedFault

INJECT_AT = 3  # batch index (0-based) at which each fault class fires


def _cfg() -> WizardConfig:
    return WizardConfig(search=SearchConfig(
        strategy="greedy", max_states=400,
        weights=QualityWeights(w_exec=1.0, w_maint=1.0, w_space=1.0)))


def _update(rng, store, size=8):
    tt = store.triples
    subjects = np.unique(tt[:, 0])
    preds = np.unique(tt[:, 1])
    objects = np.unique(tt[:, 2])
    return np.stack([rng.choice(subjects, size), rng.choice(preds, size),
                     rng.choice(objects, size)], axis=1).astype(np.int32)


def _inject(klass: str, srv, chaos: FaultInjector) -> None:
    """Arm one fault class.  Durations are sized so the fault outlives
    the in-batch retry (max_attempts=2) for one batch, then clears —
    recovery is the ladder's job, not the schedule's."""
    if klass == "device_call":
        chaos.arm("device_call", count=2)
    elif klass == "capacity_overflow":
        chaos.arm("capacity_overflow", count=2)
    elif klass == "compile":
        srv.invalidate()  # fresh program: the next run must compile
        chaos.arm("compile", count=2)
    elif klass == "maintenance_apply":
        chaos.arm("maintenance_apply", count=1)
    elif klass == "extent_corrupt":
        chaos.corrupt_extent(srv.executor)
    elif klass == "retune_crash":
        chaos.arm("retune", count=1)
        try:
            srv.retune_online()  # rolled back; previous program serves
        except InjectedFault:
            pass  # expected: the edit rolls back, serving continues
    else:
        raise ValueError(f"unknown fault class {klass!r}")


def _stream(session, rng, names, klass: str, n_batches: int,
            metrics: dict, lines: list[str]) -> tuple[int, int]:
    """Serve one stream with `klass` injected at INJECT_AT; returns
    (served, total) batch counts."""
    chaos = FaultInjector()
    srv = session.serve(maintenance=MaintenanceConfig(auto_retune=False),
                        chaos=chaos, policy=None)
    served = unavailable = degraded_batches = 0
    recovered_at = None
    for i in range(n_batches):
        if i == INJECT_AT:
            _inject(klass, srv, chaos)
        srv.submit(inserts=_update(rng, srv.executor.store))
        name = names[i % len(names)]
        try:
            out = srv.answer_batch([name])
        except ServiceUnavailable:
            unavailable += 1
            continue
        served += 1
        last = srv.stats.last_batch
        if last["degraded"] or last["stale"]:
            degraded_batches += 1
        else:
            # an unflagged answer must equal the reference engine
            want = srv.executor.answer_group_direct(name)
            assert out[0] == want, \
                f"silently wrong answer under {klass} at batch {i}"
        if i >= INJECT_AT and recovered_at is None \
                and srv.stats.health == "HEALTHY":
            recovered_at = i
    availability = 100.0 * served / n_batches
    recovery = (recovered_at - INJECT_AT) if recovered_at is not None \
        else n_batches
    degraded_frac = degraded_batches / n_batches
    metrics[f"{klass}_availability_pct"] = availability
    metrics[f"{klass}_degraded_frac"] = degraded_frac
    metrics[f"{klass}_recovery_batches"] = recovery
    metrics[f"{klass}_injected"] = chaos.injected
    metrics[f"{klass}_final_health"] = srv.stats.health
    lines.append(emit(f"fault.{klass}", 0.0,
                      f"avail={availability:.1f}%;"
                      f"degraded={degraded_frac:.2f};"
                      f"recovery={recovery}b"))
    assert srv.stats.health == "HEALTHY", \
        f"{klass}: server must return to HEALTHY (got {srv.stats.health})"
    return served, n_batches


def main(lines: list[str]) -> None:
    quick = quick_mode()
    rng = np.random.default_rng(0)
    uni = generate(n_universities=1 if quick else 10, seed=0)
    wl = lubm_workload(uni.dictionary)
    session = TuningSession(uni.store, wl, schema=uni.schema,
                            type_id=uni.type_id, cfg=_cfg())
    session.retune()
    session.apply()
    names = [q.name for q in wl]
    n_batches = 10 if quick else 24

    metrics: dict = {"store_triples": len(session.executor.store),
                     "queries": len(wl), "quick": int(quick),
                     "batches_per_class": n_batches}
    classes = ["device_call", "capacity_overflow", "compile",
               "maintenance_apply", "extent_corrupt", "retune_crash"]
    total_served = total_batches = 0
    for klass in classes:
        served, total = _stream(session, rng, names, klass, n_batches,
                                metrics, lines)
        total_served += served
        total_batches += total

    availability = 100.0 * total_served / total_batches
    metrics["availability_pct"] = availability
    lines.append(emit("fault.aggregate", 0.0,
                      f"avail={availability:.2f}%;classes={len(classes)}"))
    assert availability >= 99.0, (
        f"degradation ladder must keep availability >= 99% under every "
        f"fault class (got {availability:.2f}%)")
    write_bench_json("fault", metrics)


if __name__ == "__main__":
    main(["name,us_per_call,derived"])
