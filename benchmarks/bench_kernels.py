"""Kernel microbenchmarks: Pallas (interpret on CPU; compiled on TPU) vs
the jnp oracle, plus the engine end-to-end with/without kernels.

On this CPU container interpret-mode timings measure Python emulation —
the DERIVED column reports the TPU-side arithmetic-intensity estimate
(bytes/flops per probe) that the roofline analysis uses."""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.bench_common import emit, time_us
from repro.kernels import ref
from repro.kernels.join_count import join_count_pallas


def main(lines: list[str]) -> None:
    rng = np.random.default_rng(0)
    for n_probe, n_build in [(1 << 12, 1 << 14), (1 << 14, 1 << 16)]:
        probe = jnp.asarray(rng.integers(0, 1 << 20, n_probe).astype(np.int32))
        build = jnp.asarray(np.sort(
            rng.integers(0, 1 << 20, n_build).astype(np.int32)))

        oracle = jax.jit(ref.join_count_ref)
        us_ref = time_us(lambda: jax.block_until_ready(oracle(probe, build)))
        # interpret-mode kernel: correctness-path timing only
        us_pal = time_us(
            lambda: jax.block_until_ready(
                join_count_pallas(probe, build, interpret=True)),
            warmup=1, iters=2)
        # TPU-side derived terms for one (256,512) tile pair:
        #   bytes/tile = (256+512)*4 ; compares = 256*512*2
        tiles = (n_probe / 256) * (n_build / 512)
        tpu_bytes = (256 + 512) * 4 * tiles
        tpu_cmps = 256 * 512 * 2 * tiles
        lines.append(emit(f"kernels.join_count.ref.{n_probe}x{n_build}",
                          us_ref, "jnp searchsorted"))
        lines.append(emit(
            f"kernels.join_count.pallas_interpret.{n_probe}x{n_build}",
            us_pal,
            f"tpu_bytes={tpu_bytes:.0f};tpu_cmps={tpu_cmps:.0f};"
            f"intensity={tpu_cmps / tpu_bytes:.1f}"))
