"""Paper table 1 (demo §4): search strategies — states explored, quality
reached, wall time.  Validates the claim that heuristics prune the
above-exponential space with bounded quality loss."""
from __future__ import annotations

import time

from benchmarks.bench_common import emit
from repro.core.quality import quality
from repro.core.search import SearchConfig, search
from repro.core.state import initial_state
from repro.rdf.generator import generate, lubm_workload


def main(lines: list[str]) -> None:
    uni = generate(n_universities=1, seed=0, dept_per_univ=2,
                   prof_per_dept=4, stud_per_dept=15, course_per_dept=6)
    workload = lubm_workload(uni.dictionary)
    st0 = initial_state(workload)
    q0 = quality(st0, uni.store.stats)
    lines.append(emit("search.initial_state", 0.0,
                      f"total={q0.total:.0f};views={len(st0.views)}"))
    for strat, budget in [("exhaustive_dfs", 2000), ("best_first", 2000),
                          ("greedy", 2000), ("beam", 2000), ("anneal", 2000)]:
        t0 = time.perf_counter()
        res = search(st0, uni.store.stats,
                     SearchConfig(strategy=strat, max_states=budget,
                                  max_seconds=45))
        dt = (time.perf_counter() - t0) * 1e6
        lines.append(emit(
            f"search.{strat}", dt,
            f"explored={res.explored};best={res.best_quality.total:.0f};"
            f"views={len(res.best.views)};"
            f"improvement={q0.total / max(res.best_quality.total, 1e-9):.2f}x"))
