"""Paper table 1 (demo §4): search strategies — states explored, quality
reached, wall time.  Validates the claim that heuristics prune the
above-exponential space with bounded quality loss.  Lands in
BENCH_search.json."""
from __future__ import annotations

import time

from benchmarks.bench_common import emit, quick_mode, write_bench_json
from repro.core.quality import quality
from repro.core.search import SearchConfig, search
from repro.core.state import initial_state
from repro.rdf.generator import generate, lubm_workload


def main(lines: list[str]) -> None:
    quick = quick_mode()
    uni = generate(n_universities=1, seed=0, dept_per_univ=2,
                   prof_per_dept=4, stud_per_dept=15, course_per_dept=6)
    workload = lubm_workload(uni.dictionary)
    st0 = initial_state(workload)
    q0 = quality(st0, uni.store.stats)
    lines.append(emit("search.initial_state", 0.0,
                      f"total={q0.total:.0f};views={len(st0.views)}"))
    budget = 400 if quick else 2000
    max_s = 15 if quick else 45
    metrics: dict = {"quick": int(quick), "initial_total": q0.total,
                     "initial_views": len(st0.views)}
    for strat in ["exhaustive_dfs", "best_first", "greedy", "beam", "anneal"]:
        t0 = time.perf_counter()
        res = search(st0, uni.store.stats,
                     SearchConfig(strategy=strat, max_states=budget,
                                  max_seconds=max_s))
        dt = (time.perf_counter() - t0) * 1e6
        improvement = q0.total / max(res.best_quality.total, 1e-9)
        lines.append(emit(
            f"search.{strat}", dt,
            f"explored={res.explored};best={res.best_quality.total:.0f};"
            f"views={len(res.best.views)};"
            f"improvement={improvement:.2f}x"))
        metrics[f"{strat}_us"] = dt
        metrics[f"{strat}_explored"] = res.explored
        metrics[f"{strat}_best_total"] = res.best_quality.total
        metrics[f"{strat}_views"] = len(res.best.views)
        metrics[f"{strat}_improvement"] = improvement
    write_bench_json("search", metrics)
