"""Serving frontend under open-loop traffic: the "millions of users"
story measured, not asserted.

A tuned LUBM session serves through `ServingFrontend` while the load
generator replays seeded Poisson arrivals (plus a streaming update
component) at three offered-load levels — 0.5x, 1.0x and 1.5x of the
server's nominal batch capacity.  The batch service model is CALIBRATED
from real measured dispatches (one full batch and one singleton through
the live `QueryServer`, plus a measured maintenance drain), then the
traffic runs on the virtual clock: deterministic under the seed, with
latencies denominated in calibrated virtual seconds.

Reported per class and per level: p50/p99/mean latency, throughput,
shed/downgrade counts and SLO compliance; plus the no-admission FIFO
baseline at the overload level.  The acceptance story is asserted
in-process before BENCH_serve.json is written:

  * at 0.5x (the CI gate level): zero sheds and the top class's p99
    within its SLO budget,
  * at 1.5x with admission control: shed rate > 0 AND the top class's
    p99 still within SLO,
  * at 1.5x without admission (FIFO, unbounded queue): the top class's
    p99 breaches — admission control is what holds the SLO.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.bench_common import emit, quick_mode, write_bench_json
from repro.api import (MaintenanceConfig, QualityWeights, SearchConfig,
                       TuningSession, WizardConfig)
from repro.rdf.generator import generate, lubm_workload
from repro.serve.frontend import (FixedServiceModel, FrontendConfig,
                                  QueryClass, ServingFrontend, VirtualClock)
from repro.serve.loadgen import ClassSpec, TrafficConfig, run_open_loop

MAX_BATCH = 16
QUEUE_CAP = 64
LEVELS = (("0.5x", 0.5), ("1.0x", 1.0), ("1.5x", 1.5))


def _cfg() -> WizardConfig:
    return WizardConfig(search=SearchConfig(
        strategy="greedy", max_states=400,
        weights=QualityWeights(w_exec=1.0, w_maint=1.0, w_space=1.0)))


def _update(rng, store, size=8):
    tt = store.triples
    return np.stack([rng.choice(np.unique(tt[:, 0]), size),
                     rng.choice(np.unique(tt[:, 1]), size),
                     rng.choice(np.unique(tt[:, 2]), size)],
                    axis=1).astype(np.int32)


def _measure(fn, iters: int) -> float:
    fn()  # warm
    t0 = time.perf_counter()
    for _ in range(iters):
        fn()
    return (time.perf_counter() - t0) / iters


def calibrate(session, names, rng, iters: int) -> FixedServiceModel:
    """Fit the virtual batch service model to the live server: base +
    per-request from measured full/singleton dispatches, per-maintained-
    triple from a measured update drain."""
    srv = session.serve(maintenance=MaintenanceConfig(auto_retune=False))
    full = (names * MAX_BATCH)[:MAX_BATCH]
    w_full = _measure(lambda: srv.answer_batch(full), iters)
    w_one = _measure(lambda: srv.answer_batch(names[:1]), iters)
    per_request = max((w_full - w_one) / (MAX_BATCH - 1), 1e-7)
    base = max(w_one - per_request, 1e-5)

    def drain():
        srv.submit(inserts=_update(rng, srv.executor.store, 16))
        srv.flush()

    applied0 = srv.stats.updates_applied
    w_maint = _measure(drain, max(2, iters // 2))
    n_applied = max(srv.stats.updates_applied - applied0, 1)
    per_triple = max(w_maint * (max(2, iters // 2) + 1) / n_applied, 1e-7)
    return FixedServiceModel(base, per_request, per_triple)


def build_frontend(session, classes, model, window, admission="shed",
                   priority_dispatch=True, queue_cap=QUEUE_CAP):
    server = session.serve(maintenance=MaintenanceConfig(auto_retune=False))
    return ServingFrontend(
        server, classes,
        FrontendConfig(queue_cap=queue_cap, batching_window=window,
                       max_batch=MAX_BATCH, admission=admission,
                       priority_dispatch=priority_dispatch),
        clock=VirtualClock(), service_model=model)


def _record(metrics, lines, tag, rep, slo_ms):
    metrics[f"{tag}.shed_rate"] = round(rep.shed_rate, 4)
    metrics[f"{tag}.throughput_rps"] = round(rep.throughput, 1)
    metrics[f"{tag}.batch_occupancy"] = round(rep.batch_occupancy, 2)
    metrics[f"{tag}.max_queue_depth"] = rep.max_queue_depth
    for cname, cr in rep.per_class.items():
        p = f"{tag}.{cname}"
        metrics[f"{p}.p50_ms"] = round(cr.p50 * 1e3, 4)
        metrics[f"{p}.p99_ms"] = round(cr.p99 * 1e3, 4)
        metrics[f"{p}.mean_ms"] = round(cr.mean * 1e3, 4)
        metrics[f"{p}.throughput_rps"] = round(cr.throughput, 1)
        metrics[f"{p}.offered"] = cr.offered
        metrics[f"{p}.shed"] = cr.shed
        metrics[f"{p}.downgraded"] = cr.downgraded
        metrics[f"{p}.slo_ms"] = (round(cr.slo * 1e3, 4)
                                  if cr.slo is not None else "none")
        metrics[f"{p}.slo_met"] = str(cr.slo_met)
    g = rep.per_class["gold"]
    lines.append(emit(
        f"serve.{tag}", g.p99 * 1e6,
        f"gold_p99/slo={g.p99 * 1e3:.2f}/{slo_ms:.2f}ms;"
        f"shed={rep.shed_rate:.2f};thr={rep.throughput:.0f}rps"))


def main(lines: list[str]) -> None:
    quick = quick_mode()
    rng = np.random.default_rng(0)
    uni = generate(n_universities=1 if quick else 10, seed=0)
    wl = lubm_workload(uni.dictionary)
    session = TuningSession(uni.store, wl, schema=uni.schema,
                            type_id=uni.type_id, cfg=_cfg())
    session.retune()
    session.apply()
    names = [q.name for q in wl]

    model = calibrate(session, names, rng, iters=3 if quick else 8)
    # every timescale is service-relative so the regime is identical
    # whatever the calibrated wall costs came out to: batching window =
    # one full-batch service, update batches sized so one maintenance
    # drain costs at most ~2 batch services, SLOs carry one maintenance
    # allowance (an update can stall exactly one in-flight batch)
    s_max = model.estimate(MAX_BATCH)
    window = s_max
    capacity = MAX_BATCH / s_max          # requests / virtual second
    upd_size = max(1, min(8, int(2.0 * s_max / model.per_maint_triple)))
    maint_cost = upd_size * model.per_maint_triple
    gold_slo = window + 4.0 * s_max + maint_cost
    std_slo = window + 16.0 * s_max + maint_cost
    bulk_slo = 400.0 * s_max + maint_cost
    class_specs = (
        ClassSpec("gold", 0.2, tuple(names[0::3]), priority=2, slo=gold_slo),
        ClassSpec("std", 0.3, tuple(names[1::3]), priority=1, slo=std_slo),
        ClassSpec("bulk", 0.5, tuple(names[2::3] or names[:1]), priority=0,
                  slo=bulk_slo),
    )
    classes = [QueryClass(c.name, priority=c.priority, slo=c.slo)
               for c in class_specs]
    duration = (150 if quick else 400) * s_max
    update_rate = 4.0 / duration          # a few update batches per run

    metrics: dict = {
        "store_triples": len(session.executor.store), "queries": len(wl),
        "quick": int(quick), "batch_base_us": round(model.batch_base * 1e6, 2),
        "per_request_us": round(model.per_request * 1e6, 3),
        "per_maint_triple_us": round(model.per_maint_triple * 1e6, 3),
        "capacity_rps": round(capacity, 1), "max_batch": MAX_BATCH,
        "queue_cap": QUEUE_CAP, "batching_window_ms": round(window * 1e3, 4),
        "update_size": upd_size, "gold_slo_ms": round(gold_slo * 1e3, 4),
    }

    def traffic(scale):
        return TrafficConfig(
            rate=scale * capacity, duration=duration, classes=class_specs,
            seed=42, update_rate=update_rate, update_size=upd_size)

    def update_fn(urng):
        return _update(urng, session.executor.store, upd_size), None

    reports = {}
    for tag, scale in LEVELS:
        fe = build_frontend(session, classes, model, window)
        reports[tag] = run_open_loop(fe, traffic(scale), update_fn=update_fn)
        _record(metrics, lines, tag, reports[tag], gold_slo * 1e3)

    # no-admission FIFO baseline at the overload level: same traffic,
    # no SLO shedding, no priority dispatch, effectively unbounded queue
    fe_base = build_frontend(session, classes, model, window,
                             admission="none", priority_dispatch=False,
                             queue_cap=1 << 16)
    base = run_open_loop(fe_base, traffic(1.5), update_fn=update_fn)
    _record(metrics, lines, "1.5x_noadm", base, gold_slo * 1e3)

    # ---- acceptance assertions (the CI SLO gate) ---------------------
    low, high = reports["0.5x"], reports["1.5x"]
    assert low.shed_rate == 0.0, \
        f"must not shed at 0.5x load (shed_rate={low.shed_rate})"
    assert low.per_class["gold"].slo_met is True, (
        f"gold p99 {low.per_class['gold'].p99 * 1e3:.2f}ms breaches its "
        f"{gold_slo * 1e3:.2f}ms SLO at 0.5x load")
    assert high.shed_rate > 0.0, "overload must shed under admission control"
    assert high.per_class["gold"].slo_met is True, (
        "admission control must hold the gold p99 SLO under 1.5x overload "
        f"(p99={high.per_class['gold'].p99 * 1e3:.2f}ms)")
    assert base.per_class["gold"].slo_met is False, (
        "the no-admission baseline should breach the gold SLO under "
        "overload — otherwise the offered load is not an overload")
    write_bench_json("serve", metrics)


if __name__ == "__main__":
    main(["name,us_per_call,derived"])
