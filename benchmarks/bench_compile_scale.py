"""Compile-time scaling of the shape-bucketed workload executor.

The unrolled fused program traces one closure per DAG node, so its XLA
compile time grows linearly with workload size; the bucketed lowering
(query/buckets.py) compiles one `lax.scan` body per distinct shape, so
its compile time should stay near-flat as the workload grows from 22 to
1000+ members drawn from a fixed template vocabulary.

The sweep synthesizes distinct LUBM-vocabulary queries (same shapes,
different constants), clears the persistent compile cache at every
point (cold-compile measurement), runs the bucketed executor, and
checks every answer bit-identically against the numpy reference engine.
An unrolled A/B leg runs at the small end of the sweep — past that its
compile time is the wall this benchmark exists to remove.

Gate (CI runs the quick sweep): cold compile time at the largest point
must stay within `THRESHOLD`x the smallest point — super-linear compile
scaling fails the job.  Full mode covers 22 -> 1000 members for the
acceptance table in docs/query_pipeline.md.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.bench_common import (emit, quick_mode, time_us,
                                     write_bench_json)
from repro.core.queries import Atom, CQ, Const, Var
from repro.query import engine as E
from repro.query import ref_engine as R
from repro.query.buckets import clear_compile_cache, compile_cache
from repro.query.dag import build_dag
from repro.query.plan import plan_for_cq
from repro.query.workload import WorkloadExecutor
from repro.rdf.generator import generate

THRESHOLD = 3.0  # max allowed compile-time ratio, largest vs smallest N


# ----------------------------------------------------------------------
# synthetic workload: fixed template shapes, growing constant supply
# ----------------------------------------------------------------------
def synth_workload(uni, n: int) -> list[CQ]:
    """`n` distinct conjunctive queries over the LUBM vocabulary.

    Five templates (three single-scan shapes, two join shapes) are
    drawn round-robin; successive queries of one template differ only
    in their bound constants, so workload growth adds *members*, not
    *shapes* — the regime the bucketed executor targets.  The course-
    pair template supplies O(|courses|^2) distinct queries, so a small
    universe sustains 1000+ members.
    """
    d = uni.dictionary
    takes = Const(d.lookup("ub:takesCourse"))
    member = Const(d.lookup("ub:memberOf"))
    teacher = Const(d.lookup("ub:teacherOf"))
    t = np.asarray(uni.store.triples)
    courses = [int(c) for c in np.unique(t[t[:, 1] == takes.id][:, 2])]
    depts = [int(c) for c in np.unique(t[t[:, 1] == member.id][:, 2])]
    x, y = Var("x"), Var("y")

    def t_takes():
        for c in courses:
            yield (x,), (Atom(x, takes, Const(c)),)

    def t_member():
        for dep in depts:
            yield (x,), (Atom(x, member, Const(dep)),)

    def t_teacher():
        for c in courses:
            yield (y,), (Atom(y, teacher, Const(c)),)

    def t_dept_course():
        for dep in depts:
            for c in courses:
                yield (x,), (Atom(x, takes, Const(c)),
                             Atom(x, member, Const(dep)))

    def t_course_pair():
        for i, c1 in enumerate(courses):
            for c2 in courses[i + 1:]:
                yield (x,), (Atom(x, takes, Const(c1)),
                             Atom(x, takes, Const(c2)))

    streams = [t_takes(), t_member(), t_teacher(), t_dept_course(),
               t_course_pair()]
    out: list[CQ] = []
    while len(out) < n and streams:
        alive = []
        for s in streams:
            head_atoms = next(s, None)
            if head_atoms is None:
                continue
            alive.append(s)
            head, atoms = head_atoms
            out.append(CQ(head, atoms, name=f"q{len(out)}"))
            if len(out) == n:
                return out
        streams = alive
    raise ValueError(f"template supply exhausted at {len(out)} < {n} "
                     f"queries; grow the universe")


def _sorted_rows(rows) -> np.ndarray:
    a = np.asarray(rows, np.int64)
    if a.size == 0:
        return np.zeros((0,), np.int64)
    a = a.reshape(len(a), -1)
    return a[np.lexsort(a.T[::-1])].ravel()


def check_exact(uni, qs: list[CQ], roots) -> int:
    """Bit-identical comparison against the reference engine: sorted
    result arrays must be exactly equal.  Returns the mismatch count."""
    bad = 0
    for q in qs:
        got = _sorted_rows(E.to_numpy(roots[q.name]))
        want = _sorted_rows(sorted(R.evaluate_cq(q, uni.store).as_set()))
        if not np.array_equal(got, want):
            bad += 1
    return bad


# ----------------------------------------------------------------------
def main(lines: list[str]) -> None:
    quick = quick_mode()
    if quick:
        uni = generate(n_universities=1, seed=0, dept_per_univ=2,
                       prof_per_dept=4, stud_per_dept=12, course_per_dept=5)
        sweep, unroll_cap = [8, 32, 64], 32
    else:
        uni = generate(n_universities=2, seed=0, dept_per_univ=4,
                       prof_per_dept=4, stud_per_dept=20, course_per_dept=8)
        sweep, unroll_cap = [22, 64, 128, 256, 512, 1000], 64
    tt = E.tt_device_indexes(uni.store)

    metrics: dict = {"quick": int(quick), "threshold": THRESHOLD,
                     "members_min": sweep[0], "members_max": sweep[-1]}
    compile_s: dict[int, float] = {}
    for n in sweep:
        qs = synth_workload(uni, n)
        dag = build_dag({q.name: plan_for_cq(q) for q in qs})

        clear_compile_cache()  # measure cold compiles at every point
        wl = WorkloadExecutor(dag, uni.store.stats, {}, max_retries=24)
        t0 = time.perf_counter()
        roots = wl.run(tt, {})
        first_s = time.perf_counter() - t0
        mismatches = check_exact(uni, qs, roots)
        assert mismatches == 0, (
            f"{mismatches} results differ from ref_engine at N={n}")

        def run():
            out = wl.run(tt, {})
            next(iter(out.values())).n.block_until_ready()

        steady_us = time_us(run, warmup=1, iters=3)
        t = wl.telemetry()
        compile_s[n] = t["bucket_compile_seconds"]
        st = dag.stats()
        metrics[f"compile_s_{n}"] = t["bucket_compile_seconds"]
        metrics[f"first_run_s_{n}"] = first_s
        metrics[f"steady_us_{n}"] = steady_us
        metrics[f"buckets_{n}"] = t["buckets"]
        metrics[f"bucket_signatures_{n}"] = t["bucket_signatures"]
        metrics[f"bucket_compiles_{n}"] = t["bucket_compiles"]
        metrics[f"recompiles_{n}"] = t["recompiles"]
        metrics[f"dag_nodes_{n}"] = st["dag_nodes"]
        lines.append(emit(
            f"compile_scale.bucketed.n{n}", steady_us,
            f"compile_s={t['bucket_compile_seconds']:.2f} "
            f"buckets={t['buckets']} dag_nodes={st['dag_nodes']}"))

        if n <= unroll_cap:  # A/B: linear-compile reference path
            wl_u = WorkloadExecutor(dag, uni.store.stats, {},
                                    max_retries=24, mode="unrolled")
            t0 = time.perf_counter()
            roots_u = wl_u.run(tt, {})
            unrolled_s = time.perf_counter() - t0
            assert check_exact(uni, qs, roots_u) == 0
            metrics[f"unrolled_first_run_s_{n}"] = unrolled_s
            lines.append(emit(f"compile_scale.unrolled.n{n}", 0.0,
                              f"first_run_s={unrolled_s:.2f}"))

    ratio = compile_s[sweep[-1]] / max(compile_s[sweep[0]], 1e-9)
    metrics["compile_ratio"] = ratio
    metrics["compile_cache_entries_last"] = compile_cache().stats()["entries"]
    lines.append(emit("compile_scale.ratio", 0.0,
                      f"{ratio:.2f}x over {sweep[0]}->{sweep[-1]} members "
                      f"(threshold {THRESHOLD}x)"))
    write_bench_json("compile_scale", metrics)
    assert ratio <= THRESHOLD, (
        f"compile time grew {ratio:.2f}x from {sweep[0]} to {sweep[-1]} "
        f"members (> {THRESHOLD}x): bucketed compile scaling regressed")
