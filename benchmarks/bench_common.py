"""Shared timing utilities for the benchmark harness."""
from __future__ import annotations

import json
import os
import time


def time_us(fn, *args, warmup: int = 2, iters: int = 10) -> float:
    for _ in range(warmup):
        fn(*args)
    t0 = time.perf_counter()
    for _ in range(iters):
        fn(*args)
    return (time.perf_counter() - t0) / iters * 1e6


def emit(name: str, us: float, derived: str = "") -> str:
    line = f"{name},{us:.1f},{derived}"
    print(line)
    return line


def quick_mode() -> bool:
    """CI smoke mode: shrink datasets/iterations (set REPRO_BENCH_QUICK=1)."""
    return os.environ.get("REPRO_BENCH_QUICK", "") not in ("", "0")


def write_bench_json(suite: str, metrics: dict, out_dir: str | None = None) -> str:
    """Standard benchmark artifact: BENCH_<suite>.json with a flat
    metrics dict (numbers or strings); returns the path written."""
    out_dir = out_dir or os.environ.get("REPRO_BENCH_DIR", ".")
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"BENCH_{suite}.json")
    with open(path, "w") as f:
        json.dump({"suite": suite, "metrics": metrics}, f, indent=2,
                  sort_keys=True)
    print(f"# wrote {path}")
    return path
