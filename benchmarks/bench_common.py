"""Shared timing utilities for the benchmark harness."""
from __future__ import annotations

import time


def time_us(fn, *args, warmup: int = 2, iters: int = 10) -> float:
    for _ in range(warmup):
        fn(*args)
    t0 = time.perf_counter()
    for _ in range(iters):
        fn(*args)
    return (time.perf_counter() - t0) / iters * 1e6


def emit(name: str, us: float, derived: str = "") -> str:
    line = f"{name},{us:.1f},{derived}"
    print(line)
    return line
