"""Benchmark harness: one module per paper table/figure.

  search          demo §4 / TR: strategies vs states explored vs quality
  query_eval      demo finale: TT vs materialized views latency
  compile_scale   bucketed executor: compile time vs workload size
  retune          TuningSession: cold tune() vs warm retune()+delta apply()
  reformulation   §3 Workload Processor: union sizes + completeness gain
  maintenance     quality m-term: incremental vs recompute
  fault           degradation ladder: availability/recovery per fault class
  serve           async frontend: per-class p50/p99 + SLO at 3 offered loads
  kernels         Pallas join probe vs jnp oracle (+TPU derived terms)
  lm_step         LM substrate smoke-step timings

Prints ``name,us_per_call,derived`` CSV.  Roofline numbers for the full
(arch x shape x mesh) grid come from the dry-run artifacts
(artifacts/dryrun/*.json) — see EXPERIMENTS.md §Roofline.
"""
from __future__ import annotations

import os
import sys


def main() -> None:
    from benchmarks import (bench_compile_scale, bench_fault, bench_kernels,
                            bench_lm_step, bench_maintenance,
                            bench_query_eval, bench_reformulation,
                            bench_retune, bench_search, bench_serve)

    args = sys.argv[1:]
    if "--quick" in args:  # CI smoke: small datasets, few iterations
        os.environ["REPRO_BENCH_QUICK"] = "1"
        args = [a for a in args if a != "--quick"]
    only = args[0] if args else None
    suites = {
        "search": bench_search.main,
        "query_eval": bench_query_eval.main,
        "compile_scale": bench_compile_scale.main,
        "retune": bench_retune.main,
        "reformulation": bench_reformulation.main,
        "maintenance": bench_maintenance.main,
        "fault": bench_fault.main,
        "serve": bench_serve.main,
        "kernels": bench_kernels.main,
        "lm_step": bench_lm_step.main,
    }
    lines: list[str] = ["name,us_per_call,derived"]
    print("name,us_per_call,derived")
    for name, fn in suites.items():
        if only and name != only:
            continue
        fn(lines)
    print(f"# {len(lines) - 1} rows")


if __name__ == "__main__":
    main()
