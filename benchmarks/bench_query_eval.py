"""Paper demo finale + workload-compilation A/B.

Part 1 (the demo's performance claim): per-query latency answered from
the triple table vs from the wizard's materialized views.

Part 2 (workload-level compilation): the per-query jitted path — one
XLA program per workload member — vs the fused shared-subplan executor
— ONE program for the entire workload (query/dag.py + workload.py).
Reports compile count, compile time, per-workload latency, and the
DAG's shared-node hit rate; the speedup lands in BENCH_query_eval.json.
"""
from __future__ import annotations

import time

import jax

from benchmarks.bench_common import (emit, quick_mode, time_us,
                                     write_bench_json)
from repro.core.search import SearchConfig
from repro.core.wizard import WizardConfig, tune
from repro.query import engine as E
from repro.query.dag import build_dag
from repro.query.plan import plan_for_cq
from repro.query.workload import WorkloadExecutor
from repro.rdf.generator import generate, lubm_workload


def main(lines: list[str]) -> None:
    quick = quick_mode()
    uni = generate(n_universities=1 if quick else 4, seed=0)
    workload = lubm_workload(uni.dictionary)
    rep = tune(uni.store, workload, uni.schema, uni.type_id,
               WizardConfig(search=SearchConfig(strategy="greedy",
                                                max_states=60 if quick
                                                else 300)))
    ex = rep.executor
    tt = E.tt_device_indexes(uni.store)

    # ------------------------------------------------------------------
    # part 1: TT vs materialized views, per query group
    # ------------------------------------------------------------------
    speedups = []
    for q in workload:
        # baseline: every reformulation member evaluated over the TT
        members = [m for m in rep.result.best.queries
                   if m.name in rep.groups[q.name]]
        base_fns = []
        for m in members:
            fn = E.build_executor(plan_for_cq(m), uni.store.stats, {})
            base_fns.append(jax.jit(fn))

        def run_base():
            for f in base_fns:
                f(tt, {}).n.block_until_ready()

        def run_views():
            for name in rep.groups[q.name]:
                fn, _ = ex._fns[name]
                fn(ex.tt, ex.device_views).n.block_until_ready()

        us_base = time_us(run_base)
        us_views = time_us(run_views)
        speedups.append(us_base / max(us_views, 1e-9))
        lines.append(emit(f"query_eval.{q.name}.tt", us_base,
                          f"members={len(members)}"))
        lines.append(emit(f"query_eval.{q.name}.views", us_views,
                          f"speedup={us_base / max(us_views, 1e-9):.2f}x"))
    geo = 1.0
    for s in speedups:
        geo *= s
    geo **= 1.0 / len(speedups)
    lines.append(emit("query_eval.geomean_speedup", 0.0, f"{geo:.2f}x"))

    # ------------------------------------------------------------------
    # part 2: per-query compilation vs fused workload executor
    # (both over the no-views baseline plans: identical physical work,
    #  so the delta isolates sharing + single-dispatch)
    # ------------------------------------------------------------------
    members = list(rep.result.best.queries)
    plans = {m.name: plan_for_cq(m) for m in members}

    # per-query path: one XLA program per member
    t0 = time.perf_counter()
    per_q = [jax.jit(E.build_executor(p, uni.store.stats, {}))
             for p in plans.values()]
    for f in per_q:  # first call = compile
        f(tt, {}).n.block_until_ready()
    perq_compile_us = (time.perf_counter() - t0) * 1e6
    perq_compiles = len(per_q)

    def run_per_query():
        for f in per_q:
            f(tt, {}).n.block_until_ready()

    perq_us = time_us(run_per_query)

    # fused path: one program for the whole workload.  The A/B leg runs
    # unrolled — this benchmark isolates subplan sharing + single
    # dispatch against per-query compilation at matched lowering; the
    # bucketed lowering's compile-time scaling (and its per-run driver
    # overhead) is measured separately in bench_compile_scale.
    dag = build_dag(plans)
    wl = WorkloadExecutor(dag, uni.store.stats, {}, mode="unrolled")
    t0 = time.perf_counter()
    wl.run(tt, {})  # compile + first run (adaptive driver)
    fused_compile_us = (time.perf_counter() - t0) * 1e6
    fused_compiles = wl.compiles

    def run_fused():
        roots = wl.run(tt, {})
        next(iter(roots.values())).n.block_until_ready()

    fused_us = time_us(run_fused)

    # bucketed steady-state latency, for the record (same DAG/answers)
    wl_b = WorkloadExecutor(build_dag(plans), uni.store.stats, {})
    wl_b.run(tt, {})

    def run_bucketed():
        roots = wl_b.run(tt, {})
        next(iter(roots.values())).n.block_until_ready()

    bucketed_us = time_us(run_bucketed)
    st = dag.stats()
    workload_speedup = perq_us / max(fused_us, 1e-9)

    lines.append(emit("query_eval.workload.per_query", perq_us,
                      f"compiles={perq_compiles}"))
    lines.append(emit("query_eval.workload.fused", fused_us,
                      f"compiles={fused_compiles} "
                      f"shared={st['shared_nodes']} "
                      f"hit_rate={st['hit_rate']:.2f}"))
    lines.append(emit("query_eval.workload.speedup", 0.0,
                      f"{workload_speedup:.2f}x"))
    lines.append(emit("query_eval.workload.bucketed", bucketed_us,
                      f"buckets={wl_b.telemetry()['buckets']}"))

    assert fused_compiles < perq_compiles, (
        "fused executor must compile strictly fewer programs")

    write_bench_json("query_eval", {
        "geomean_tt_vs_views_speedup": geo,
        "workload_members": len(members),
        "per_query_compile_us": perq_compile_us,
        "per_query_compiles": perq_compiles,
        "per_query_workload_us": perq_us,
        "fused_compile_us": fused_compile_us,
        "fused_compiles": fused_compiles,
        "fused_workload_us": fused_us,
        "fused_recompiles": wl.recompiles,
        "bucketed_workload_us": bucketed_us,
        "bucketed_buckets": wl_b.telemetry()["buckets"],
        "bucketed_compile_s": wl_b.telemetry()["bucket_compile_seconds"],
        "dag_nodes": st["dag_nodes"],
        "tree_nodes": st["tree_nodes"],
        "shared_nodes": st["shared_nodes"],
        "node_reuse_count": st["node_reuse_count"],
        "shared_node_hit_rate": st["hit_rate"],
        "workload_speedup": workload_speedup,
    })
