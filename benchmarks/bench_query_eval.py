"""Paper demo finale: per-query latency answered from the triple table vs
from the wizard's materialized views (the performance benefit the demo
shows attendees).  JAX engine both ways; µs per query."""
from __future__ import annotations

import jax

from benchmarks.bench_common import emit, time_us
from repro.core.search import SearchConfig
from repro.core.wizard import WizardConfig, tune
from repro.query import engine as E
from repro.query.plan import plan_for_cq
from repro.rdf.generator import generate, lubm_workload


def main(lines: list[str]) -> None:
    uni = generate(n_universities=4, seed=0)
    workload = lubm_workload(uni.dictionary)
    rep = tune(uni.store, workload, uni.schema, uni.type_id,
               WizardConfig(search=SearchConfig(strategy="greedy",
                                                max_states=300)))
    ex = rep.executor
    tt = E.tt_device_indexes(uni.store)

    speedups = []
    for q in workload:
        # baseline: every reformulation member evaluated over the TT
        members = [m for m in rep.result.best.queries
                   if m.name in rep.groups[q.name]]
        base_fns = []
        for m in members:
            fn = E.build_executor(plan_for_cq(m), uni.store.stats, {})
            base_fns.append(jax.jit(fn))

        def run_base():
            for f in base_fns:
                f(tt, {}).n.block_until_ready()

        def run_views():
            for name in rep.groups[q.name]:
                fn, _ = ex._fns[name]
                fn(ex.tt, ex.device_views).n.block_until_ready()

        us_base = time_us(run_base)
        us_views = time_us(run_views)
        speedups.append(us_base / max(us_views, 1e-9))
        lines.append(emit(f"query_eval.{q.name}.tt", us_base,
                          f"members={len(members)}"))
        lines.append(emit(f"query_eval.{q.name}.views", us_views,
                          f"speedup={us_base / max(us_views, 1e-9):.2f}x"))
    geo = 1.0
    for s in speedups:
        geo *= s
    geo **= 1.0 / len(speedups)
    lines.append(emit("query_eval.geomean_speedup", 0.0, f"{geo:.2f}x"))
