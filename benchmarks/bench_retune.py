"""Incremental re-tuning A/B: cold `tune()` vs warm `retune()+apply()`.

Scenario (the TuningSession lifecycle under workload drift): a store is
tuned for a prefix workload; then one query is added.  The cold path
re-runs the whole wizard from `initial_state`; the warm path resumes
the States Navigator from the previous best and delta-swaps only the
views whose canonical key changed.  Reports states explored, quality
totals, wall time, and the materialize/reuse split; lands in
BENCH_retune.json with the acceptance assertions applied.
"""
from __future__ import annotations

import time

from benchmarks.bench_common import emit, quick_mode, write_bench_json
from repro.api import (QualityWeights, SearchConfig, TuningSession,
                       WizardConfig)
from repro.rdf.generator import generate, lubm_workload


def _cfg(quick: bool) -> WizardConfig:
    return WizardConfig(search=SearchConfig(
        strategy="greedy", max_states=600 if quick else 3000,
        weights=QualityWeights(w_exec=1.0, w_maint=1.0, w_space=1.0)))


def main(lines: list[str]) -> None:
    quick = quick_mode()
    uni = generate(n_universities=1 if quick else 2, seed=0)
    wl = lubm_workload(uni.dictionary)
    prefix, perturbation = wl[:-1], wl[-1]

    # cold: one-shot wizard over the full (perturbed) workload
    t0 = time.perf_counter()
    cold = TuningSession(uni.store, wl, schema=uni.schema,
                         type_id=uni.type_id, cfg=_cfg(quick))
    cold_rep = cold.retune()
    cold_apply = cold.apply()
    cold_us = (time.perf_counter() - t0) * 1e6

    # warm: session tuned on the prefix, then add the query + retune
    warm = TuningSession(uni.store, prefix, schema=uni.schema,
                         type_id=uni.type_id, cfg=_cfg(quick))
    warm.retune()
    warm.apply()
    t0 = time.perf_counter()
    warm.add_query(perturbation)
    warm_rep = warm.retune()
    warm_apply = warm.apply()
    warm_us = (time.perf_counter() - t0) * 1e6

    cold_explored = cold_rep.result.explored
    warm_explored = warm_rep.result.explored
    cold_total = cold_rep.result.best_quality.total
    warm_total = warm_rep.result.best_quality.total

    lines.append(emit("retune.cold", cold_us,
                      f"explored={cold_explored};total={cold_total:.0f};"
                      f"materialized={len(cold_apply.materialized)}"))
    lines.append(emit("retune.warm", warm_us,
                      f"explored={warm_explored};total={warm_total:.0f};"
                      f"materialized={len(warm_apply.materialized)};"
                      f"reused={len(warm_apply.reused)}"))
    lines.append(emit(
        "retune.speedup", 0.0,
        f"explored={cold_explored / max(warm_explored, 1):.2f}x;"
        f"wall={cold_us / max(warm_us, 1e-9):.2f}x"))

    # acceptance: strictly fewer states at equal-or-better quality, and
    # the swap only touches the diffed views
    assert warm_explored < cold_explored, (
        f"warm retune must explore strictly fewer states "
        f"({warm_explored} vs {cold_explored})")
    assert warm_total <= cold_total + 1e-9, (
        f"warm retune must reach equal-or-better quality "
        f"({warm_total} vs {cold_total})")
    assert warm_apply.reused and \
        len(warm_apply.materialized) < len(warm.best.views), (
            "delta apply must reuse surviving views")

    write_bench_json("retune", {
        "workload_queries": len(wl),
        "perturbation": perturbation.name,
        "cold_explored": cold_explored,
        "warm_explored": warm_explored,
        "explored_ratio": cold_explored / max(warm_explored, 1),
        "cold_quality_total": cold_total,
        "warm_quality_total": warm_total,
        "cold_wall_us": cold_us,
        "warm_wall_us": warm_us,
        "wall_speedup": cold_us / max(warm_us, 1e-9),
        "cold_views_materialized": len(cold_apply.materialized),
        "warm_views_materialized": len(warm_apply.materialized),
        "warm_views_reused": len(warm_apply.reused),
        "warm_views_dropped": len(warm_apply.dropped),
    })
