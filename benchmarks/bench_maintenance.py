"""View maintenance (quality-function m-term): incremental single-triple
maintenance vs full recompute."""
from __future__ import annotations

import numpy as np

from benchmarks.bench_common import emit, time_us
from repro.core.queries import full_projection
from repro.rdf.generator import generate, lubm_workload
from repro.views.maintenance import maintain
from repro.views.materializer import materialize_view


def main(lines: list[str]) -> None:
    uni = generate(n_universities=2, seed=0)
    workload = lubm_workload(uni.dictionary)
    d = uni.dictionary
    takes = d.lookup("ub:takesCourse")
    students = uni.store.scan(None, d.lookup("ub:memberOf"), None)[:, 0]
    courses = uni.store.scan(None, takes, None)[:, 2]
    rng = np.random.default_rng(0)

    for q in workload[:3]:
        view_cq = full_projection(q.atoms, name=f"v_{q.name}")
        extent = materialize_view(view_cq, uni.store).rows
        triple = (int(rng.choice(students)), takes, int(rng.choice(courses)))

        us_inc = time_us(
            lambda: maintain(view_cq, extent, uni.store, triple), iters=5)
        us_full = time_us(
            lambda: materialize_view(view_cq, uni.store.insert(
                np.array([triple], np.int32))), iters=5)
        lines.append(emit(f"maintenance.{q.name}.incremental", us_inc,
                          f"rows={len(extent)}"))
        lines.append(emit(f"maintenance.{q.name}.recompute", us_full,
                          f"speedup={us_full / max(us_inc, 1e-9):.1f}x"))
