"""Streaming maintenance A/B: incremental device maintenance vs full
re-materialization.

Scenario (the serving store under a write stream): a tuned LUBM session
is streamed mixed insert/delete batches.  The incremental path is one
`ViewMaintainer.apply()` — host membership deletes + Pallas scatter-
append inserts inside fixed capacity classes, zero steady-state
recompiles.  The full path is what the system did before the subsystem
existed: `QueryExecutor.refresh()` — re-evaluate every extent, re-upload
everything, rebuild the program.  Swept over batch sizes and
update:query ratios; also demonstrates measured maintenance costs
shifting the retune objective and a drift-triggered auto-retune.  Lands
in BENCH_maintenance.json with the acceptance assertions applied
(incremental >= 5x on batches <= 1% of the store).
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.bench_common import emit, quick_mode, write_bench_json
from repro.api import (MaintenanceConfig, QualityWeights, SearchConfig,
                       TuningSession, WizardConfig)
from repro.core.quality import quality
from repro.maintenance import Delta, ViewMaintainer
from repro.rdf.generator import generate, lubm_workload


def _cfg() -> WizardConfig:
    return WizardConfig(search=SearchConfig(
        strategy="greedy", max_states=400,
        weights=QualityWeights(w_exec=1.0, w_maint=1.0, w_space=1.0)))


def _mixed_batch(rng, store, size: int, frac_deletes: float = 0.3):
    """size triples: fresh inserts in the store's id universe + deletes
    drawn from the live table."""
    n_del = min(int(size * frac_deletes), len(store.triples))
    n_ins = size - n_del
    tt = store.triples
    subjects = np.unique(tt[:, 0])
    preds = np.unique(tt[:, 1])
    objects = np.unique(tt[:, 2])
    ins = np.stack([rng.choice(subjects, n_ins), rng.choice(preds, n_ins),
                    rng.choice(objects, n_ins)], axis=1).astype(np.int32)
    dels = tt[rng.choice(len(tt), n_del, replace=False)]
    return Delta.of(ins, dels)


def main(lines: list[str]) -> None:
    quick = quick_mode()
    rng = np.random.default_rng(0)
    # full mode runs at a scale where full re-materialization visibly
    # hurts (~43k triples); quick keeps CI structural (small store, so
    # no batch clears the <=1% bar and the speedup floor is full-only)
    uni = generate(n_universities=1 if quick else 60, seed=0)
    wl = lubm_workload(uni.dictionary)

    session = TuningSession(uni.store, wl, schema=uni.schema,
                            type_id=uni.type_id, cfg=_cfg())
    session.retune()
    session.apply()
    ex = session.executor
    n_tt = len(ex.store)

    # ------------------------------------------------------------------
    # incremental vs full re-materialization across batch sizes
    # ------------------------------------------------------------------
    batch_sizes = [8, 64] if quick else [8, 64, 512]
    reps = 3 if quick else 5
    metrics: dict = {"store_triples": n_tt, "views": len(ex.state.views),
                     "queries": len(wl), "quick": int(quick)}
    qualifying_speedups = []  # batches <= 1% of the store
    maintainer = None
    for size in batch_sizes:
        maintainer = ViewMaintainer(ex, MaintenanceConfig(),
                                    costs=session.maintenance_costs)
        maintainer.apply(_mixed_batch(rng, ex.store, size))  # compile/warm
        inc_times = []
        for _ in range(reps):
            delta = _mixed_batch(rng, ex.store, size)
            t0 = time.perf_counter()
            maintainer.apply(delta)
            inc_times.append(time.perf_counter() - t0)
        inc_us = float(np.mean(inc_times)) * 1e6
        steady_recompiles = maintainer.telemetry()["delta_recompiles"]

        full_times = []
        for _ in range(max(reps - 2, 2)):  # same store state: refresh is
            t0 = time.perf_counter()       # idempotent full re-evaluation
            ex.refresh()
            full_times.append(time.perf_counter() - t0)
        full_us = float(np.mean(full_times)) * 1e6
        maintainer.rebind(ex)  # refresh() rebuilt unpadded device state

        speedup = full_us / max(inc_us, 1e-9)
        pct = 100.0 * size / max(n_tt, 1)
        metrics[f"inc_us_b{size}"] = inc_us
        metrics[f"full_us_b{size}"] = full_us
        metrics[f"speedup_b{size}"] = speedup
        metrics[f"batch_pct_b{size}"] = pct
        metrics[f"steady_recompiles_b{size}"] = steady_recompiles
        lines.append(emit(f"maintenance.incremental.b{size}", inc_us,
                          f"batch={pct:.2f}%tt"))
        lines.append(emit(f"maintenance.full_remat.b{size}", full_us,
                          f"speedup={speedup:.1f}x"))
        if pct <= 1.0:
            qualifying_speedups.append((size, speedup))
        assert steady_recompiles == 0, (
            f"steady-state maintenance must not recompile "
            f"(batch {size}: {steady_recompiles})")

    metrics["insert_engine"] = maintainer.telemetry()["insert_engine"]
    if not quick:
        assert qualifying_speedups, "no batch size was <= 1% of the store"
        for size, speedup in qualifying_speedups:
            assert speedup >= 5.0, (
                f"incremental maintenance must be >= 5x full "
                f"re-materialization on small batches "
                f"(batch {size}: {speedup:.1f}x)")

    # ------------------------------------------------------------------
    # serving under update:query ratios (staleness budget = one batch)
    # ------------------------------------------------------------------
    ratios = [(1, 8), (1, 1), (8, 1)] if not quick else [(1, 4), (4, 1)]
    ops = 24 if quick else 60
    upd_size = 32
    names = [q.name for q in wl]
    for n_upd, n_query in ratios:
        srv = session.serve(maintenance=MaintenanceConfig(
            staleness_budget=upd_size, auto_retune=False))
        cycle = n_upd + n_query
        t0 = time.perf_counter()
        for i in range(ops):
            if i % cycle < n_upd:
                srv.submit(inserts=_mixed_batch(
                    rng, ex.store, upd_size, frac_deletes=0.0).inserts)
            else:
                srv.answer_batch([names[i % len(names)]])
        srv.flush()
        wall = time.perf_counter() - t0
        us_per_op = wall / ops * 1e6
        maint_frac = srv.stats.maintenance_seconds / max(wall, 1e-9)
        key = f"ratio_{n_upd}u{n_query}q"
        metrics[f"{key}_us_per_op"] = us_per_op
        metrics[f"{key}_maint_frac"] = maint_frac
        metrics[f"{key}_max_staleness"] = srv.stats.max_staleness_served
        lines.append(emit(f"maintenance.{key}", us_per_op,
                          f"maint_frac={maint_frac:.2f};"
                          f"max_stale={srv.stats.max_staleness_served}"))
        assert srv.stats.max_staleness_served <= upd_size

    # ------------------------------------------------------------------
    # measured costs shift the retune objective
    # ------------------------------------------------------------------
    stats = ex.store.stats
    static_q = quality(session.best, stats, _cfg().search.weights)
    measured_q = quality(session.best, stats, _cfg().search.weights,
                         session.maintenance_costs)
    shift = 100.0 * abs(measured_q.total - static_q.total) \
        / max(abs(static_q.total), 1e-9)
    metrics["measured_views"] = len(session.maintenance_costs)
    metrics["objective_static_total"] = static_q.total
    metrics["objective_measured_total"] = measured_q.total
    metrics["objective_shift_pct"] = shift
    lines.append(emit("maintenance.objective_shift", 0.0,
                      f"static={static_q.total:.0f};"
                      f"measured={measured_q.total:.0f};shift={shift:.1f}%"))
    assert len(session.maintenance_costs) >= 1, \
        "streaming must populate measured maintenance costs"

    # ------------------------------------------------------------------
    # drift-triggered auto-retune
    # ------------------------------------------------------------------
    srv = session.serve(maintenance=MaintenanceConfig(
        drift_window=3, drift_rate_factor=2.0, drift_min_triples=32))
    for _ in range(4):  # baseline rate
        srv.submit(inserts=_mixed_batch(rng, ex.store, 4,
                                        frac_deletes=0.0).inserts)
        srv.answer_batch([names[0]])
    hot_pred = int(np.unique(ex.store.triples[:, 1])[0])
    for _ in range(6):  # 40x rate on one predicate
        burst = _mixed_batch(rng, ex.store, 160, frac_deletes=0.0).inserts
        burst[:, 1] = hot_pred
        srv.submit(inserts=burst)
        srv.answer_batch([names[0]])
    metrics["drift_retunes"] = srv.stats.drift_retunes
    lines.append(emit("maintenance.drift_retunes", 0.0,
                      f"count={srv.stats.drift_retunes}"))
    assert srv.stats.drift_retunes >= 1, \
        "injected drift must trigger an automatic retune"

    write_bench_json("maintenance", metrics)
