"""LM substrate microbenchmark: smoke-scale train/decode step wall time
per architecture (CPU; real perf numbers come from the dry-run roofline)."""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.bench_common import emit, time_us
from repro.configs import get_smoke_config, list_archs
from repro.models.model import build_model
from repro.train.train_step import TrainConfig, init_train_state, make_train_step


def main(lines: list[str]) -> None:
    rng = np.random.default_rng(0)
    for arch in list_archs():
        cfg = get_smoke_config(arch)
        model = build_model(cfg)
        tc = TrainConfig(remat="none")
        state = init_train_state(model, tc, jax.random.key(0))
        step = jax.jit(make_train_step(model, tc))
        toks = jnp.asarray(rng.integers(8, cfg.vocab,
                                        size=(2, 16)).astype(np.int32))
        batch = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}
        if cfg.mrope:
            batch["positions"] = jnp.broadcast_to(
                jnp.arange(16, dtype=jnp.int32)[None, :, None], (2, 16, 3))
        if cfg.encoder is not None:
            batch["enc_frames"] = jnp.asarray(
                rng.normal(size=(2, 8, cfg.encoder.d_input)).astype(np.float32))

        def run(state=state, batch=batch):
            s, m = step(state, batch)
            jax.block_until_ready(m["loss"])

        us = time_us(run, warmup=2, iters=3)
        lines.append(emit(f"lm_step.{arch}.smoke_train", us, "B=2,S=16"))
