"""End-to-end driver: train a ~100M-parameter LM on an RDF corpus served
through the wizard's materialized views.

The full pipeline of DESIGN.md §Arch-applicability: RDFViewS tunes the
storage for the data pipeline's SPARQL workload; training batches are
verbalized from the rewritten queries' answers.

    PYTHONPATH=src python examples/train_lm_on_rdf.py            # quick
    PYTHONPATH=src python examples/train_lm_on_rdf.py --full     # ~100M,
                                                 # a few hundred steps
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.search import SearchConfig
from repro.core.wizard import WizardConfig, tune
from repro.data.pipeline import PipelineConfig, RDFTokenPipeline
from repro.models.config import ModelConfig
from repro.models.model import build_model
from repro.rdf.generator import generate, lubm_workload
from repro.train.optimizer import OptConfig
from repro.train.train_step import (TrainConfig, init_train_state,
                                    make_train_step)

ap = argparse.ArgumentParser()
ap.add_argument("--full", action="store_true",
                help="~100M params, 300 steps (CPU: slow but runnable)")
ap.add_argument("--steps", type=int, default=0)
args = ap.parse_args()

if args.full:
    cfg = ModelConfig(name="rdf-lm-100m", n_layers=12, d_model=768,
                      n_heads=12, n_kv_heads=4, d_ff=3072, vocab=16384)
    steps = args.steps or 300
    seq, batch = 256, 8
else:
    cfg = ModelConfig(name="rdf-lm-10m", n_layers=4, d_model=256,
                      n_heads=8, n_kv_heads=4, d_ff=1024, vocab=4096)
    steps = args.steps or 30
    seq, batch = 128, 4

# --- storage tuning (the paper) -------------------------------------
uni = generate(n_universities=2, seed=0)
rep = tune(uni.store, lubm_workload(uni.dictionary), uni.schema, uni.type_id,
           WizardConfig(search=SearchConfig(strategy="greedy", max_states=300)))
print("wizard:", rep.result.summary())

# --- data pipeline over the tuned store ------------------------------
pipe = iter(RDFTokenPipeline(rep.executor,
                             PipelineConfig(seq_len=seq, batch_size=batch,
                                            vocab=cfg.vocab)))

# --- train ------------------------------------------------------------
model = build_model(cfg)
n_params = cfg.param_count()
print(f"model: {cfg.name} ({n_params/1e6:.1f}M params), "
      f"{steps} steps @ batch={batch} seq={seq}")
tc = TrainConfig(opt=OptConfig(lr=3e-4, warmup_steps=max(steps // 10, 1),
                               total_steps=steps), remat="none")
state = init_train_state(model, tc, jax.random.key(0))
step_fn = jax.jit(make_train_step(model, tc))

t_start = time.perf_counter()
first = last = None
for i in range(1, steps + 1):
    batch_np = next(pipe)
    b = {k: jnp.asarray(v) for k, v in batch_np.items()}
    state, metrics = step_fn(state, b)
    loss = float(metrics["loss"])
    first = first if first is not None else loss
    last = loss
    if i % max(steps // 10, 1) == 0:
        dt = time.perf_counter() - t_start
        print(f"step {i:4d}/{steps} loss {loss:7.4f} "
              f"({batch*seq*i/dt:,.0f} tok/s)")
print(f"\nloss {first:.4f} -> {last:.4f} "
      f"({'improved' if last < first else 'NO IMPROVEMENT'})")
assert last < first, "training must reduce loss"
