"""The demo scenario of §4, headless: play the DBA.

Walks the attendee flow: pick a dataset, tune with different strategies,
adjust the quality-function weights, inspect the search, then issue
queries against TT vs views.

    PYTHONPATH=src python examples/wizard_tour.py
"""
import time

from repro.core.quality import QualityWeights, quality
from repro.core.search import SearchConfig, search
from repro.core.state import initial_state
from repro.core.wizard import WizardConfig, tune
from repro.rdf.generator import generate, lubm_workload

print("=" * 66)
print("RDFViewS storage tuning wizard — demo tour")
print("=" * 66)

# --- choose a dataset (the demo pre-loads LUBM et al.) ---------------
uni = generate(n_universities=1, seed=0, dept_per_univ=2)
workload = lubm_workload(uni.dictionary)
stats = uni.store.stats
print(f"\n[dataset] LUBM-style: {len(uni.store):,} triples, "
      f"{stats.distinct_p} predicates")
print(f"[workload] {len(workload)} conjunctive queries, weights "
      f"{[q.weight for q in workload]}")

# --- quick search vs optimal search (the demo's main knob) ----------
st0 = initial_state(workload)
print(f"\n[initial state] {len(st0.views)} views "
      f"(= materialize the workload; best exec, worst space)")
for strat in ["greedy", "beam", "best_first"]:
    t0 = time.perf_counter()
    res = search(st0, stats, SearchConfig(strategy=strat, max_states=800,
                                          max_seconds=20))
    print(f"  {strat:12s}: {res.summary()}")

# --- steer with the quality weights ----------------------------------
print("\n[weights] space-hungry vs space-frugal configurations:")
for name, w in [("exec-heavy", QualityWeights(1.0, 0.0, 1e-6)),
                ("balanced", QualityWeights(1.0, 0.1, 0.01)),
                ("space-heavy", QualityWeights(1e-6, 0.0, 1.0))]:
    res = search(st0, stats, SearchConfig(strategy="greedy", max_states=500,
                                          weights=w))
    q = res.best_quality
    print(f"  {name:12s}: views={len(res.best.views)} "
          f"exec={q.exec_cost:10.0f} space={q.space_bytes:9.0f}B")

# --- full pipeline with RDFS + verification ---------------------------
print("\n[full tune] greedy + RDFS reformulation:")
rep = tune(uni.store, workload, uni.schema, uni.type_id,
           WizardConfig(search=SearchConfig(strategy="greedy",
                                            max_states=500)))
print(rep.summary())
print("\n[verify] answers from views == direct evaluation:")
for q in workload:
    got = rep.executor.answer_group(q.name)
    want = rep.executor.answer_group_direct(q.name)
    print(f"  {q.name}: {len(got)} answers {'ok' if got == want else 'FAIL'}")
print("\ntour complete.")
