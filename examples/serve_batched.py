"""Batched serving demo: continuous-batching loop over request slots.

    PYTHONPATH=src python examples/serve_batched.py --arch gemma3-12b
(uses the reduced config on CPU; the full config is exercised by the
dry-run decode cells)
"""
import argparse
import time

import jax

from repro.configs import get_smoke_config
from repro.models.model import build_model
from repro.serve.serve_step import BatchedServer, ServeConfig

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="gemma3-12b")
ap.add_argument("--batch", type=int, default=8)
ap.add_argument("--steps", type=int, default=32)
args = ap.parse_args()

cfg = get_smoke_config(args.arch)
model = build_model(cfg)
params = model.init(jax.random.key(0))
server = BatchedServer(model, params, ServeConfig(cache_len=64,
                                                  temperature=0.8),
                       batch=args.batch, max_new=8)
t0 = time.perf_counter()
done = server.run(args.steps, key=jax.random.key(42))
dt = time.perf_counter() - t0
tput = args.batch * args.steps / dt
print(f"arch={cfg.name} batch={args.batch}")
print(f"{args.steps} decode steps in {dt:.2f}s -> {tput:.0f} tok/s")
print(f"completed requests: {len(done)}")
for i, seq in enumerate(done[:5]):
    print(f"  req{i}: {seq}")
