"""Quickstart: tune an RDF store with RDFViewS and query it, end to end.

    PYTHONPATH=src python examples/quickstart.py
"""
import time

from repro.core.quality import QualityWeights, quality
from repro.core.search import SearchConfig
from repro.core.wizard import WizardConfig, tune
from repro.rdf.generator import generate, lubm_workload

# 1) an RDF universe: LUBM-style instance data + RDFS schema
uni = generate(n_universities=2, seed=0)
workload = lubm_workload(uni.dictionary)
print(f"triple table: {len(uni.store):,} triples, "
      f"workload: {len(workload)} weighted conjunctive queries")

# 2) run the wizard: reformulate under RDFS, search view configurations
cfg = WizardConfig(
    search=SearchConfig(strategy="greedy", max_states=500,
                        weights=QualityWeights(w_exec=1.0, w_maint=0.1,
                                               w_space=0.01)))
t0 = time.perf_counter()
report = tune(uni.store, workload, uni.schema, uni.type_id, cfg)
print(f"\nwizard finished in {time.perf_counter() - t0:.2f}s")
print(report.summary())

# 3) answer the workload from the materialized views and compare with
# direct evaluation over the triple table (the demo's finale)
print("\nanswers (views vs direct):")
for q in workload:
    report.executor.answer_group(q.name)  # warm-up (jit compile)
    t0 = time.perf_counter()
    via_views = report.executor.answer_group(q.name)
    t_views = time.perf_counter() - t0
    t0 = time.perf_counter()
    direct = report.executor.answer_group_direct(q.name)
    t_direct = time.perf_counter() - t0
    assert via_views == direct
    print(f"  {q.name}: {len(via_views):5d} answers | views "
          f"{t_views*1e3:7.2f} ms vs direct {t_direct*1e3:7.2f} ms")

# 4) the schema matters: q4 asks for Faculty, which no triple states
# directly — reformulation recovers the entailed answers
q4 = report.executor.answer_group("q4")
print(f"\nq4 (ub:Faculty via RDFS reasoning): {len(q4)} answers "
      f"(0 without the schema)")
