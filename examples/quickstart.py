"""Quickstart: tune an RDF store with a TuningSession and query it.

    PYTHONPATH=src python examples/quickstart.py

Covers the full session lifecycle: cold retune + apply, batched
answers from the materialized views, then workload drift — one query
removed, the tuning warm-started and the view set delta-swapped online.
"""
import time

from repro.api import (QualityWeights, SearchConfig, TuningSession,
                       WizardConfig)
from repro.rdf.generator import generate, lubm_workload

# 1) an RDF universe: LUBM-style instance data + RDFS schema
uni = generate(n_universities=2, seed=0)
workload = lubm_workload(uni.dictionary)
print(f"triple table: {len(uni.store):,} triples, "
      f"workload: {len(workload)} weighted conjunctive queries")

# 2) open a tuning session: RDFS reformulation (rdf:type inferred from
# the schema), then the States Navigator searches view configurations
cfg = WizardConfig(
    search=SearchConfig(strategy="greedy", max_states=500,
                        weights=QualityWeights(w_exec=1.0, w_maint=0.1,
                                               w_space=0.01)))
session = TuningSession(uni.store, workload, schema=uni.schema, cfg=cfg)
t0 = time.perf_counter()
report = session.retune()          # cold: from the paper's initial state
swap = session.apply()             # materialize + compile the chosen views
print(f"\nwizard finished in {time.perf_counter() - t0:.2f}s")
print(report.summary())
print(swap.summary())

# 3) answer the workload from the materialized views and compare with
# direct evaluation over the triple table (the demo's finale)
print("\nanswers (views vs direct):")
for q in workload:
    session.answer(q.name)  # warm-up (jit compile)
    t0 = time.perf_counter()
    via_views = session.answer(q.name)
    t_views = time.perf_counter() - t0
    t0 = time.perf_counter()
    direct = session.executor.answer_group_direct(q.name)
    t_direct = time.perf_counter() - t0
    assert via_views == direct
    print(f"  {q.name}: {len(via_views):5d} answers | views "
          f"{t_views*1e3:7.2f} ms vs direct {t_direct*1e3:7.2f} ms")

# 4) the schema matters: q4 asks for Faculty, which no triple states
# directly — reformulation recovers the entailed answers
q4 = session.answer("q4")
print(f"\nq4 (ub:Faculty via RDFS reasoning): {len(q4)} answers "
      f"(0 without the schema)")

# 5) the workload drifts: drop the heaviest query, retune INCREMENTALLY
# — the navigator warm-starts from the previous best instead of
# re-deriving everything, and apply() only touches the diffed views
removed = session.remove_query("q1")
t0 = time.perf_counter()
retune = session.retune()
swap = session.apply()
dt = time.perf_counter() - t0
print(f"\nafter dropping {removed.name}: {retune.summary()}")
print(f"{swap.summary()} — in {dt:.2f}s, serving uninterrupted")
for q in workload[1:]:
    assert session.answer(q.name) == session.executor.answer_group_direct(q.name)
print("remaining workload still answered exactly")

# 6) the graph never stops changing: stream write batches through the
# staleness-bounded server — small deltas maintain the views
# incrementally (no re-materialization), queries stay at most
# `staleness_budget` pending triples stale, and a bursty write pattern
# trips the drift detector into an automatic retune
import numpy as np

from repro.api import MaintenanceConfig

rng = np.random.default_rng(7)
tt = session.store.triples


def write_batch(size: int, pred: int | None = None) -> np.ndarray:
    rows = tt[rng.choice(len(tt), size)].copy()
    rows[:, 2] = rows[::-1, 2]  # recombine: mostly-novel triples
    if pred is not None:
        rows[:, 1] = pred
    return rows


server = session.serve(maintenance=MaintenanceConfig(
    staleness_budget=64, drift_window=3, drift_rate_factor=2.0,
    drift_min_triples=32))
probe = workload[1].name
for _ in range(4):                      # steady trickle of writes
    server.submit(inserts=write_batch(8))
    server.answer_batch([probe])
hot_pred = int(tt[0, 1])
for _ in range(5):                      # write burst on one predicate
    server.submit(inserts=write_batch(96, pred=hot_pred))
    server.answer_batch([probe])
server.flush()
st = server.stats
print(f"\nstreamed {st.updates_submitted} triples in {st.refreshes} "
      f"maintenance passes ({st.maintenance_seconds*1e3:.0f} ms), "
      f"served at most {st.max_staleness_served} triples stale, "
      f"drift retunes: {st.drift_retunes}")
assert st.max_staleness_served <= 64
assert server.answer_batch([probe])[0] \
    == session.executor.answer_group_direct(probe)
print("views stayed exact under the write stream")
