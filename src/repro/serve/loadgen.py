"""Open-loop traffic generation for the serving frontend.

Open-loop means arrivals do NOT wait for completions: the schedule is a
seeded Poisson process per the offered rate, so when the server falls
behind, the queue grows and admission control has to act — exactly the
regime a closed-loop (request-after-response) generator can never
produce.  Everything is virtual-clock: the schedule is a sorted list of
(time, event) pairs generated up front from one `numpy` PRNG, and
`run_open_loop` replays it through `ServingFrontend.offer /
submit_update`.  Same seed, same config -> bit-identical traffic and
bit-identical frontend decisions.

The update-stream component interleaves triple-delta batches with query
arrivals, so maintenance backpressure (the server draining its update
backlog inside a dispatch) shows up in the measured serving latency.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.errors import require
from repro.serve.frontend import ServingFrontend


@dataclass(frozen=True)
class ClassSpec:
    """One traffic class: its share of arrivals, its priority/SLO, and
    the query-name population it draws from (uniformly)."""

    name: str
    weight: float
    queries: tuple[str, ...]
    priority: int = 0
    slo: float | None = None

    def __post_init__(self):
        require(self.weight > 0, f"class {self.name!r}: weight must be > 0")
        require(len(self.queries) > 0,
                f"class {self.name!r}: needs at least one query")


@dataclass(frozen=True)
class TrafficConfig:
    rate: float                   # offered queries/second (virtual)
    duration: float               # virtual seconds of arrivals
    classes: tuple[ClassSpec, ...]
    seed: int = 0
    update_rate: float = 0.0      # update batches/second (virtual)
    update_size: int = 0          # triples per update batch

    def __post_init__(self):
        require(self.rate > 0, "rate must be > 0")
        require(self.duration > 0, "duration must be > 0")
        require(len(self.classes) > 0, "need at least one traffic class")


@dataclass(frozen=True)
class Arrival:
    t: float
    kind: str                     # "query" | "update"
    cls: str = ""
    name: str = ""


def generate_schedule(cfg: TrafficConfig) -> list[Arrival]:
    """Materialize the full arrival schedule: Poisson query arrivals
    (exponential inter-arrival gaps at `rate`), weighted class choice,
    uniform query choice within the class, plus an independent Poisson
    update stream; merged and time-sorted.  Pure function of `cfg`."""
    rng = np.random.default_rng(cfg.seed)
    out: list[Arrival] = []

    names = [c.name for c in cfg.classes]
    w = np.asarray([c.weight for c in cfg.classes], dtype=np.float64)
    w = w / w.sum()
    by_name = {c.name: c for c in cfg.classes}

    t = 0.0
    while True:
        t += float(rng.exponential(1.0 / cfg.rate))
        if t >= cfg.duration:
            break
        cls = names[int(rng.choice(len(names), p=w))]
        spec = by_name[cls]
        q = spec.queries[int(rng.integers(len(spec.queries)))]
        out.append(Arrival(t=t, kind="query", cls=cls, name=q))

    if cfg.update_rate > 0 and cfg.update_size > 0:
        t = 0.0
        while True:
            t += float(rng.exponential(1.0 / cfg.update_rate))
            if t >= cfg.duration:
                break
            out.append(Arrival(t=t, kind="update"))

    out.sort(key=lambda a: (a.t, a.kind))
    return out


@dataclass
class ClassReport:
    offered: int = 0
    admitted: int = 0
    shed: int = 0
    downgraded: int = 0
    p50: float = 0.0
    p99: float = 0.0
    mean: float = 0.0
    throughput: float = 0.0       # completions / virtual second
    slo: float | None = None
    slo_met: bool | None = None   # None when the class has no SLO

    def as_dict(self) -> dict:
        return dict(self.__dict__)


@dataclass
class TrafficReport:
    duration: float               # virtual seconds incl. drain
    offered_rate: float
    completed: int = 0
    shed_rate: float = 0.0
    throughput: float = 0.0
    batches: int = 0
    batch_occupancy: float = 0.0
    max_queue_depth: int = 0
    per_class: dict = field(default_factory=dict)  # name -> ClassReport

    def as_dict(self) -> dict:
        d = dict(self.__dict__)
        d["per_class"] = {k: v.as_dict() for k, v in self.per_class.items()}
        return d


def run_open_loop(frontend: ServingFrontend, cfg: TrafficConfig,
                  update_fn=None) -> TrafficReport:
    """Replay `cfg`'s schedule through the frontend, flush, and report.

    `update_fn(rng) -> (inserts, deletes)` supplies each update batch's
    triples (seeded off `cfg.seed + 1` so query arrivals are unchanged
    whether or not updates flow).  Without it, update events are
    skipped."""
    schedule = generate_schedule(cfg)
    upd_rng = np.random.default_rng(cfg.seed + 1)
    for a in schedule:
        if a.kind == "query":
            frontend.offer(a.name, a.cls, t=a.t)
        elif update_fn is not None:
            ins, dels = update_fn(upd_rng)
            frontend.submit_update(inserts=ins, deletes=dels, t=a.t)
    end = frontend.flush()
    return summarize(frontend, cfg, end)


def summarize(frontend: ServingFrontend, cfg: TrafficConfig,
              end_time: float) -> TrafficReport:
    st = frontend.stats
    dur = max(end_time, cfg.duration)
    rep = TrafficReport(
        duration=dur, offered_rate=cfg.rate,
        completed=st.completed,
        shed_rate=st.shed / st.offered if st.offered else 0.0,
        throughput=st.completed / dur if dur > 0 else 0.0,
        batches=st.batches, batch_occupancy=st.batch_occupancy,
        max_queue_depth=st.max_queue_depth)
    for spec in cfg.classes:
        rec = st.latency.get(spec.name)
        cr = ClassReport(
            offered=st.offered_by_class.get(spec.name, 0),
            shed=st.shed_by_class.get(spec.name, 0),
            downgraded=st.downgraded_by_class.get(spec.name, 0),
            slo=spec.slo)
        cr.admitted = cr.offered - cr.shed
        if rec is not None and rec.count:
            cr.p50 = rec.percentile(50)
            cr.p99 = rec.percentile(99)
            cr.mean = rec.mean
            cr.throughput = rec.count / dur if dur > 0 else 0.0
            if spec.slo is not None:
                cr.slo_met = cr.p99 <= spec.slo
        elif spec.slo is not None:
            # nothing completed in this class; SLO trivially unmet
            # only if requests were offered and all shed/downgraded
            cr.slo_met = cr.offered == 0
        rep.per_class[spec.name] = cr
    return rep
