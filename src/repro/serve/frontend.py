"""Async serving frontend: bounded queue, micro-batches, admission control.

`ServingFrontend` sits in front of a batched query server (a
`repro.serve.query_server.QueryServer`, a `repro.serve.sharded.
ShardedBackend`, or anything exposing `answer_batch(names)`) and turns
per-request traffic into the micro-batches the fused device program is
built for:

  * requests enter a BOUNDED queue (`queue_cap`); the queue never grows
    without limit — when full, admission control decides who is shed;
  * a micro-batch dispatches when the queue reaches `max_batch` or the
    oldest admitted request has waited `batching_window`, whichever
    first, and the server is free (one batch in flight at a time — the
    backing executor answers a whole batch in one device call);
  * dispatch order is priority-major (higher `QueryClass.priority`
    first, FIFO within a class), so the top class rides the front of
    every batch;
  * admission control (`admission="shed"|"downgrade"`) protects
    per-class latency SLOs: a request whose estimated completion would
    breach its class budget is shed at the door — or downgraded to the
    best-effort class — instead of poisoning the queue for everyone
    behind it.  With `admission="none"` the frontend only enforces the
    hard queue bound.

Everything runs on a VIRTUAL CLOCK: arrivals carry virtual timestamps,
batch service costs virtual seconds from a pluggable service model, and
no code path reads wall time unless you opt into `MeasuredServiceModel`
(benchmarks only).  Tests and the load generator replay bit-identically
under a fixed seed.

Telemetry — queue depth, batch occupancy, shed/downgrade counters and
per-class latency recorders — lives in `FrontendStats`, is mirrored
into the backing server's `ServeStats.frontend`, and is surfaced by
`readiness()` alongside the server's own health probe.
"""
from __future__ import annotations

import bisect
from dataclasses import dataclass, field

from repro.errors import InvariantViolation, require

BEST_EFFORT = "best_effort"
_EPS = 1e-12

# log2-spaced latency histogram bucket edges (virtual seconds): 0.1 ms
# up to ~7 min, plus an overflow bucket.  Fixed size — telemetry never
# grows with traffic.
HIST_EDGES: tuple[float, ...] = tuple(1e-4 * (2.0 ** i) for i in range(22))


class VirtualClock:
    """Deterministic monotone clock in virtual seconds."""

    def __init__(self, start: float = 0.0):
        self._now = float(start)

    def now(self) -> float:
        return self._now

    def advance_to(self, t: float) -> float:
        if t < self._now - _EPS:
            raise InvariantViolation(
                f"virtual clock cannot run backwards: {t} < {self._now}")
        self._now = max(self._now, float(t))
        return self._now

    def advance(self, dt: float) -> float:
        return self.advance_to(self._now + float(dt))


@dataclass(frozen=True)
class QueryClass:
    """One traffic class: a priority tier and an optional latency SLO
    (virtual seconds, arrival to completion)."""

    name: str
    priority: int = 0           # higher dispatches first
    slo: float | None = None    # None: best effort, never shed on SLO

    def __post_init__(self):
        require(bool(self.name), "query class needs a name")
        require(self.slo is None or self.slo > 0, "slo must be positive")


@dataclass(frozen=True)
class FrontendConfig:
    queue_cap: int = 64           # hard bound on admitted-but-undispatched
    batching_window: float = 0.005  # max wait before a partial batch goes
    max_batch: int = 16           # requests per dispatch
    admission: str = "shed"       # "shed" | "downgrade" | "none"
    slo_margin: float = 1.0       # admit while est. latency <= margin*slo
    priority_dispatch: bool = True  # False: plain FIFO (baseline mode)
    latency_reservoir: int = 65536  # exact-quantile samples kept per class

    def __post_init__(self):
        require(self.queue_cap >= 1, "queue_cap must be >= 1")
        require(self.max_batch >= 1, "max_batch must be >= 1")
        require(self.batching_window >= 0.0, "batching_window must be >= 0")
        require(self.admission in ("shed", "downgrade", "none"),
                f"admission must be shed|downgrade|none, "
                f"got {self.admission!r}")


@dataclass
class Request:
    rid: int
    name: str                   # workload query name
    cls: str                    # serving class (after any downgrade)
    orig_cls: str               # class at the door
    priority: int
    slo: float | None
    arrival: float
    downgraded: bool = False
    dispatch: float | None = None
    finish: float | None = None


class LatencyRecorder:
    """Per-class latency telemetry: a bounded sample reservoir (exact
    quantiles while under `cap`; overflow counted, never grown) plus a
    fixed log-bucketed histogram."""

    def __init__(self, cap: int = 65536):
        self.cap = cap
        self.samples: list[float] = []
        self.overflowed = 0         # samples beyond the reservoir cap
        self.hist = [0] * (len(HIST_EDGES) + 1)
        self.count = 0
        self.total = 0.0
        self.worst = 0.0

    def record(self, latency: float) -> None:
        self.count += 1
        self.total += latency
        self.worst = max(self.worst, latency)
        self.hist[bisect.bisect_right(HIST_EDGES, latency)] += 1
        if len(self.samples) < self.cap:
            self.samples.append(latency)
        else:
            self.overflowed += 1

    def percentile(self, q: float) -> float:
        """Exact sample quantile (nearest-rank) over the reservoir."""
        if not self.samples:
            return 0.0
        s = sorted(self.samples)
        rank = min(len(s) - 1, max(0, int(round(q / 100.0 * len(s))) - 1))
        return s[rank]

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def summary(self) -> dict:
        return {"count": self.count, "mean": self.mean,
                "p50": self.percentile(50), "p99": self.percentile(99),
                "max": self.worst, "hist": list(self.hist),
                "overflowed": self.overflowed}


# ----------------------------------------------------------------------
# service-time models
# ----------------------------------------------------------------------
class FixedServiceModel:
    """Deterministic virtual batch service time: affine in batch size
    plus a per-maintained-triple surcharge (so an update backlog drained
    inside a dispatch stretches that batch's service — maintenance
    backpressure shows up in serving latency)."""

    def __init__(self, batch_base: float = 0.002,
                 per_request: float = 0.0005,
                 per_maint_triple: float = 0.0):
        self.batch_base = batch_base
        self.per_request = per_request
        self.per_maint_triple = per_maint_triple

    def __call__(self, names, wall_seconds: float,
                 maint_triples: int) -> float:
        return (self.batch_base + self.per_request * len(names)
                + self.per_maint_triple * maint_triples)

    def estimate(self, n: int) -> float:
        """Prior service estimate for an n-request batch."""
        return self.batch_base + self.per_request * n


class MeasuredServiceModel:
    """Charge the measured wall time of the real dispatch to the virtual
    clock (benchmark realism).  NOT for tests: wall time is
    nondeterministic by nature."""

    def __init__(self, scale: float = 1.0):
        self.scale = scale

    def __call__(self, names, wall_seconds: float,
                 maint_triples: int) -> float:
        return wall_seconds * self.scale

    def estimate(self, n: int) -> float | None:
        return None             # no prior; the EWMA learns from batches


@dataclass
class FrontendStats:
    offered: int = 0
    admitted: int = 0
    shed: int = 0               # at the door + evicted from a full queue
    evicted: int = 0            # subset of shed: displaced by priority
    downgraded: int = 0
    completed: int = 0
    batches: int = 0
    batch_occupancy_sum: int = 0
    queue_depth: int = 0        # right now
    max_queue_depth: int = 0
    updates_submitted: int = 0
    offered_by_class: dict = field(default_factory=dict)
    shed_by_class: dict = field(default_factory=dict)
    downgraded_by_class: dict = field(default_factory=dict)
    latency: dict = field(default_factory=dict)  # cls -> LatencyRecorder

    @property
    def batch_occupancy(self) -> float:
        return self.batch_occupancy_sum / self.batches if self.batches else 0.0

    def summary(self) -> dict:
        return {
            "offered": self.offered, "admitted": self.admitted,
            "shed": self.shed, "evicted": self.evicted,
            "downgraded": self.downgraded, "completed": self.completed,
            "batches": self.batches, "batch_occupancy": self.batch_occupancy,
            "queue_depth": self.queue_depth,
            "max_queue_depth": self.max_queue_depth,
            "shed_by_class": dict(self.shed_by_class),
            "downgraded_by_class": dict(self.downgraded_by_class),
            "latency": {c: r.summary() for c, r in self.latency.items()},
        }


class ServingFrontend:
    """Virtual-clock micro-batching frontend over a batched server.

    MAX_BATCH_LOG pins how many (dispatch_time, size) entries the batch
    log keeps — telemetry stays bounded no matter how long the frontend
    runs.
    """

    MAX_BATCH_LOG = 1024

    def __init__(self, server, classes, cfg: FrontendConfig | None = None,
                 clock: VirtualClock | None = None, service_model=None):
        self.server = server
        self.cfg = cfg or FrontendConfig()
        self.clock = clock or VirtualClock()
        self.service_model = service_model or FixedServiceModel()
        self.classes: dict[str, QueryClass] = {}
        for c in classes:
            require(c.name not in self.classes,
                    f"duplicate query class {c.name!r}")
            self.classes[c.name] = c
        require(bool(self.classes), "frontend needs at least one class")
        if self.cfg.admission == "downgrade" and BEST_EFFORT not in self.classes:
            floor = min(c.priority for c in self.classes.values())
            self.classes[BEST_EFFORT] = QueryClass(
                BEST_EFFORT, priority=floor - 1, slo=None)
        self.stats = FrontendStats()
        for name in self.classes:
            self.stats.latency[name] = LatencyRecorder(
                self.cfg.latency_reservoir)
        self._queue: list[Request] = []     # bounded: len() < cfg.queue_cap
        self._inflight: list[Request] | None = None
        self._busy_until = self.clock.now()
        self._service_ewma: float | None = None
        self._rid = 0
        self.batch_log: list[tuple[float, int]] = []

    # ------------------------------------------------------------------
    # request admission
    # ------------------------------------------------------------------
    def offer(self, name: str, cls: str | None = None,
              t: float | None = None) -> bool:
        """Offer one request at virtual time `t` (default: now).
        Returns True when admitted (possibly downgraded), False when
        shed by admission control or the queue bound."""
        if t is not None:
            self.advance_to(t)
        else:
            self._pump()
        if cls is None:
            if len(self.classes) != 1:
                raise ValueError("cls is required with multiple classes")
            cls = next(iter(self.classes))
        qc = self.classes.get(cls)
        if qc is None:
            raise KeyError(f"unknown query class {cls!r}")
        self.stats.offered += 1
        self.stats.offered_by_class[cls] = \
            self.stats.offered_by_class.get(cls, 0) + 1
        r = Request(rid=self._rid, name=name, cls=cls, orig_cls=cls,
                    priority=qc.priority, slo=qc.slo,
                    arrival=self.clock.now())
        self._rid += 1

        # SLO admission: would this request blow its own budget?
        if (self.cfg.admission != "none" and r.slo is not None
                and self._est_latency(r) > self.cfg.slo_margin * r.slo):
            if self.cfg.admission == "downgrade":
                be = self.classes[BEST_EFFORT]
                r.cls, r.priority, r.slo = be.name, be.priority, be.slo
                r.downgraded = True
                self.stats.downgraded += 1
                self.stats.downgraded_by_class[cls] = \
                    self.stats.downgraded_by_class.get(cls, 0) + 1
            else:
                self._shed(r)
                return False

        # hard queue bound: shed the incoming request, or — under
        # admission control — displace a strictly lower-priority one
        if len(self._queue) >= self.cfg.queue_cap:
            victim = None
            if self.cfg.admission != "none" and self.cfg.priority_dispatch:
                low = min(self._queue, key=lambda q: (q.priority, -q.arrival))
                if low.priority < r.priority:
                    victim = low
            if victim is None:
                self._shed(r)
                return False
            self._queue.remove(victim)
            self.stats.evicted += 1
            self._shed(victim, already_admitted=True)
        self._queue.append(r)
        self.stats.admitted += 1
        self.stats.queue_depth = len(self._queue)
        self.stats.max_queue_depth = max(self.stats.max_queue_depth,
                                         len(self._queue))
        self._pump()
        return True

    def _shed(self, r: Request, already_admitted: bool = False) -> None:
        self.stats.shed += 1
        self.stats.shed_by_class[r.orig_cls] = \
            self.stats.shed_by_class.get(r.orig_cls, 0) + 1
        if already_admitted:
            self.stats.admitted -= 1
        self.stats.queue_depth = len(self._queue)

    # ------------------------------------------------------------------
    # latency estimation (admission control's crystal ball)
    # ------------------------------------------------------------------
    def _service_est(self) -> float:
        if self._service_ewma is not None:
            return self._service_ewma
        prior = None
        est = getattr(self.service_model, "estimate", None)
        if est is not None:
            prior = est(self.cfg.max_batch)
        return prior if prior is not None else self.cfg.batching_window

    def _est_latency(self, r: Request) -> float:
        """Estimated arrival-to-completion latency for an incoming
        request: remaining in-flight service, plus one batch service per
        `max_batch` queued requests that would dispatch before or with
        it (only same-or-higher priority when priority dispatch is on),
        plus the batching window it may spend waiting to fill."""
        s = self._service_est()
        if self.cfg.priority_dispatch:
            ahead = sum(1 for q in self._queue if q.priority >= r.priority)
        else:
            ahead = len(self._queue)
        batches = ahead // self.cfg.max_batch + 1  # incl. its own batch
        busy = max(self._busy_until - self.clock.now(), 0.0)
        return busy + batches * s + self.cfg.batching_window

    # ------------------------------------------------------------------
    # virtual-time machinery
    # ------------------------------------------------------------------
    def _next_event(self) -> float | None:
        if self._inflight is not None:
            return self._busy_until
        if self._queue:
            if len(self._queue) >= min(self.cfg.max_batch,
                                       self.cfg.queue_cap):
                return self.clock.now()
            oldest = min(q.arrival for q in self._queue)
            return oldest + self.cfg.batching_window
        return None

    def _on_event(self) -> None:
        now = self.clock.now()
        if self._inflight is not None and now >= self._busy_until - _EPS:
            self._complete_inflight()
        if self._inflight is None and self._queue:
            # a batch-full OR cap-full queue dispatches immediately (the
            # cap means it cannot grow, so waiting out the window would
            # only add latency) — must mirror _next_event's readiness
            # condition exactly or the event pump spins
            full = len(self._queue) >= min(self.cfg.max_batch,
                                           self.cfg.queue_cap)
            oldest = min(q.arrival for q in self._queue)
            if full or now - oldest >= self.cfg.batching_window - _EPS:
                self._dispatch()

    def _pump(self) -> None:
        """Process every event due at or before the current time."""
        while True:
            ev = self._next_event()
            if ev is None or ev > self.clock.now() + _EPS:
                return
            before = (len(self._queue), self.stats.batches,
                      self.stats.completed)
            self._on_event()
            if before == (len(self._queue), self.stats.batches,
                          self.stats.completed):
                raise InvariantViolation(
                    "frontend event pump made no progress — "
                    "_next_event/_on_event readiness conditions diverged")

    def advance_to(self, t: float) -> None:
        """Advance virtual time to `t`, firing dispatches/completions in
        order along the way."""
        while True:
            ev = self._next_event()
            if ev is None or ev > t + _EPS:
                break
            self.clock.advance_to(max(ev, self.clock.now()))
            self._on_event()
        self.clock.advance_to(t)

    def flush(self) -> float:
        """Drain: run virtual time forward until the queue is empty and
        nothing is in flight.  Returns the final virtual time."""
        while self._queue or self._inflight is not None:
            ev = self._next_event()
            if ev is None:
                break
            self.clock.advance_to(max(ev, self.clock.now()))
            self._on_event()
        return self.clock.now()

    # ------------------------------------------------------------------
    # dispatch
    # ------------------------------------------------------------------
    def _take_batch(self) -> list[Request]:
        if self.cfg.priority_dispatch:
            order = sorted(self._queue,
                           key=lambda q: (-q.priority, q.arrival, q.rid))
        else:
            order = sorted(self._queue, key=lambda q: (q.arrival, q.rid))
        batch = order[: self.cfg.max_batch]
        taken = {q.rid for q in batch}
        self._queue = [q for q in self._queue if q.rid not in taken]
        return batch

    def _dispatch(self) -> None:
        import time as _time

        now = self.clock.now()
        batch = self._take_batch()
        names = [r.name for r in batch]
        server_stats = getattr(self.server, "stats", None)
        maint_before = getattr(server_stats, "updates_applied", 0)
        t0 = _time.perf_counter()
        self.server.answer_batch(names)
        wall = _time.perf_counter() - t0
        maint = getattr(server_stats, "updates_applied", 0) - maint_before
        service = float(self.service_model(names, wall, maint))
        require(service >= 0.0, "service model returned negative time")
        self._service_ewma = (service if self._service_ewma is None
                              else 0.7 * self._service_ewma + 0.3 * service)
        for r in batch:
            r.dispatch = now
            r.finish = now + service
        self._inflight = batch
        self._busy_until = now + service
        self.stats.batches += 1
        self.stats.batch_occupancy_sum += len(batch)
        self.stats.queue_depth = len(self._queue)
        self.batch_log.append((now, len(batch)))
        if len(self.batch_log) > self.MAX_BATCH_LOG:
            del self.batch_log[:-self.MAX_BATCH_LOG]

    def _complete_inflight(self) -> None:
        for r in self._inflight:
            self.stats.completed += 1
            self.stats.latency[r.cls].record(r.finish - r.arrival)
        self._inflight = None
        self._sync()

    # ------------------------------------------------------------------
    # update stream passthrough (streaming maintenance backpressure)
    # ------------------------------------------------------------------
    def submit_update(self, inserts=None, deletes=None,
                      t: float | None = None) -> None:
        """Enqueue one triple-delta batch on the backing server at
        virtual time `t`; the backlog drains inside later dispatches
        under the server's staleness budget, stretching their service
        time (see `FixedServiceModel.per_maint_triple`)."""
        if t is not None:
            self.advance_to(t)
        self.server.submit(inserts=inserts, deletes=deletes)
        self.stats.updates_submitted += 1

    # ------------------------------------------------------------------
    # telemetry
    # ------------------------------------------------------------------
    def _sync(self) -> None:
        server_stats = getattr(self.server, "stats", None)
        if server_stats is not None and hasattr(server_stats, "frontend"):
            server_stats.frontend = self.stats.summary()

    def readiness(self) -> dict:
        """Frontend readiness: the server's own probe plus queue state."""
        base = {}
        probe = getattr(self.server, "readiness", None)
        if probe is not None:
            base = dict(probe())
        base.update({
            "queue_depth": len(self._queue),
            "inflight": 0 if self._inflight is None else len(self._inflight),
            "shed": self.stats.shed,
            "downgraded": self.stats.downgraded,
            "virtual_time": self.clock.now(),
        })
        return base
