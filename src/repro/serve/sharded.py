"""Sharded serving backend: the workload answered across a device mesh.

`ShardedBackend` adapts a tuned `QueryExecutor` to multi-device SPMD
serving: the triple table is hash(subject)-partitioned and every view
extent hash-partitioned on its first head column via
`repro.query.distributed` (`shard_store_by_subject`, `shard_prel_rows`),
and each workload rewriting is compiled once, lazily, into a shard_map
program (`build_distributed_executor`) with co-partition elision where
the plan's join keys line up with the partitioning.

It speaks the same protocol as `QueryServer` — `answer_batch(names)`,
`stats: ServeStats`, `readiness()` — so `ServingFrontend` fronts either
interchangeably, and it reuses the `ServingSupervisor` fault vocabulary
with PER-SHARD granularity:

  * every batch starts with an integrity probe comparing each device
    shard's live row count against its host mirror (`TripleStore` per
    shard, kept from `shard_store_by_subject(with_shards=True)`);
  * a corrupt/lost shard maps to a per-shard ladder tier
    (`observe_shard`) — the batch is answered EXACTLY by the host
    reference engine over the full mirror, and the supervisor `rollup`
    reports DEGRADED while a quorum of shards still serves, NOT
    whole-server DOWN;
  * restored shards flip the rollup back to HEALTHY on the next batch.

`corrupt_shard` / `restore_shard` are deterministic test hooks that
damage exactly one shard's device slabs in place.
"""
from __future__ import annotations

import numpy as np

from repro.distributed.fault import RetryPolicy, ServingSupervisor
from repro.errors import ServiceUnavailable
from repro.query import distributed as D
from repro.serve.query_server import ServeStats

_SENTINEL = 2**31 - 1


class ShardedBackend:
    def __init__(self, executor, mesh=None, axis: str = "data",
                 policy: RetryPolicy | None = None):
        import jax  # heavy import deferred to backend construction

        self._jax = jax
        self.executor = executor
        if mesh is None:
            from repro.launch.mesh import make_host_mesh

            mesh = make_host_mesh(axis=axis)
        self.mesh = mesh
        self.axis = axis
        self.ndev = int(mesh.shape[axis])
        self.supervisor = ServingSupervisor(policy or RetryPolicy())
        self.stats = ServeStats()
        self._fns: dict[str, object] = {}     # member -> jitted SPMD fn

        # device TT shards + host per-shard mirrors (probe targets and
        # the exact fallback when a shard degrades)
        self._tt_host = None
        self._shards = None
        self._load()

    # ------------------------------------------------------------------
    # device state
    # ------------------------------------------------------------------
    def _load(self) -> None:
        tt, shards = D.shard_store_by_subject(
            self.executor.store, self.mesh, self.axis, with_shards=True)
        self._shards = shards
        # keep the stacked host arrays so shard-level corruption hooks
        # and re-uploads can surgically touch one shard's slab
        cap = tt["spo"].shape[0] // self.ndev
        self._cap = cap
        self._tt_host = {k: np.asarray(v).reshape(self.ndev, cap, 3).copy()
                         for k, v in tt.items()}
        self._tt = tt
        self._views = {}
        self._partition_cols: dict[int, str] = {}
        for vid, rel in self.executor.extents.items():
            width = max(len(rel.cols), 1)
            self._views[vid] = D.shard_prel_rows(
                rel.rows, 0, self.mesh, self.axis, width=width)
            if len(rel.cols):
                self._partition_cols[vid] = rel.cols[0]
        self._fns.clear()

    def _upload_tt(self) -> None:
        from jax.sharding import NamedSharding, PartitionSpec as P

        sharding = NamedSharding(self.mesh, P(self.axis))
        self._tt = {
            k: self._jax.device_put(
                v.reshape(self.ndev * self._cap, 3), sharding)
            for k, v in self._tt_host.items()}

    def _fn(self, member: str):
        fn = self._fns.get(member)
        if fn is None:
            plan = self.executor.state.rewritings[member]
            fn = self._jax.jit(D.build_distributed_executor(
                plan, self.executor.store.stats, self.executor.infos,
                self.mesh, self.axis,
                partition_cols=self._partition_cols))
            self._fns[member] = fn
        return fn

    # ------------------------------------------------------------------
    # per-shard integrity probe + fault hooks
    # ------------------------------------------------------------------
    def _probe(self) -> set[int]:
        """Shards whose device slab disagrees with the host mirror.
        Live rows are non-sentinel in the spo index; each shard must
        hold exactly its mirror's triple count."""
        spo = np.asarray(self._tt["spo"]).reshape(self.ndev, self._cap, 3)
        bad = set()
        for d in range(self.ndev):
            live = int((spo[d, :, 0] != _SENTINEL).sum())
            if live != len(self._shards[d]):
                bad.add(d)
        return bad

    def corrupt_shard(self, d: int) -> None:
        """Deterministically damage shard `d`'s device slabs (every
        index order) — the probe sees a row-count mismatch next batch."""
        for name in self._tt_host:
            self._tt_host[name][d] = 0
        self._upload_tt()

    def restore_shard(self, d: int) -> None:
        """Undo `corrupt_shard`: rebuild shard `d`'s slabs from the host
        mirror and re-upload."""
        for name in self._tt_host:
            slab = np.full((self._cap, 3), _SENTINEL, dtype=np.int32)
            idx = self._shards[d].index(name)
            slab[: len(idx)] = idx
            self._tt_host[name][d] = slab
        self._upload_tt()

    # ------------------------------------------------------------------
    # serving
    # ------------------------------------------------------------------
    def _answer_device(self, names: list[str]) -> dict[str, set]:
        answers: dict[str, set] = {}
        for name in names:
            out: set = set()
            for member in self.executor.groups[name]:
                if member in self.executor._oracle_names:
                    # cartesian rewritings never lower to the SPMD
                    # engine; the host reference engine is exact
                    out |= self.executor.answer_direct(member)
                    continue
                rel = self._fn(member)(self._tt, self._views)
                if bool(np.asarray(rel.overflow).any()):
                    raise RuntimeError(f"{member}: sharded capacity overflow")
                rows = D.gather_result(rel)
                out |= {tuple(r) for r in rows.tolist()}
            answers[name] = out
        return answers

    def answer_batch(self, names: list[str]) -> list[set | None]:
        """Answer a batch across the mesh.  All shards healthy: SPMD
        device programs per rewriting.  Any shard degraded (or a device
        failure mid-batch): exact host fallback over the full mirror,
        per-shard tiers recorded, health rolls up to DEGRADED while a
        quorum holds — never DOWN for one lost shard."""
        self.supervisor.begin_batch()
        bad = self._probe()
        known = [n for n in names if n in self.executor.groups]
        tier_by_shard = {d: (2 if d in bad else 0) for d in range(self.ndev)}
        device_ok = not bad
        answers: dict[str, set] = {}
        if device_ok:
            try:
                answers = self._answer_device(known)
            except Exception as exc:
                device_ok = False
                self.stats.fused_failures += 1
                self.stats.faults.append(f"sharded_device: {exc}")
                del self.stats.faults[:-64]
                tier_by_shard = {d: 1 for d in range(self.ndev)}
        if not device_ok:
            try:
                answers = {n: self.executor.answer_group_direct(n)
                           for n in known}
            except Exception as exc:
                for d in range(self.ndev):
                    self.supervisor.observe_shard(d, None)
                self.supervisor.rollup(reason=f"host fallback failed: {exc}")
                self._finish(names, known, tier=None)
                raise ServiceUnavailable(
                    f"sharded device path and host fallback failed: {exc}"
                ) from exc
        for d, t in tier_by_shard.items():
            self.supervisor.observe_shard(d, t)
        self.supervisor.rollup()
        out: list[set | None] = []
        for n in names:
            if n in self.executor.groups:
                out.append(answers[n])
            else:
                self.stats.unknown += 1
                out.append(None)
        if not device_ok:
            self.stats.degraded_answers += len(known)
        self._finish(names, known, tier=0 if device_ok else 2)
        return out

    def _finish(self, names, known, tier) -> None:
        self.stats.requests += len(names)
        self.stats.batches += 1
        self.stats.served_tier = tier if tier is not None else -1
        self.stats.health = self.supervisor.health
        self.stats.last_batch = {"tier": tier,
                                 "degraded": tier not in (0, None),
                                 "stale": False}

    def answer(self, name: str) -> set | None:
        return self.answer_batch([name])[0]

    # ------------------------------------------------------------------
    def readiness(self) -> dict:
        return {
            "ready": self.supervisor.ready(),
            "health": self.supervisor.health,
            "shards": dict(self.supervisor.shard_health),
            "quorum": self.supervisor.quorum(),
            "ndev": self.ndev,
            "batches": self.supervisor.batches,
        }

    # no update stream: sharded serving is static-store for now; the
    # frontend surfaces this as a loud error instead of silent drops
    def submit(self, inserts=None, deletes=None) -> None:
        raise RuntimeError(
            "ShardedBackend has no update stream; serve maintenance "
            "through QueryServer (maintenance=) instead")
