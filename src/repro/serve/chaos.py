"""Deterministic fault injection for the serving core.

Every failure boundary of the serving path carries a hook point that
calls `FaultInjector.fire(site)`; an armed site raises an
`InjectedFault` (or `InjectedTimeout`) at an exact, reproducible call
index — no randomness, no wall clock — so the chaos suite and
`benchmarks/bench_fault.py` replay identically everywhere.

Sites and where they fire:

  device_call        WorkloadExecutor.run — the fused device program
  capacity_overflow  WorkloadExecutor.run — an overflow storm that
                     exhausts the adaptive-recompile budget
  compile            WorkloadExecutor program (re)construction — the
                     first compile of a fresh/hot-swapped program
  maintenance_apply  ViewMaintainer.apply — a streaming delta pass
  retune             TuningSession.retune — the States Navigator
  apply              TuningSession.apply — the delta view swap
  per_query_call     QueryServer's per-query fallback tier
  ref_engine_call    QueryServer's host reference-engine tier

Armed specs fire `count` times starting after `after` clean calls at
that site, then clear themselves — "the fault clears" is part of the
schedule, which is what lets tests assert recovery to HEALTHY.

`corrupt_extent` is the one fault that mutates state instead of
raising: it breaks the host-mirror / device-buffer row alignment of a
materialized view extent (the invariant streaming maintenance
preserves), which the server's integrity probe must catch before the
fused path can serve a silently wrong answer.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

SITES = ("device_call", "capacity_overflow", "compile", "maintenance_apply",
         "retune", "apply", "per_query_call", "ref_engine_call")


class InjectedFault(RuntimeError):
    """A fault raised by the chaos harness (never by real code)."""

    def __init__(self, site: str, message: str = ""):
        self.site = site
        super().__init__(message or f"injected fault at {site!r}")


class InjectedTimeout(InjectedFault):
    """An injected call-timeout (the call never returned in budget)."""

    def __init__(self, site: str):
        super().__init__(site, f"injected timeout at {site!r}")


@dataclass
class FaultSpec:
    """One armed fault: raise at calls (after, after+count] of `site`."""

    site: str
    after: int = 0            # clean calls to let through first
    count: int | None = 1     # raises before auto-clearing (None: sticky)
    kind: str = "error"       # "error" | "timeout"
    calls: int = 0            # calls seen since arming
    fired: int = 0            # raises so far

    def __post_init__(self):
        if self.site not in SITES:
            raise ValueError(f"unknown fault site {self.site!r}; "
                             f"one of {SITES}")
        if self.kind not in ("error", "timeout"):
            raise ValueError(f"kind must be error|timeout, got {self.kind!r}")
        if self.after < 0 or (self.count is not None and self.count < 1):
            raise ValueError("after must be >= 0 and count >= 1")


@dataclass
class FaultInjector:
    """The registry the hook points consult.  Duck-typed: everything
    below `serve/` only needs `.fire(site)`, so the query and
    maintenance layers never import this module."""

    MAX_LOG = 4096  # injection log cap: chaos soaks run for many batches

    specs: dict[str, FaultSpec] = field(default_factory=dict)
    calls: dict[str, int] = field(default_factory=dict)   # per-site, lifetime
    injected: int = 0
    log: list[tuple[str, int]] = field(default_factory=list)

    # ------------------------------------------------------------------
    def arm(self, site: str, after: int = 0, count: int | None = 1,
            kind: str = "error") -> FaultSpec:
        """Arm `site`; replaces any previous spec for it."""
        spec = FaultSpec(site=site, after=after, count=count, kind=kind)
        self.specs[site] = spec
        return spec

    def clear(self, site: str | None = None) -> None:
        if site is None:
            self.specs.clear()
        else:
            self.specs.pop(site, None)

    def armed(self, site: str) -> bool:
        return site in self.specs

    # ------------------------------------------------------------------
    def fire(self, site: str) -> None:
        """Hook point: raise iff `site` is armed and scheduled."""
        self.calls[site] = self.calls.get(site, 0) + 1
        spec = self.specs.get(site)
        if spec is None:
            return
        spec.calls += 1
        if spec.calls <= spec.after:
            return
        if spec.count is not None and spec.fired >= spec.count:
            # exhausted (kept armed only when sticky)
            self.specs.pop(site, None)
            return
        spec.fired += 1
        self.injected += 1
        self.log.append((site, self.calls[site]))
        del self.log[:-self.MAX_LOG]
        if spec.count is not None and spec.fired >= spec.count:
            self.specs.pop(site, None)
        if spec.kind == "timeout":
            raise InjectedTimeout(site)
        raise InjectedFault(site)

    # ------------------------------------------------------------------
    def corrupt_extent(self, executor, vid: int | None = None) -> int:
        """Break host/device row alignment of one materialized extent.

        Truncates the host mirror by one row (or plants a phantom row in
        an empty extent), so `len(extents[vid].rows) != device n` — the
        exact invariant `ViewMaintainer.check_alignment` guards and the
        serving integrity probe checks before trusting the fused path.
        Returns the corrupted view id.
        """
        from repro.query import ref_engine as R

        vids = sorted(executor.extents)
        if not vids:
            raise ValueError("executor has no materialized extents")
        if vid is None:
            vid = vids[0]
        rel = executor.extents[vid]
        if len(rel.rows):
            rows = rel.rows[:-1]
        else:
            rows = np.zeros((1, max(len(rel.cols), 1)), np.int32)
        executor.extents[vid] = R.Relation(rows, rel.cols)
        self.injected += 1
        self.log.append(("extent_corrupt", vid))
        del self.log[:-self.MAX_LOG]
        return vid
