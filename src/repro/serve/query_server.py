"""Workload query serving: batched requests through the fused executor.

The paper's demo answers one query at a time; at serving scale requests
arrive in batches drawn from the tuned workload.  `QueryServer` front-
ends a `QueryExecutor`: the whole workload is answered by ONE jitted
device program (shared subplans computed once), so a batch of requests
— whatever its mix of queries — costs at most one device call, and
repeat batches are served from the cached workload results until the
store or state changes (`invalidate`).

Union semantics over RDFS reformulation groups are applied per request,
matching `QueryExecutor.answer_group`.

A server bound to a `repro.api.TuningSession` can retune ONLINE: the
session's `apply()` hot-swaps the compiled workload program on the same
executor object this server holds, so `retune_online()` evolves the
workload behind the batched endpoint without a server restart.

With `maintenance=` configured the server also ingests streaming triple
deltas (`submit`) under a staleness budget: pending updates are applied
by the incremental `ViewMaintainer` (repro.maintenance) between batches
whenever the backlog exceeds `staleness_budget` pending triples, so an
answered batch is never more than the budget stale.  The maintainer's
drift detector can trigger an automatic retune (`auto_retune`), with
measured per-view maintenance costs feeding the retune's objective.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.core.executor import QueryExecutor


@dataclass
class ServeStats:
    requests: int = 0
    batches: int = 0
    unknown: int = 0
    device_runs: int = 0
    compiles: int = 0
    recompiles: int = 0
    shared_nodes: int = 0
    node_reuse_count: int = 0
    retunes: int = 0
    # shape-bucketed compile telemetry (query/buckets.py)
    buckets: int = 0
    bucket_compiles: int = 0
    bucket_cache_hits: int = 0
    bucket_cache_misses: int = 0
    bucket_compile_seconds: float = 0.0
    compile_cache_entries: int = 0
    # streaming maintenance (repro.maintenance)
    updates_submitted: int = 0     # triples ever submitted
    updates_applied: int = 0       # effective triples maintained
    refreshes: int = 0             # maintenance passes run
    backlog_batches: int = 0       # pending update batches right now
    backlog_triples: int = 0       # pending triples right now (lag)
    max_staleness_served: int = 0  # worst pending-triple count at answer
    maintenance_seconds: float = 0.0
    drift_retunes: int = 0

    def as_dict(self) -> dict:
        return dict(self.__dict__)


class QueryServer:
    def __init__(self, executor: QueryExecutor, session=None,
                 maintenance=None):
        """`maintenance`: None (static store), a
        `repro.maintenance.MaintenanceConfig`, or a pre-built
        `ViewMaintainer` bound to this executor."""
        self.executor = executor
        self.session = session
        self.stats = ServeStats()
        self.maintainer = None
        self.stream = None
        if maintenance is not None:
            from repro.maintenance import (MaintenanceConfig, UpdateStream,
                                           ViewMaintainer)

            if isinstance(maintenance, ViewMaintainer):
                self.maintainer = maintenance
                if self.session is not None:
                    # session adopts the pre-built maintainer's measured
                    # costs so they flow into its retune objective
                    self.session.maintenance_costs = self.maintainer.costs
            else:
                cfg = maintenance if isinstance(maintenance,
                                                MaintenanceConfig) \
                    else MaintenanceConfig()
                costs = (self.session.maintenance_costs
                         if self.session is not None else None)
                self.maintainer = ViewMaintainer(executor, cfg, costs=costs)
            self.stream = UpdateStream()

    @classmethod
    def from_tuned(cls, store, workload, schema=None, type_id=None, cfg=None):
        """Convenience: one retained tuning session, served.  The server
        can retune online (unlike the deprecated one-shot `tune()`)."""
        from repro.api.session import TuningSession

        session = TuningSession(store, workload=list(workload), schema=schema,
                                type_id=type_id, cfg=cfg)
        session.retune()
        session.apply()
        return cls(session.executor, session=session)

    # ------------------------------------------------------------------
    def retune_online(self, add=(), remove=()) -> dict:
        """Evolve the workload behind the endpoint: add/remove queries,
        warm-retune, delta-swap the view set — all while this server
        object keeps serving (next batch sees the new configuration).
        The whole edit is validated before any of it is applied, so a
        bad request leaves the workload untouched.
        Returns {"retune": RetuneReport, "apply": ApplyReport}."""
        if self.session is None:
            raise RuntimeError(
                "retune_online needs a session-bound server; construct via "
                "TuningSession.serve() or QueryServer.from_tuned()")
        current = {q.name for q in self.session.workload}
        unknown = set(remove) - current
        if unknown:
            raise KeyError(f"unknown queries: {sorted(unknown)}")
        surviving = current - set(remove)
        for q in add:
            if not q.name:
                raise ValueError("workload queries must be named")
            if q.name in surviving:
                raise ValueError(f"duplicate query name {q.name!r}")
            surviving.add(q.name)
        for name in remove:
            self.session.remove_query(name)
        for q in add:
            self.session.add_query(q)
        retune = self.session.retune()
        apply_ = self.session.apply()  # hot swap: self.executor stays valid
        if self.maintainer is not None:
            self.maintainer.rebind(self.executor)
        self.stats.retunes += 1
        return {"retune": retune, "apply": apply_}

    # ------------------------------------------------------------------
    # streaming updates (repro.maintenance)
    # ------------------------------------------------------------------
    def submit(self, inserts=None, deletes=None) -> None:
        """Enqueue one update batch.  Cheap: the device work happens at
        the next answer under the staleness budget (or at `flush`)."""
        if self.stream is None:
            raise RuntimeError(
                "server has no update stream; construct with maintenance=")
        from repro.maintenance import Delta

        self.stream.push(Delta.of(inserts, deletes))
        self.stats.updates_submitted = self.stream.total_pushed

    def flush(self) -> list:
        """Apply the entire backlog now, regardless of budget."""
        return self._refresh(budget=0)

    def _refresh(self, budget: int | None = None) -> list:
        """Apply pending deltas while the backlog exceeds the budget;
        returns the MaintenanceReports of the applied passes."""
        if self.stream is None or self.maintainer is None:
            return []
        if budget is None:
            budget = self.maintainer.cfg.staleness_budget
        reports = []
        while self.stream.pending_triples > budget:
            delta = self.stream.coalesce() if budget == 0 \
                else self.stream.pop()
            if delta is None:
                break
            report = self.maintainer.apply(delta)
            reports.append(report)
            self.stats.refreshes += 1
            self.stats.updates_applied += (report.eff_inserts
                                           + report.eff_deletes)
            self.stats.maintenance_seconds += report.seconds
            if self.session is not None:
                self.session.store = self.executor.store
            if (report.drift is not None and report.drift.triggered
                    and self.maintainer.cfg.auto_retune
                    and self.session is not None):
                self._drift_retune()
        self.stats.backlog_batches = self.stream.pending_batches
        self.stats.backlog_triples = self.stream.pending_triples
        return reports

    def _drift_retune(self) -> None:
        """Drift-triggered retune: re-search with measured maintenance
        costs and the store's fresh statistics, hot-swap the program,
        and rebind the maintainer to the new view set."""
        self.session.retune()
        self.session.apply()  # hot swap on the same executor object
        self.maintainer.rebind(self.executor)
        self.stats.retunes += 1
        self.stats.drift_retunes += 1

    # ------------------------------------------------------------------
    def answer_batch(self, names: list[str]) -> list[set[tuple[int, ...]] | None]:
        """Answer a batch of workload query names (union-group semantics).

        Unknown names yield None instead of failing the batch.  The
        first batch triggers the single fused workload evaluation; later
        batches are served from the cached results.  With streaming
        maintenance configured, pending updates beyond the staleness
        budget are applied first — the answers of a batch are never more
        than `staleness_budget` pending triples stale.
        """
        self._refresh()
        if self.stream is not None:
            self.stats.max_staleness_served = max(
                self.stats.max_staleness_served, self.stream.pending_triples)
        self.executor.answer_workload()  # at most one device call
        out: list[set[tuple[int, ...]] | None] = []
        for name in names:
            if name in self.executor.groups:
                out.append(self.executor.answer_group(name))
            else:
                self.stats.unknown += 1
                out.append(None)
        self.stats.requests += len(names)
        self.stats.batches += 1
        self._sync_telemetry()
        return out

    def answer(self, name: str) -> set[tuple[int, ...]] | None:
        return self.answer_batch([name])[0]

    # ------------------------------------------------------------------
    def invalidate(self, store=None) -> None:
        """Refresh after TT maintenance: re-materialize view extents,
        re-upload the triple-table indexes (optionally from a replaced
        store), and drop cached results so the next batch re-runs the
        fused program against fresh data."""
        self.executor.refresh(store)
        if self.session is not None:
            # keep the session on the serving store: later retunes search
            # with its statistics, and save() persists its triple table
            self.session.store = self.executor.store
        if self.maintainer is not None:
            # refresh() rebuilt device state from scratch (unpadded TT,
            # exact-class extents): re-establish maintenance invariants
            self.maintainer.rebind(self.executor)

    def _sync_telemetry(self) -> None:
        t = self.executor.telemetry()
        self.stats.device_runs = t["runs"]
        self.stats.compiles = t["compiles"]
        self.stats.recompiles = t["recompiles"]
        self.stats.shared_nodes = t["shared_nodes"]
        self.stats.node_reuse_count = t["node_reuse_count"]
        self.stats.buckets = t["buckets"]
        self.stats.bucket_compiles = t["bucket_compiles"]
        self.stats.bucket_cache_hits = t["bucket_cache_hits"]
        self.stats.bucket_cache_misses = t["bucket_compiles"]
        self.stats.bucket_compile_seconds = t["bucket_compile_seconds"]
        self.stats.compile_cache_entries = t["compile_cache"]["entries"]
        if self.stream is not None:
            self.stats.backlog_batches = self.stream.pending_batches
            self.stats.backlog_triples = self.stream.pending_triples
