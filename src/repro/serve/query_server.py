"""Workload query serving: batched requests through the fused executor.

The paper's demo answers one query at a time; at serving scale requests
arrive in batches drawn from the tuned workload.  `QueryServer` front-
ends a `QueryExecutor`: the whole workload is answered by ONE jitted
device program (shared subplans computed once), so a batch of requests
— whatever its mix of queries — costs at most one device call, and
repeat batches are served from the cached workload results until the
store or state changes (`invalidate`).

Union semantics over RDFS reformulation groups are applied per request,
matching `QueryExecutor.answer_group`.

Serving is FAULT TOLERANT through a degradation ladder (docs/serving.md):

  tier 0  fused device program (fast path; circuit-broken on failure)
  tier 1  per-query unrolled jitted path (no shared subplans)
  tier 2  host reference engine over the raw triple table (exact,
          independent of view extents and device state)
  tier 3  last-known-good cached answers, explicitly flagged stale

Tiers 0-2 are exact; an answer is never silently wrong — before the
fused path serves, an integrity probe checks host-mirror/device-buffer
row alignment of every extent and repairs via re-materialization.  The
`ServingSupervisor` (repro.distributed.fault) owns a deterministic,
batch-clocked circuit breaker over tier 0 and the health state machine
HEALTHY / DEGRADED / STALE_ONLY / DOWN surfaced in `ServeStats` and the
`readiness()` probe.  When no tier can serve, `answer_batch` raises
`ServiceUnavailable` instead of returning wrong data.

A server bound to a `repro.api.TuningSession` can retune ONLINE: the
session's `apply()` hot-swaps the compiled workload program on the same
executor object this server holds, so `retune_online()` evolves the
workload behind the batched endpoint without a server restart.  Both
`retune_online()` and drift-triggered retunes are TRANSACTIONAL: the
session and executor bindings are snapshotted first and restored on any
failure, so a crashed retune leaves the previous program serving.

With `maintenance=` configured the server also ingests streaming triple
deltas (`submit`) under a staleness budget: pending updates are applied
by the incremental `ViewMaintainer` (repro.maintenance) between batches
whenever the backlog exceeds `staleness_budget` pending triples, so an
answered batch is never more than the budget stale.  A failed
maintenance pass requeues its delta at the head of the stream and the
batch is flagged stale if the backlog exceeds the budget.  The
maintainer's drift detector can trigger an automatic retune
(`auto_retune`), with measured per-view maintenance costs feeding the
retune's objective.

`chaos=` attaches a `repro.serve.chaos.FaultInjector` to every fault
boundary for deterministic fault-injection testing.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

from repro.core.executor import QueryExecutor
from repro.distributed.fault import RetryPolicy, ServingSupervisor
from repro.errors import ServiceUnavailable


@dataclass
class ServeStats:
    requests: int = 0
    batches: int = 0
    unknown: int = 0
    device_runs: int = 0
    compiles: int = 0
    recompiles: int = 0
    shared_nodes: int = 0
    node_reuse_count: int = 0
    retunes: int = 0
    # shape-bucketed compile telemetry (query/buckets.py)
    buckets: int = 0
    bucket_compiles: int = 0
    bucket_cache_hits: int = 0
    bucket_cache_misses: int = 0
    bucket_compile_seconds: float = 0.0
    compile_cache_entries: int = 0
    # streaming maintenance (repro.maintenance)
    updates_submitted: int = 0     # triples ever submitted
    updates_applied: int = 0       # effective triples maintained
    refreshes: int = 0             # maintenance passes run
    backlog_batches: int = 0       # pending update batches right now
    backlog_triples: int = 0       # pending triples right now (lag)
    max_staleness_served: int = 0  # worst pending-triple count at answer
    maintenance_seconds: float = 0.0
    drift_retunes: int = 0
    # fault tolerance (degradation ladder, repro.distributed.fault)
    health: str = "HEALTHY"        # HEALTHY|DEGRADED|STALE_ONLY|DOWN
    served_tier: int = 0           # tier that answered the last batch
    degraded_answers: int = 0      # answers served below tier 0
    stale_answers: int = 0         # answers flagged stale (budget/LKG)
    fused_failures: int = 0        # tier-0 batches lost after retries
    per_query_failures: int = 0
    ref_engine_failures: int = 0
    maintenance_failures: int = 0  # delta passes that failed (requeued)
    integrity_failures: int = 0    # extent misalignment detections
    repairs: int = 0               # successful integrity repairs
    retune_failures: int = 0       # drift retunes rolled back
    retune_rollbacks: int = 0      # retune_online calls rolled back
    breaker_state: str = "closed"
    breaker_opens: int = 0
    last_batch: dict = field(default_factory=lambda: {
        "tier": 0, "degraded": False, "stale": False})
    faults: list = field(default_factory=list)   # bounded fault log
    # async frontend summary (repro.serve.frontend): queue depth, batch
    # occupancy, shed/downgrade counters, per-class latency histograms —
    # mirrored in by ServingFrontend._sync after each completed batch
    frontend: dict = field(default_factory=dict)

    def as_dict(self) -> dict:
        d = dict(self.__dict__)
        d["last_batch"] = dict(self.last_batch)
        d["faults"] = list(self.faults)
        d["frontend"] = dict(self.frontend)
        return d


class QueryServer:
    MAX_FAULT_LOG = 64

    def __init__(self, executor: QueryExecutor, session=None,
                 maintenance=None, chaos=None, policy=None):
        """`maintenance`: None (static store), a
        `repro.maintenance.MaintenanceConfig`, or a pre-built
        `ViewMaintainer` bound to this executor.  `chaos`: a
        `repro.serve.chaos.FaultInjector` wired into every fault
        boundary (executor, session, maintainer).  `policy`: the
        degradation ladder's `RetryPolicy` (retries, breaker cooldown,
        backoff — all deterministic batch counts)."""
        self.executor = executor
        self.session = session
        self.chaos = chaos
        self.policy = policy or RetryPolicy()
        self.supervisor = ServingSupervisor(self.policy)
        self.stats = ServeStats()
        self.maintainer = None
        self.stream = None
        self._lkg: dict[str, set[tuple[int, ...]]] = {}
        if chaos is not None:
            executor.set_fault_hook(chaos)
            if session is not None:
                session.fault_hook = chaos
        if maintenance is not None:
            from repro.maintenance import (MaintenanceConfig, UpdateStream,
                                           ViewMaintainer)

            if isinstance(maintenance, ViewMaintainer):
                self.maintainer = maintenance
                if self.session is not None:
                    # session adopts the pre-built maintainer's measured
                    # costs so they flow into its retune objective
                    self.session.maintenance_costs = self.maintainer.costs
            else:
                cfg = maintenance if isinstance(maintenance,
                                                MaintenanceConfig) \
                    else MaintenanceConfig()
                costs = (self.session.maintenance_costs
                         if self.session is not None else None)
                self.maintainer = ViewMaintainer(executor, cfg, costs=costs)
            self.stream = UpdateStream()

    @classmethod
    def from_tuned(cls, store, workload, schema=None, type_id=None, cfg=None,
                   chaos=None, policy=None):
        """Convenience: one retained tuning session, served.  The server
        can retune online (unlike the deprecated one-shot `tune()`)."""
        from repro.api.session import TuningSession

        session = TuningSession(store, workload=list(workload), schema=schema,
                                type_id=type_id, cfg=cfg)
        session.retune()
        session.apply()
        return cls(session.executor, session=session, chaos=chaos,
                   policy=policy)

    # ------------------------------------------------------------------
    def retune_online(self, add=(), remove=()) -> dict:
        """Evolve the workload behind the endpoint: add/remove queries,
        warm-retune, delta-swap the view set — all while this server
        object keeps serving (next batch sees the new configuration).
        The whole edit is validated before any of it is applied, and the
        retune+apply runs as ONE TRANSACTION: any failure rolls the
        session, workload and executor bindings back to their pre-call
        state (the previous compiled program keeps serving) and
        re-raises.  Returns {"retune": RetuneReport, "apply": ApplyReport}.
        """
        if self.session is None:
            raise RuntimeError(
                "retune_online needs a session-bound server; construct via "
                "TuningSession.serve() or QueryServer.from_tuned()")
        current = {q.name for q in self.session.workload}
        unknown = set(remove) - current
        if unknown:
            raise KeyError(f"unknown queries: {sorted(unknown)}")
        surviving = current - set(remove)
        for q in add:
            if not q.name:
                raise ValueError("workload queries must be named")
            if q.name in surviving:
                raise ValueError(f"duplicate query name {q.name!r}")
            surviving.add(q.name)
        snap = self.session.snapshot()
        try:
            for name in remove:
                self.session.remove_query(name)
            for q in add:
                self.session.add_query(q)
            retune = self.session.retune()
            apply_ = self.session.apply()  # hot swap: executor stays valid
        except Exception as exc:
            self.session.restore(snap)
            if self.maintainer is not None:
                self.maintainer.rebind(self.executor)
            self.stats.retune_rollbacks += 1
            self._note_fault("retune_online", exc)
            raise
        if self.maintainer is not None:
            self.maintainer.rebind(self.executor)
        self.stats.retunes += 1
        return {"retune": retune, "apply": apply_}

    # ------------------------------------------------------------------
    # streaming updates (repro.maintenance)
    # ------------------------------------------------------------------
    def submit(self, inserts=None, deletes=None) -> None:
        """Enqueue one update batch.  Cheap: the device work happens at
        the next answer under the staleness budget (or at `flush`)."""
        if self.stream is None:
            raise RuntimeError(
                "server has no update stream; construct with maintenance=")
        from repro.maintenance import Delta

        self.stream.push(Delta.of(inserts, deletes))
        self.stats.updates_submitted = self.stream.total_pushed

    def flush(self) -> list:
        """Apply the entire backlog now, regardless of budget."""
        return self._refresh(budget=0)

    def _refresh(self, budget: int | None = None) -> list:
        """Apply pending deltas while the backlog exceeds the budget;
        returns the MaintenanceReports of the applied passes.  A delta
        whose apply fails is requeued at the stream head (sequential
        semantics preserved) and the failure re-raised — `answer_batch`
        absorbs it and serves stale-flagged answers instead."""
        if self.stream is None or self.maintainer is None:
            return []
        if budget is None:
            budget = self.maintainer.cfg.staleness_budget
        reports = []
        while self.stream.pending_triples > budget:
            delta = self.stream.coalesce() if budget == 0 \
                else self.stream.pop()
            if delta is None:
                break
            try:
                report = self.maintainer.apply(delta)
            except Exception:
                self.stream.push_front(delta)
                self.stats.backlog_batches = self.stream.pending_batches
                self.stats.backlog_triples = self.stream.pending_triples
                raise
            reports.append(report)
            self.stats.refreshes += 1
            self.stats.updates_applied += (report.eff_inserts
                                           + report.eff_deletes)
            self.stats.maintenance_seconds += report.seconds
            if self.session is not None:
                self.session.store = self.executor.store
            if (report.drift is not None and report.drift.triggered
                    and self.maintainer.cfg.auto_retune
                    and self.session is not None):
                self._drift_retune()
        self.stats.backlog_batches = self.stream.pending_batches
        self.stats.backlog_triples = self.stream.pending_triples
        return reports

    def _drift_retune(self) -> None:
        """Drift-triggered retune: re-search with measured maintenance
        costs and the store's fresh statistics, hot-swap the program,
        and rebind the maintainer to the new view set.  Transactional:
        a failure restores the session/executor to their pre-retune
        bindings and is absorbed (counted in `retune_failures`) — an
        automatic background retune must never take serving down."""
        snap = self.session.snapshot()
        try:
            self.session.retune()
            self.session.apply()  # hot swap on the same executor object
        except Exception as exc:
            self.session.restore(snap)
            self.maintainer.rebind(self.executor)
            self.stats.retune_failures += 1
            self._note_fault("drift_retune", exc)
            return
        self.maintainer.rebind(self.executor)
        self.stats.retunes += 1
        self.stats.drift_retunes += 1

    # ------------------------------------------------------------------
    # degradation ladder
    # ------------------------------------------------------------------
    def _integrity_ok(self) -> bool:
        """Probe the invariant streaming maintenance preserves: every
        materialized extent's host mirror has exactly the device
        buffer's logical row count.  A mismatch means one side is
        corrupt — the fused and per-query tiers (which read the device
        buffers and, for oracle fallbacks, the mirrors) must not serve
        until re-materialization repairs it."""
        for vid, dev in self.executor.device_views.items():
            rel = self.executor.extents.get(vid)
            if rel is None or len(rel.rows) != int(dev.n):
                return False
        return True

    def _note_fault(self, kind: str, exc) -> None:
        self.stats.faults.append(f"{kind}: {exc}")
        del self.stats.faults[:-self.MAX_FAULT_LOG]

    def _serve_names(self, known: list[str]
                     ) -> tuple[int, dict[str, set[tuple[int, ...]]], bool]:
        """Run the degradation ladder for this batch's known names.
        Returns (tier, answers, repaired); raises `ServiceUnavailable`
        when no tier (including the LKG cache) can serve."""
        pol, breaker = self.policy, self.supervisor.fused
        repaired = False

        # ---- tier 0: fused device program -------------------------
        extents_ok = self._integrity_ok()
        if not extents_ok:
            self.stats.integrity_failures += 1
            self._note_fault("integrity", "extent host/device misalignment")
            try:
                self.invalidate()  # repair: re-materialize from the store
                extents_ok = self._integrity_ok()
                if extents_ok:
                    self.stats.repairs += 1
                    repaired = True
            except Exception as exc:
                self._note_fault("repair", exc)
        if extents_ok and breaker.allow():
            for attempt in range(pol.max_attempts):
                try:
                    t0 = time.perf_counter()
                    self.executor.answer_workload()  # one device call
                    answers = {n: self.executor.answer_group(n)
                               for n in known}
                    elapsed = time.perf_counter() - t0
                    if (pol.call_timeout_seconds is not None
                            and elapsed > pol.call_timeout_seconds):
                        # soft budget: the answers are exact but the
                        # tier is too slow — trip the breaker so later
                        # batches degrade instead of stalling
                        breaker.record_failure()
                        self._note_fault(
                            "fused_slow",
                            f"{elapsed:.3f}s > {pol.call_timeout_seconds}s")
                    else:
                        breaker.record_success()
                    return 0, answers, repaired
                except Exception as exc:
                    if attempt + 1 >= pol.max_attempts:
                        breaker.record_failure()
                        self.stats.fused_failures += 1
                        self._note_fault("fused", exc)

        # ---- tier 1: per-query unrolled path ----------------------
        if extents_ok:
            try:
                if self.chaos is not None:
                    self.chaos.fire("per_query_call")
                answers = {n: self.executor.answer_group_per_query(n)
                           for n in known}
                return 1, answers, repaired
            except Exception as exc:
                self.stats.per_query_failures += 1
                self._note_fault("per_query", exc)

        # ---- tier 2: host reference engine over the raw TT --------
        try:
            if self.chaos is not None:
                self.chaos.fire("ref_engine_call")
            answers = {n: self.executor.answer_group_direct(n)
                       for n in known}
            return 2, answers, repaired
        except Exception as exc:
            self.stats.ref_engine_failures += 1
            self._note_fault("ref_engine", exc)

        # ---- tier 3: last-known-good cache (stale) ----------------
        if known and all(n in self._lkg for n in known):
            return 3, {n: self._lkg[n] for n in known}, repaired
        raise ServiceUnavailable(
            "no serving tier available and no last-known-good answers "
            f"for {sorted(n for n in known if n not in self._lkg)}")

    # ------------------------------------------------------------------
    def answer_batch(self, names: list[str]) -> list[set[tuple[int, ...]] | None]:
        """Answer a batch of workload query names (union-group semantics)
        through the degradation ladder.

        Unknown names yield None instead of failing the batch.  With
        streaming maintenance configured, pending updates beyond the
        staleness budget are applied first; a failed maintenance pass
        requeues its delta and the batch is flagged stale if the
        backlog exceeds the budget.  Every returned answer is exact for
        the store snapshot it was computed on unless
        `stats.last_batch["stale"]` is set (tier-3 / over-budget
        serving).  Raises `ServiceUnavailable` — and goes DOWN — only
        when every tier and the last-known-good cache fail.
        """
        self.supervisor.begin_batch()
        stale = False
        try:
            self._refresh()
        except Exception as exc:
            self.stats.maintenance_failures += 1
            self._note_fault("maintenance", exc)
        if self.stream is not None:
            pending = self.stream.pending_triples
            self.stats.max_staleness_served = max(
                self.stats.max_staleness_served, pending)
            if pending > self.maintainer.cfg.staleness_budget:
                stale = True
        known = [n for n in names if n in self.executor.groups]
        try:
            tier, answers, repaired = self._serve_names(known)
        except ServiceUnavailable:
            self.supervisor.observe(None, stale, reason="no servable tier")
            self._finish_batch(names, known, tier=None, stale=stale)
            raise
        if tier < 3:
            self._lkg.update(answers)
        else:
            stale = True
        self.supervisor.observe(tier, stale, degraded=repaired)
        degraded = tier > 0 or repaired
        out: list[set[tuple[int, ...]] | None] = []
        for name in names:
            if name in self.executor.groups:
                out.append(answers[name])
            else:
                self.stats.unknown += 1
                out.append(None)
        if degraded:
            self.stats.degraded_answers += len(known)
        if stale:
            self.stats.stale_answers += len(known)
        self._finish_batch(names, known, tier=tier, stale=stale,
                           degraded=degraded)
        return out

    def _finish_batch(self, names, known, tier, stale,
                      degraded: bool = False) -> None:
        self.stats.requests += len(names)
        self.stats.batches += 1
        self.stats.served_tier = tier if tier is not None else -1
        self.stats.last_batch = {"tier": tier, "degraded": degraded,
                                 "stale": stale}
        self.stats.health = self.supervisor.health
        self.stats.breaker_state = self.supervisor.fused.state
        self.stats.breaker_opens = self.supervisor.fused.opens
        self._sync_telemetry()

    def answer(self, name: str) -> set[tuple[int, ...]] | None:
        return self.answer_batch([name])[0]

    # ------------------------------------------------------------------
    def readiness(self) -> dict:
        """Readiness probe: can this server answer SOMETHING (possibly
        stale)?  Ready in every health state but DOWN."""
        probe = {
            "ready": self.supervisor.ready(),
            "health": self.supervisor.health,
            "breaker": self.supervisor.fused.state,
            "backlog_triples": (self.stream.pending_triples
                                if self.stream is not None else 0),
            "lkg_queries": len(self._lkg),
            "batches": self.supervisor.batches,
        }
        if self.stats.frontend:
            # async frontend attached: surface its queue/shed state too
            probe["frontend"] = {
                k: self.stats.frontend.get(k)
                for k in ("queue_depth", "shed", "downgraded",
                          "batch_occupancy")}
        return probe

    # ------------------------------------------------------------------
    def invalidate(self, store=None) -> None:
        """Refresh after TT maintenance: re-materialize view extents,
        re-upload the triple-table indexes (optionally from a replaced
        store), and drop cached results so the next batch re-runs the
        fused program against fresh data."""
        self.executor.refresh(store)
        if self.session is not None:
            # keep the session on the serving store: later retunes search
            # with its statistics, and save() persists its triple table
            self.session.store = self.executor.store
        if self.maintainer is not None:
            # refresh() rebuilt device state from scratch (unpadded TT,
            # exact-class extents): re-establish maintenance invariants
            self.maintainer.rebind(self.executor)

    def _sync_telemetry(self) -> None:
        t = self.executor.telemetry()
        self.stats.device_runs = t["runs"]
        self.stats.compiles = t["compiles"]
        self.stats.recompiles = t["recompiles"]
        self.stats.shared_nodes = t["shared_nodes"]
        self.stats.node_reuse_count = t["node_reuse_count"]
        self.stats.buckets = t["buckets"]
        self.stats.bucket_compiles = t["bucket_compiles"]
        self.stats.bucket_cache_hits = t["bucket_cache_hits"]
        self.stats.bucket_cache_misses = t["bucket_cache_misses"]
        self.stats.bucket_compile_seconds = t["bucket_compile_seconds"]
        self.stats.compile_cache_entries = t["compile_cache"]["entries"]
        if self.stream is not None:
            self.stats.backlog_batches = self.stream.pending_batches
            self.stats.backlog_triples = self.stream.pending_triples
