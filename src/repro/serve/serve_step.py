"""Serving: jitted decode step + a minimal batched-request engine.

`make_serve_step` is what the dry-run lowers for decode_* / long_* cells:
one new token against a KV (or recurrent-state) cache of `cache_len`.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model


@dataclass(frozen=True)
class ServeConfig:
    temperature: float = 0.0  # 0 = greedy
    cache_len: int = 4096


def make_serve_step(model: Model, sc: ServeConfig):
    """serve_step(params, cache, token, pos, key) -> (next_token, cache)."""

    def step(params, cache, token, pos, key):
        logits, cache = model.decode_step(params, token, pos, cache)
        last = logits[:, -1, :].astype(jnp.float32)
        if sc.temperature > 0.0:
            nxt = jax.random.categorical(key, last / sc.temperature, axis=-1)
        else:
            nxt = jnp.argmax(last, axis=-1)
        return nxt[:, None].astype(jnp.int32), cache

    return step


def make_prefill(model: Model):
    """prefill(params, tokens) -> logits (the inference-prefill workload)."""

    def prefill(params, tokens, positions=None, enc_frames=None):
        return model.forward(params, tokens=tokens, positions=positions,
                             enc_frames=enc_frames)

    return prefill


class BatchedServer:
    """Toy continuous-batching server: fixed batch of request slots, each
    slot decodes independently; finished slots are refilled.  Exercises
    the serving path end-to-end in examples/ and tests."""

    def __init__(self, model: Model, params, sc: ServeConfig, batch: int,
                 eos_id: int = 0, max_new: int = 16):
        self.model = model
        self.params = params
        self.sc = sc
        self.batch = batch
        self.eos_id = eos_id
        self.max_new = max_new
        self.step_fn = jax.jit(make_serve_step(model, sc))
        enc_len = 8 if model.cfg.encoder is not None else 0
        self.cache = model.init_cache(batch, sc.cache_len, enc_len)
        self.tokens = jnp.zeros((batch, 1), jnp.int32)
        self.produced: list[list[int]] = [[] for _ in range(batch)]
        self.done: list[list[int]] = []

    def run(self, steps: int, key=None):
        key = key if key is not None else jax.random.key(0)
        for pos in range(steps):
            key, sub = jax.random.split(key)
            self.tokens, self.cache = self.step_fn(
                self.params, self.cache, self.tokens, jnp.int32(pos), sub)
            toks = np.asarray(self.tokens)[:, 0]
            for i, t in enumerate(toks.tolist()):
                self.produced[i].append(t)
                if t == self.eos_id or len(self.produced[i]) >= self.max_new:
                    # bounded by steps*batch within one run() call
                    self.done.append(self.produced[i])  # lint: allow-unbounded
                    self.produced[i] = []  # slot refilled with a new request
        return self.done
