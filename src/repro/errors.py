"""Typed exceptions for library invariants.

Library code paths must not rely on bare ``assert`` statements: they are
stripped under ``python -O``, silently turning invariant violations into
wrong answers downstream.  The repo-rule analyzer
(`repro.analysis.repo_rules`, rule ``bare-assert``) enforces that every
invariant check in the pipeline packages raises one of these instead.
"""
from __future__ import annotations


class InvariantViolation(RuntimeError):
    """An internal structural invariant was broken.

    Raised where a bare ``assert`` used to live: the condition is not a
    user error but a bug in this library (or corrupted state fed back
    into it), and it must fail loudly even under ``python -O``.
    """


class ServiceUnavailable(RuntimeError):
    """Every tier of the serving degradation ladder failed for a batch.

    Raised by `repro.serve.query_server.QueryServer` only when the fused
    device path, the per-query fallback, the host reference engine AND
    the last-known-good cache all failed to produce an answer — the
    server is DOWN and says so instead of returning anything silently
    wrong.  The request may be retried: the ladder re-runs per batch
    and recovers as soon as any tier heals.
    """


def require(condition: bool, message: str) -> None:
    """``assert`` replacement that survives ``python -O``."""
    if not condition:
        raise InvariantViolation(message)
