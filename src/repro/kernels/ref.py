"""Pure-jnp oracles for every Pallas kernel (allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def join_count_ref(probe: jax.Array, build_sorted: jax.Array
                   ) -> tuple[jax.Array, jax.Array]:
    """lo = #{s < l}, count = #{s == l} via binary search."""
    lo = jnp.searchsorted(build_sorted, probe, side="left").astype(jnp.int32)
    hi = jnp.searchsorted(build_sorted, probe, side="right").astype(jnp.int32)
    return lo, hi - lo


def filter_mask_ref(rows: jax.Array, conds: tuple[tuple[int, int], ...],
                    br: int) -> tuple[jax.Array, jax.Array]:
    """mask + per-block popcounts (block size br, zero-padded tail)."""
    n = rows.shape[0]
    mask = rows[:, 0] >= 0
    for col, val in conds:
        mask = mask & (rows[:, col] == jnp.int32(val))
    mask = mask.astype(jnp.int32)
    npad = -(-n // br) * br
    padded = jnp.zeros((npad,), jnp.int32).at[:n].set(mask)
    counts = padded.reshape(-1, br).sum(axis=1).astype(jnp.int32)
    return mask, counts


def flash_attention_ref(q, k, v, window: int = 0):
    """Dense causal GQA attention oracle. q:(B,S,H,hd); k,v:(B,S,Hkv,hd)."""
    B, S, H, hd = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    qg = q.reshape(B, S, Hkv, G, hd).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    s_ = jnp.einsum("bskgh,btkh->bkgst", qg, kf) / (hd ** 0.5)
    i = jax.lax.broadcasted_iota(jnp.int32, (S, S), 0)
    j = jax.lax.broadcasted_iota(jnp.int32, (S, S), 1)
    mask = j <= i
    if window > 0:
        mask = mask & (j > i - window)
    s_ = jnp.where(mask[None, None, None], s_, -1e30)
    p = jax.nn.softmax(s_, axis=-1)
    out = jnp.einsum("bkgst,btkh->bskgh", p, v.astype(jnp.float32))
    return out.reshape(B, S, H, hd).astype(q.dtype)
