"""Pallas TPU kernel: flash attention forward (online softmax).

The §Perf cell-B analysis shows the remaining memory term of 32k-token
prefill is the HBM-charged score-tile passes of the XLA-loop chunked
attention.  This kernel is the VMEM-resident version: one (Cq x Ck) f32
score tile lives in VMEM per grid step; HBM traffic is exactly
q + k + v + o (+ the tiny m/l accumulators).

  grid = (B*H, nq, nk)        # nk minor => sequential accumulation
  q tile (Cq, hd) x k/v tiles (Ck, hd) per (batch*head)
  GQA: head h reads kv-head h // (H // Hkv) via the k/v index maps.

Accumulators (o, m, l) are output refs indexed by (bh, i): Pallas keeps
them resident across the nk loop; the last step normalizes o by l.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, *,
            scale: float, cq: int, ck: int, nk: int, window: int):
    i = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[...].astype(jnp.float32)            # (Cq, hd)
    k = k_ref[...].astype(jnp.float32)            # (Ck, hd)
    v = v_ref[...].astype(jnp.float32)            # (Ck, hd)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale  # (Cq,Ck)

    qpos = i * cq + jax.lax.broadcasted_iota(jnp.int32, (cq, ck), 0)
    kpos = j * ck + jax.lax.broadcasted_iota(jnp.int32, (cq, ck), 1)
    mask = kpos <= qpos
    if window > 0:
        mask = mask & (kpos > qpos - window)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                            # (Cq, 1)
    l_prev = l_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)                         # (Cq, Ck)
    alpha = jnp.exp(m_prev - m_new)                # (Cq, 1)
    l_new = l_prev * alpha + jnp.sum(p, axis=1, keepdims=True)
    o_new = o_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())))            # (Cq, hd)

    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(j == nk - 1)
    def _finalize():
        o_ref[...] = o_new / jnp.maximum(l_new, 1e-30)

    @pl.when(j != nk - 1)
    def _store():
        o_ref[...] = o_new


@functools.partial(jax.jit,
                   static_argnames=("window", "cq", "ck", "interpret"))
def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array,
                           window: int = 0, cq: int = 128, ck: int = 128,
                           interpret: bool = True) -> jax.Array:
    """Causal (optionally sliding-window) GQA flash attention.

    q: (B,S,H,hd); k,v: (B,S,Hkv,hd) -> (B,S,H,hd).  S % cq == S % ck == 0.
    """
    B, S, H, hd = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    if S % cq != 0 or S % ck != 0:
        raise ValueError(
            f"sequence length {S} must be a multiple of the query/key "
            f"block sizes ({cq}, {ck})")
    nq, nk = S // cq, S // ck
    scale = 1.0 / (hd ** 0.5)

    # (B*H, S, hd) layout; kv stays at (B*Hkv, S, hd)
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(B * Hkv, S, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * Hkv, S, hd)

    def kv_index(bh, i, j):
        return ((bh // H) * Hkv + (bh % H) // G, j, 0)

    out, _, _ = pl.pallas_call(
        functools.partial(_kernel, scale=scale, cq=cq, ck=ck, nk=nk,
                          window=window),
        grid=(B * H, nq, nk),
        in_specs=[
            pl.BlockSpec((None, cq, hd), lambda bh, i, j: (bh, i, 0)),
            pl.BlockSpec((None, ck, hd), kv_index),
            pl.BlockSpec((None, ck, hd), kv_index),
        ],
        out_specs=[
            pl.BlockSpec((None, cq, hd), lambda bh, i, j: (bh, i, 0)),
            pl.BlockSpec((None, cq, 1), lambda bh, i, j: (bh, i, 0)),
            pl.BlockSpec((None, cq, 1), lambda bh, i, j: (bh, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * H, S, hd), jnp.float32),
            jax.ShapeDtypeStruct((B * H, S, 1), jnp.float32),
            jax.ShapeDtypeStruct((B * H, S, 1), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, S, hd).transpose(0, 2, 1, 3).astype(q.dtype)
