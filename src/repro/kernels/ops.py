"""Jit'd wrappers the query engine calls.

`interpret` defaults to True off-TPU (this container validates kernels in
interpret mode); on a real TPU backend the compiled kernels run.

Every wrapper validates operand dtypes/shapes (and static arguments) up
front.  Interpret mode is far laxer than compiled Mosaic — a float64
probe column or a 3-D rows buffer would "work" on CPU and then fail (or
silently truncate) the first time the same call hits a real TPU — so
the contract is enforced identically on both paths, with a typed error
naming the operand instead of a shape blow-up from inside the kernel.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.filter_compact import filter_mask_pallas
from repro.kernels.flash_attn import flash_attention_pallas
from repro.kernels.join_count import join_count_pallas
from repro.kernels.scatter_append import scatter_append_pallas


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _check(x, name: str, ndim: int, dtype=None) -> None:
    """Operand contract: rank and (optionally) dtype.  Works on concrete
    arrays and tracers alike — both carry shape/dtype."""
    shape = getattr(x, "shape", None)
    got_dtype = getattr(x, "dtype", None)
    if shape is None or got_dtype is None:
        raise TypeError(f"{name} must be an array, got {type(x).__name__}")
    if len(shape) != ndim:
        raise ValueError(
            f"{name} must be {ndim}-D, got shape {tuple(shape)}")
    if dtype is not None and jnp.dtype(got_dtype) != jnp.dtype(dtype):
        raise TypeError(
            f"{name} must be {jnp.dtype(dtype).name}, got "
            f"{jnp.dtype(got_dtype).name}")


def join_count(probe: jax.Array, build_sorted: jax.Array
               ) -> tuple[jax.Array, jax.Array]:
    """(lo, count) per probe key — Pallas probe phase of the sorted join."""
    _check(probe, "probe", 1, jnp.int32)
    _check(build_sorted, "build_sorted", 1, jnp.int32)
    return join_count_pallas(probe, build_sorted, interpret=_interpret())


def filter_mask(rows: jax.Array, conds: tuple[tuple[int, int], ...]
                ) -> tuple[jax.Array, jax.Array]:
    """(mask, block_counts) for a static conjunction of equalities."""
    _check(rows, "rows", 2, jnp.int32)
    width = rows.shape[1]
    for k, cond in enumerate(conds):
        if len(cond) != 2 or not all(isinstance(c, int) for c in cond):
            raise TypeError(
                f"conds[{k}] must be a static (col, value) int pair, "
                f"got {cond!r}")
        col, _value = cond
        if not (0 <= col < width):
            raise ValueError(
                f"conds[{k}] column {col} out of range for rows of "
                f"width {width}")
    return filter_mask_pallas(rows, conds, interpret=_interpret())


def scatter_append(buf: jax.Array, n, rows: jax.Array, k) -> jax.Array:
    """Append rows[:k] at position n of the (cap, W) buffer without
    changing its shape — the streaming-maintenance extent append.

    n and k may be host ints (checked against cap here) or int32 scalars;
    either way they travel to the kernel as data, so one compilation
    covers every batch of the same (cap, dcap, W) shape class."""
    _check(buf, "buf", 2, jnp.int32)
    _check(rows, "rows", 2, jnp.int32)
    if buf.shape[1] != rows.shape[1]:
        raise ValueError(
            f"buf width {buf.shape[1]} != rows width {rows.shape[1]}")
    if isinstance(n, int) and isinstance(k, int):
        if n < 0 or k < 0:
            raise ValueError(f"n and k must be non-negative, got {n}, {k}")
        if n + k > buf.shape[0]:
            raise ValueError(
                f"append overflows capacity: n={n} + k={k} > cap="
                f"{buf.shape[0]} — grow the capacity class first")
        if k > rows.shape[0]:
            raise ValueError(
                f"k={k} exceeds delta buffer capacity {rows.shape[0]}")
    nk = jnp.asarray([[n, k]], dtype=jnp.int32)
    return scatter_append_pallas(buf, rows, nk, interpret=_interpret())


def flash_attention(q, k, v, window: int = 0):
    """VMEM-resident flash attention forward (GQA, causal/sliding)."""
    _check(q, "q", 4)
    _check(k, "k", 4)
    _check(v, "v", 4)
    if k.shape != v.shape:
        raise ValueError(
            f"k and v must agree, got {tuple(k.shape)} vs {tuple(v.shape)}")
    if q.shape[0] != k.shape[0] or q.shape[1] != k.shape[1] \
            or q.shape[3] != k.shape[3]:
        raise ValueError(
            f"q {tuple(q.shape)} incompatible with kv {tuple(k.shape)}: "
            "batch, sequence and head dims must agree (q: (B,S,H,hd), "
            "kv: (B,S,Hkv,hd))")
    if q.shape[2] % k.shape[2] != 0:
        raise ValueError(
            f"query heads {q.shape[2]} must be a multiple of kv heads "
            f"{k.shape[2]} (GQA grouping)")
    if not isinstance(window, int) or window < 0:
        raise ValueError(f"window must be a non-negative int, got {window!r}")
    return flash_attention_pallas(q, k, v, window=window,
                                  interpret=_interpret())
