"""Jit'd wrappers the query engine calls.

`interpret` defaults to True off-TPU (this container validates kernels in
interpret mode); on a real TPU backend the compiled kernels run.
"""
from __future__ import annotations

import jax

from repro.kernels.filter_compact import filter_mask_pallas
from repro.kernels.flash_attn import flash_attention_pallas
from repro.kernels.join_count import join_count_pallas


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def join_count(probe: jax.Array, build_sorted: jax.Array
               ) -> tuple[jax.Array, jax.Array]:
    """(lo, count) per probe key — Pallas probe phase of the sorted join."""
    return join_count_pallas(probe, build_sorted, interpret=_interpret())


def filter_mask(rows: jax.Array, conds: tuple[tuple[int, int], ...]
                ) -> tuple[jax.Array, jax.Array]:
    """(mask, block_counts) for a static conjunction of equalities."""
    return filter_mask_pallas(rows, conds, interpret=_interpret())


def flash_attention(q, k, v, window: int = 0):
    """VMEM-resident flash attention forward (GQA, causal/sliding)."""
    return flash_attention_pallas(q, k, v, window=window,
                                  interpret=_interpret())
