"""Pallas TPU kernel: scatter-append into a padded relation buffer.

Streaming maintenance appends k delta rows to a view extent living in a
(cap, W) capacity-class buffer with n valid rows.  The append must not
change the buffer shape (shape change == recompile of every consumer
bucket), so it is an in-place-style scatter: row r of the output is

    buf[r]          if r < n or r >= n + k        (untouched / scrubbed tail)
    delta[r - n]    if n <= r < n + k             (appended)

n and k are *data* (they change every batch) — they arrive as a (1, 2)
int32 operand so the compiled kernel is reused across batches.  The
gather delta[r - n] is expressed without dynamic indexing: a (BR, DCAP)
one-hot selection mask contracted against the delta buffer column by
column — pure VPU integer ops, no MXU, no scatter primitive.

  grid = (cap // BR,)
  buf tile (BR, W) VMEM + full delta (DCAP, W) VMEM -> out tile (BR, W)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BR = 512


def _make_kernel(br: int, dcap: int, w: int):
    def kernel(nk_ref, buf_ref, rows_ref, out_ref):
        i = pl.program_id(0)
        n = nk_ref[0, 0]
        k = nk_ref[0, 1]
        base = i * br
        # slot j of the delta buffer lands at absolute row n + j
        pos = base + jax.lax.broadcasted_iota(jnp.int32, (br, 1), 0)  # (BR,1)
        slot = pos - n                                                # (BR,1)
        d = jax.lax.broadcasted_iota(jnp.int32, (br, dcap), 1)
        sel = ((d == slot) & (d < k)).astype(jnp.int32)               # (BR,DCAP)
        cols = []
        for c in range(w):
            vals = rows_ref[:, c].reshape(1, dcap)                    # (1,DCAP)
            cols.append(jnp.sum(sel * vals, axis=1, keepdims=True))   # (BR,1)
        appended = jnp.concatenate(cols, axis=1)                      # (BR,W)
        take = (slot >= 0) & (slot < k)                               # (BR,1)
        out_ref[...] = jnp.where(take, appended, buf_ref[...])

    return kernel


@functools.partial(jax.jit, static_argnames=("br", "interpret"))
def scatter_append_pallas(buf: jax.Array, rows: jax.Array, nk: jax.Array,
                          br: int = DEFAULT_BR, interpret: bool = True
                          ) -> jax.Array:
    """Append rows[:k] at position n of buf (n, k = nk[0, 0], nk[0, 1]).

    buf:  (cap, W) int32 capacity-class buffer, -1-scrubbed past n
    rows: (dcap, W) int32 delta buffer; rows at index >= k are ignored
    nk:   (1, 2) int32 — dynamic (n, k), NOT baked into the compilation
    """
    cap, w = buf.shape
    dcap = rows.shape[0]
    br = min(br, cap)
    capp = -(-cap // br) * br
    buf_p = buf if capp == cap else \
        jnp.full((capp, w), -1, dtype=jnp.int32).at[:cap].set(buf)
    out = pl.pallas_call(
        _make_kernel(br, dcap, w),
        grid=(capp // br,),
        in_specs=[
            pl.BlockSpec((1, 2), lambda i: (0, 0)),
            pl.BlockSpec((br, w), lambda i: (i, 0)),
            pl.BlockSpec((dcap, w), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((br, w), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((capp, w), jnp.int32),
        interpret=interpret,
    )(nk, buf_p, rows)
    return out[:cap]
