"""Pallas TPU kernel: sorted-join probe (count + lower bound).

The hot loop of every rewriting is the equi-join probe: for each probe
key l, find `lo = #{s in S : s < l}` and `count = #{s in S : s == l}`
against the sorted build column S.  The numpy/XLA path does two binary
searches; on TPU the branchy search is hostile to the VPU, so we ADAPT
it (paper hot spot -> hardware): a tiled compare-and-accumulate.

  grid = (n_probe_tiles, n_build_tiles)      # build dim is the minor,
                                             # sequential reduction dim
  probe tile (BL,1) VMEM x build tile (BS,1) VMEM
  -> (BL,BS) compare matrix on the VPU, row-reduced into accumulators.

Cost: O(|L|·|S| / tile) compares but perfectly dense vector work, no
data-dependent control flow, and each build tile is streamed HBM->VMEM
exactly once per probe tile.  A block min/max skip (pl.when) prunes
tiles whose key range cannot intersect the probe tile — with sorted
inputs this reduces the effective work to the O(|L| + |S|) merge band.

Key conventions match the engine: valid ids are >= 0; probe slots of
invalid rows carry -1 (they match nothing because build keys are >= 0,
padded with SENTINEL_HI).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BL = 256
DEFAULT_BS = 512


def _kernel(l_ref, s_ref, lo_ref, cnt_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        lo_ref[...] = jnp.zeros_like(lo_ref)
        cnt_ref[...] = jnp.zeros_like(cnt_ref)

    l = l_ref[...]              # (BL, 1)
    s = s_ref[...]              # (BS, 1)
    st = s.reshape(1, -1)       # (1, BS)

    l_min = jnp.min(l)
    l_max = jnp.max(l)
    s_min = st[0, 0]            # sorted tile: first element is the min
    s_max = st[0, -1]

    # tile-range skip: this build tile contributes iff its key range
    # intersects [l_min, l_max] (for counts) or lies below l_max (for lo)
    @pl.when(s_min <= l_max)
    def _accumulate():
        lo_ref[...] += jnp.sum(st < l, axis=1, keepdims=True).astype(jnp.int32)

        @pl.when(s_max >= l_min)
        def _counts():
            cnt_ref[...] += jnp.sum(st == l, axis=1, keepdims=True).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("bl", "bs", "interpret"))
def join_count_pallas(probe: jax.Array, build_sorted: jax.Array,
                      bl: int = DEFAULT_BL, bs: int = DEFAULT_BS,
                      interpret: bool = True) -> tuple[jax.Array, jax.Array]:
    """(lo, count) per probe key against the sorted build column.

    probe: (L,) int32 (invalid slots = -1)
    build_sorted: (S,) int32 ascending (padded with SENTINEL_HI)
    """
    L, S = probe.shape[0], build_sorted.shape[0]
    Lp = -(-L // bl) * bl
    Sp = -(-S // bs) * bs
    # pad probes with -1 (match nothing), build with SENTINEL_HI (sorted)
    probe_p = jnp.full((Lp, 1), -1, dtype=jnp.int32).at[:L, 0].set(probe)
    build_p = jnp.full((Sp, 1), jnp.int32(2**31 - 1), dtype=jnp.int32
                       ).at[:S, 0].set(build_sorted)

    grid = (Lp // bl, Sp // bs)
    lo, cnt = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bl, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((bs, 1), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bl, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((bl, 1), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Lp, 1), jnp.int32),
            jax.ShapeDtypeStruct((Lp, 1), jnp.int32),
        ],
        interpret=interpret,
    )(probe_p, build_p)
    return lo[:L, 0], cnt[:L, 0]
