"""Pallas TPU kernel: selection-cut compensation (predicate mask + block
popcounts).

A selection cut replaces a view constant with a variable; at query time
the rewriting re-applies sigma_{col=c} over the (wider) view extent.
That scan is memory-bound: rows stream HBM->VMEM once, each tile is
evaluated against the (static) conjunction of equality predicates, and a
per-block popcount is emitted so the host/XLA side can prefix-sum the
block counts and gather the compacted survivors without re-reading the
mask twice.

  grid = (n_row_tiles,)
  row tile (BR, W) VMEM -> mask (BR, 1) + one popcount per tile
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BR = 512


def _make_kernel(conds: tuple[tuple[int, int], ...]):
    def kernel(rows_ref, mask_ref, cnt_ref):
        rows = rows_ref[...]                       # (BR, W)
        mask = rows[:, 0:1] >= 0                   # valid rows only
        for col, val in conds:
            mask = mask & (rows[:, col:col + 1] == jnp.int32(val))
        mask_ref[...] = mask.astype(jnp.int32)
        cnt_ref[...] = jnp.sum(mask.astype(jnp.int32), keepdims=True).reshape(1, 1)

    return kernel


@functools.partial(jax.jit, static_argnames=("conds", "br", "interpret"))
def filter_mask_pallas(rows: jax.Array, conds: tuple[tuple[int, int], ...],
                       br: int = DEFAULT_BR, interpret: bool = True
                       ) -> tuple[jax.Array, jax.Array]:
    """(mask, block_counts) for a conjunction of equality predicates.

    rows: (N, W) int32 relation buffer (invalid rows have id -1 in col 0)
    conds: static ((col, value), ...) conjunction
    """
    N, W = rows.shape
    Np = -(-N // br) * br
    rows_p = jnp.full((Np, W), -1, dtype=jnp.int32).at[:N].set(rows)
    grid = (Np // br,)
    mask, counts = pl.pallas_call(
        _make_kernel(conds),
        grid=grid,
        in_specs=[pl.BlockSpec((br, W), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((br, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Np, 1), jnp.int32),
            jax.ShapeDtypeStruct((Np // br, 1), jnp.int32),
        ],
        interpret=interpret,
    )(rows_p)
    return mask[:N, 0], counts[:, 0]
