"""Delta planner: per-view incremental plans derived from the view CQs.

For a view V(x̄) :- a_1, …, a_m and an insert batch Δ⁺, the classic
counting-free delta rule (valid here because wizard views are full
projections — every extent row has a unique derivation) is

    ΔV = ∪_i  π_head( (Δ⁺ ⋉ a_i)  ⋈  a_1 … a_{i-1}, a_{i+1} … a_m )

evaluated over TT' = updated store.  Each `Δ⁺ ⋉ a_i` (the batch rows
unifying with atom i, projected onto the atom's variables) enters the
plan IR as a `ViewRef` with a *pseudo view id keyed by the atom's
renaming-invariant pattern* (`dag._atom_key`), so:

  * isomorphic atoms across views/positions share ONE delta relation
    upload and one DAG leaf,
  * every remaining atom is a plain `TTScan` — shared with other delta
    plans through normal DAG interning,
  * the whole delta workload (all views × all atoms) canonicalizes into
    one `WorkloadDAG` executed in a single device call per batch by the
    same bucketed compiler the serving path uses.

Delta relations are padded to a fixed capacity class (`delta_cap`), so
plan shapes are batch-size-independent: steady-state maintenance hits
the persistent compile cache every batch.

Views whose delta plan would be disconnected (cartesian — only possible
when the view body itself was disconnected, since the delta leaf carries
all of atom i's variables) fall back to the host oracle, exactly like
the serving path does for disconnected rewritings.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.queries import Const, Var
from repro.core.state import State
from repro.query.cost import RelInfo
from repro.query.dag import WorkloadDAG, _atom_key, build_dag
from repro.query.plan import EquiJoin, Plan, Project, TTScan, ViewRef
from repro.views.maintenance import is_full_projection

# pseudo view ids for delta relations live far above real view ids
DELTA_VID_BASE = 1_000_000


@dataclass(frozen=True)
class DeltaLeaf:
    """One shared delta relation: batch rows matching one atom pattern."""

    vid: int                                  # pseudo view id
    key: tuple                                # dag._atom_key of the pattern
    width: int                                # distinct variables
    consts: tuple[tuple[int, int], ...]       # (triple position, id)
    self_eq: tuple[tuple[int, int], ...]      # same-variable positions
    takes: tuple[int, ...]                    # first-occurrence positions

    def match(self, batch: np.ndarray) -> np.ndarray:
        """Project the (k, 3) triple batch onto this pattern's variables:
        unification as a vectorized filter + column take."""
        batch = np.asarray(batch, np.int32).reshape(-1, 3)
        mask = np.ones(len(batch), dtype=bool)
        for pos, cid in self.consts:
            mask &= batch[:, pos] == cid
        for a, b in self.self_eq:
            mask &= batch[:, a] == batch[:, b]
        rows = batch[mask][:, list(self.takes)]
        return np.unique(rows, axis=0) if len(rows) else rows


def _leaf_spec(atom) -> tuple[tuple, tuple, tuple]:
    consts, self_eq, takes = [], [], []
    first: dict[str, int] = {}
    for pos, t in enumerate(atom.terms()):
        if isinstance(t, Const):
            consts.append((pos, t.id))
        elif t.name in first:
            self_eq.append((first[t.name], pos))
        else:
            first[t.name] = pos
            takes.append(pos)
    return tuple(consts), tuple(self_eq), tuple(takes)


def _atom_var_names(atom) -> tuple[str, ...]:
    seen: dict[str, None] = {}
    for t in atom.terms():
        if isinstance(t, Var):
            seen.setdefault(t.name)
    return tuple(seen)


@dataclass
class DeltaPlanSet:
    """Everything the maintainer needs to run one insert batch."""

    plans: dict[str, Plan] = field(default_factory=dict)   # root name -> plan
    root_vid: dict[str, int] = field(default_factory=dict)  # root -> view id
    leaves: dict[tuple, DeltaLeaf] = field(default_factory=dict)  # key -> leaf
    oracle_vids: set[int] = field(default_factory=set)
    dag: WorkloadDAG | None = None

    def leaf_list(self) -> list[DeltaLeaf]:
        return sorted(self.leaves.values(), key=lambda l: l.vid)

    def view_infos(self, expected_batch: int) -> dict[int, RelInfo]:
        """Delta relations look like small key-relations to the cost
        model: `expected_batch` rows, every column near-distinct."""
        exp = float(max(expected_batch, 1))
        return {
            leaf.vid: RelInfo(exp, {i: exp for i in range(leaf.width)})
            for leaf in self.leaves.values()
        }


def delta_plan_for_atom(cq, i: int, leaf: DeltaLeaf) -> Plan | None:
    """Left-deep rest-plan for atom i seeded by its delta leaf, in the
    same greedy connected order as `plan_for_cq`.  Returns None when the
    chain disconnects (cartesian — view body was disconnected)."""
    current: Plan = ViewRef(leaf.vid, _atom_var_names(cq.atoms[i]))
    remaining = [TTScan(a) for j, a in enumerate(cq.atoms) if j != i]
    while remaining:
        cur_cols = set(current.columns())
        pick = None
        for j, p in enumerate(remaining):
            shared = cur_cols & set(p.columns())
            if shared:
                pick = (j, tuple(sorted(shared)))
                break
        if pick is None:
            return None
        j, shared = pick
        nxt = remaining.pop(j)
        current = EquiJoin(current, nxt, tuple((c, c) for c in shared))
    head_cols = tuple(h.name for h in cq.head)
    if head_cols != current.columns():
        current = Project(current, head_cols)
    return current


def build_delta_plans(state: State) -> DeltaPlanSet:
    """One delta plan per (view, atom), sharing leaves and scans through
    a single workload DAG."""
    out = DeltaPlanSet()
    next_vid = DELTA_VID_BASE
    for vid in sorted(state.views):
        cq = state.views[vid].cq
        if not is_full_projection(cq):
            # deletion needs unique derivations; keep the whole view on
            # the oracle (the wizard never produces such views)
            out.oracle_vids.add(vid)
            continue
        atom_plans: list[tuple[str, Plan, DeltaLeaf]] = []
        new_leaves: list[DeltaLeaf] = []
        disconnected = False
        for i, atom in enumerate(cq.atoms):
            key = _atom_key(atom)
            leaf = out.leaves.get(key)
            if leaf is None:
                leaf = next((l for l in new_leaves if l.key == key), None)
            if leaf is None:
                consts, self_eq, takes = _leaf_spec(atom)
                leaf = DeltaLeaf(next_vid, key, len(takes), consts,
                                 self_eq, takes)
                new_leaves.append(leaf)
                next_vid += 1
            plan = delta_plan_for_atom(cq, i, leaf)
            if plan is None:
                disconnected = True
                break
            atom_plans.append((f"v{vid}a{i}", plan, leaf))
        if disconnected:
            out.oracle_vids.add(vid)
            continue
        for name, plan, leaf in atom_plans:
            out.plans[name] = plan
            out.root_vid[name] = vid
            out.leaves.setdefault(leaf.key, leaf)
    if out.plans:
        out.dag = build_dag(out.plans)
    return out
