"""Vectorized host evaluation of delta plans — the CPU insert engine.

The maintainer's insert candidates come from the SAME per-(view, atom)
delta plan IR whichever engine runs it (`delta_plan.py`); this module
evaluates those plans with numpy instead of the device program.  It
exists because the two engines win on different hardware:

  * device (`WorkloadExecutor` over the shared delta DAG): one fused
    call per batch, shapes pinned to capacity classes — amortizes on an
    accelerator, but on CPU every bucket pays eager dispatch overhead
    and every TT scan walks the full padded class;
  * host (this module): dynamic shapes, selective scans, sort-based
    equi-joins — O(batch + matching triples) per plan with small
    constants, no dispatch overhead.

The reference oracle (`query/ref_engine.py`) evaluates the same IR with
a row-at-a-time dict join; this is its vectorized twin for the
maintenance hot path (joins via factorized codes + argsort/searchsorted
instead of python loops), with an empty-seed short-circuit so a batch
that touches no atom of a view never scans the store for that view.
"""
from __future__ import annotations

import numpy as np

from repro.query.plan import EquiJoin, Filter, Plan, Project, TTScan, ViewRef
from repro.query.ref_engine import Relation, scan_atom


def _fused_key(rows: np.ndarray, cols: list[int]) -> np.ndarray | None:
    """One uint64 sort key per row over <= 2 join columns.  Dictionary
    ids are non-negative int32, so two fit side by side; wider keys (or
    out-of-range ids) return None and take the factorization path."""
    if len(cols) > 2 or (len(rows) and int(rows[:, cols].min()) < 0):
        return None
    k = rows[:, cols[0]].astype(np.uint64)
    if len(cols) == 2:
        k = (k << np.uint64(32)) | rows[:, cols[1]].astype(np.uint64)
    return k


def np_equijoin(left: Relation, right: Relation,
                pairs: tuple[tuple[str, str], ...]) -> Relation:
    """Sort-based equi-join: fuse the (multi-column) key over both
    sides, argsort the right, searchsorted the left — no python loops."""
    rights_drop = {r for _, r in pairs}
    out_cols = left.cols + tuple(c for c in right.cols if c not in rights_drop)
    if len(left) == 0 or len(right) == 0 or not pairs:
        from repro.query.ref_engine import _join

        return _join(left, right, pairs)  # degenerate / cartesian cases
    lcols = [left.col_index(a) for a, _ in pairs]
    rcols = [right.col_index(b) for _, b in pairs]
    lc = _fused_key(left.rows, lcols)
    rc = _fused_key(right.rows, rcols)
    if lc is None or rc is None:  # >2 key columns: factorize instead
        lk = np.stack([left.rows[:, i] for i in lcols], axis=1)
        rk = np.stack([right.rows[:, i] for i in rcols], axis=1)
        _, codes = np.unique(np.concatenate([lk, rk]), axis=0,
                             return_inverse=True)
        lc, rc = codes[: len(lk)], codes[len(lk):]
    order = np.argsort(rc, kind="stable")
    rs = rc[order]
    starts = np.searchsorted(rs, lc, side="left")
    counts = np.searchsorted(rs, lc, side="right") - starts
    total = int(counts.sum())
    if total == 0:
        return Relation(np.zeros((0, len(out_cols)), np.int32), out_cols)
    li = np.repeat(np.arange(len(lc)), counts)
    offs = np.arange(total) - np.repeat(np.cumsum(counts) - counts, counts)
    ri = order[np.repeat(starts, counts) + offs]
    keep_right = [i for i, c in enumerate(right.cols) if c not in rights_drop]
    rows = np.concatenate([left.rows[li], right.rows[ri][:, keep_right]],
                          axis=1)
    return Relation(rows, out_cols)


def execute_host(plan: Plan, store,
                 leaves: dict[int, np.ndarray]) -> Relation:
    """Evaluate one delta plan over the store, resolving `ViewRef` leaves
    from the matched delta relations (`leaves`: pseudo-vid -> (k, w)
    rows in the leaf's variable order)."""
    if isinstance(plan, TTScan):
        return scan_atom(store, plan.atom)
    if isinstance(plan, ViewRef):
        return Relation(leaves[plan.view_id], plan.schema)
    if isinstance(plan, Filter):
        child = execute_host(plan.child, store, leaves)
        i = child.col_index(plan.col)
        return Relation(child.rows[child.rows[:, i] == plan.value],
                        child.cols)
    if isinstance(plan, EquiJoin):
        left = execute_host(plan.left, store, leaves)
        if len(left) == 0:
            # delta plans are left-deep over the seed: an empty seed
            # chain can never produce rows — skip the right-side scan
            drops = {r for _, r in plan.pairs}
            cols = left.cols + tuple(c for c in plan.right.columns()
                                     if c not in drops)
            return Relation(np.zeros((0, len(cols)), np.int32), cols)
        right = execute_host(plan.right, store, leaves)
        return np_equijoin(left, right, plan.pairs)
    if isinstance(plan, Project):
        child = execute_host(plan.child, store, leaves)
        idx = [child.col_index(c) for c in plan.cols]
        rows = child.rows[:, idx]
        if plan.dedupe and len(rows):
            rows = np.unique(rows, axis=0)
        return Relation(rows, plan.cols)
    raise TypeError(type(plan))
