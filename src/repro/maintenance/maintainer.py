"""ViewMaintainer: applies streaming deltas to a live QueryExecutor.

Per batch (one device maintenance pass, shapes constant in steady state):

  1. net the batch against the store (effective inserts/deletes);
  2. deletion pass — wizard views are full projections, so a row dies
     iff one of its instantiated atom triples is deleted: a host-side
     membership mask over the extent mirror, applied on device by the
     stable-partition `compact` (per capacity class, one compiled fn);
  3. upload TT' padded to a capacity class (`tt_device_indexes_padded`)
     — scan operand shapes never change while the store grows within
     the class;
  4. insertion pass — delta relations matched per atom pattern, then
     the per-(view, atom) delta plans run on the selected engine:
     "device" pads them to the `delta_cap` class and joins against TT'
     in ONE bucketed workload program for all views (see delta_plan.py,
     shapes batch-independent — the accelerator path); "host" evaluates
     the same plan IR with vectorized numpy joins (host_delta.py —
     selective scans and no dispatch overhead, the CPU path); "auto"
     picks by backend.  Either way the candidates are deduped against
     the extent mirror and appended on device by the Pallas
     scatter-append kernel (`kernels/ops.scatter_append`), growing to
     the next capacity class only when the extent outgrows its headroom
     (amortized: each growth doubles it);
  5. measured maintenance cost (extent rows touched per update triple,
     EWMA) flows into `core.quality.MaintenanceCostModel`, replacing
     the static estimate at the next retune;
  6. the drift detector observes the batch and may recommend a retune.

The executor's host extent mirrors and device buffers stay row-aligned
throughout (appends concatenate, deletes stable-partition on both
sides) — that alignment is what lets the deletion mask be computed on
the host and applied on the device without a gather-back.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quality import MaintenanceCostModel
from repro.core.queries import Const
from repro.errors import InvariantViolation
from repro.kernels import ops as kops
from repro.maintenance.delta_plan import DeltaPlanSet, build_delta_plans
from repro.maintenance.drift import DriftDetector, DriftReport
from repro.maintenance.host_delta import execute_host
from repro.maintenance.stream import Delta
from repro.query import engine as E
from repro.query import ref_engine as R
from repro.query.cost import capacity_for
from repro.query.workload import WorkloadExecutor
from repro.rdf.triples import TripleStore
from repro.views.maintenance import (apply_delta as oracle_apply_delta,
                                     effective_delta, retract_mask)
from repro.views.materializer import measured_info


@dataclass(frozen=True)
class MaintenanceConfig:
    delta_cap: int = 256        # capacity class of delta relations; also
    #                             the insert chunk size (bigger batches
    #                             run as several device passes)
    expected_batch: int = 64    # planning estimate for delta-join sizing
    staleness_budget: int = 0   # serve-path: max pending triples answered
    #                             stale (0 = always fresh)
    growth_safety: float = 2.0  # extent headroom when (re)packing buffers
    tt_safety: float = 1.5      # TT capacity-class headroom
    safety: float = 4.0         # delta-program buffer safety factor
    auto_retune: bool = True    # act on drift reports (server-side)
    drift_window: int = 8
    drift_rate_factor: float = 4.0
    drift_dist_threshold: float = 0.6
    drift_min_triples: int = 64
    insert_engine: str = "auto"  # "device" | "host" | "auto" (by backend)

    def __post_init__(self):
        if self.delta_cap < 1 or self.delta_cap & (self.delta_cap - 1):
            raise ValueError(
                f"delta_cap must be a power of two, got {self.delta_cap}")
        if self.expected_batch < 1:
            raise ValueError("expected_batch must be positive")
        if self.staleness_budget < 0:
            raise ValueError("staleness_budget must be >= 0")
        if self.insert_engine not in ("auto", "device", "host"):
            raise ValueError(
                f"insert_engine must be auto|device|host, "
                f"got {self.insert_engine!r}")


@dataclass
class MaintenanceReport:
    n_inserts: int
    n_deletes: int
    eff_inserts: int
    eff_deletes: int
    appended: dict[int, int] = field(default_factory=dict)
    removed: dict[int, int] = field(default_factory=dict)
    delta_candidates: int = 0
    oracle_views: int = 0
    extents_scanned: int = 0    # deletion pass: extents actually visited
    extent_growths: list[int] = field(default_factory=list)
    tt_grew: bool = False
    seconds: float = 0.0
    drift: DriftReport | None = None

    @property
    def rows_touched(self) -> int:
        return (sum(self.appended.values()) + sum(self.removed.values())
                + self.delta_candidates)

    def summary(self) -> str:
        return (f"delta +{self.eff_inserts}/-{self.eff_deletes} "
                f"(of {self.n_inserts}/{self.n_deletes} requested): "
                f"appended {sum(self.appended.values())}, removed "
                f"{sum(self.removed.values())} extent rows across "
                f"{len(set(self.appended) | set(self.removed))} views "
                f"in {self.seconds * 1e3:.1f}ms"
                + (f"; grew {self.extent_growths}" if self.extent_growths else "")
                + ("; TT class grew" if self.tt_grew else ""))


@jax.jit
def _device_delete(data: jax.Array, keep: jax.Array, overflow: jax.Array
                   ) -> E.PRel:
    return E.compact(data, keep, overflow)


def _rows_in(rows: np.ndarray, reference: np.ndarray) -> np.ndarray:
    """Membership mask for (n, w) int32 rows in a reference relation."""
    rows = np.asarray(rows, np.int32)
    reference = np.asarray(reference, np.int32)
    if len(rows) == 0:
        return np.zeros(0, dtype=bool)
    if len(reference) == 0:
        return np.zeros(len(rows), dtype=bool)
    w = rows.shape[1]
    dt = [(f"f{i}", np.int32) for i in range(w)]
    rv = np.ascontiguousarray(rows).view(dt).reshape(-1)
    fv = np.ascontiguousarray(reference).view(dt).reshape(-1)
    return np.isin(rv, fv)


def _row_bytes(rows: np.ndarray) -> list[bytes]:
    """Each (w,) int32 row as its raw bytes — a hashable key for the
    per-view extent sets (O(1) dedup per candidate, no void sorts)."""
    rows = np.ascontiguousarray(np.asarray(rows, np.int32))
    if len(rows) == 0:
        return []
    return rows.view(f"V{4 * rows.shape[1]}").reshape(-1).tolist()


class ViewMaintainer:
    """Binds to a `QueryExecutor` and maintains its extents in place."""

    def __init__(self, executor, cfg: MaintenanceConfig | None = None,
                 costs: MaintenanceCostModel | None = None):
        self.cfg = cfg or MaintenanceConfig()
        self.costs = costs if costs is not None else MaintenanceCostModel()
        # lifetime telemetry
        self.batches = 0
        self.triples_applied = 0
        self.seconds = 0.0
        self.extent_growths = 0
        self.tt_growths = 0
        self.oracle_batches = 0
        self.delete_scans = 0    # extents visited by deletion passes
        self.drift = None  # type: DriftDetector | None
        self._bind(executor)

    # ------------------------------------------------------------------
    # binding
    # ------------------------------------------------------------------
    def _bind(self, executor) -> None:
        self.executor = executor
        self.plans: DeltaPlanSet = build_delta_plans(executor.state)
        self.engine = self.cfg.insert_engine
        if self.engine == "auto":
            # device wins where the fused batch program amortizes; on
            # CPU its per-bucket dispatch overhead loses to numpy
            self.engine = ("device" if jax.default_backend() != "cpu"
                           else "host")
        self._delta_exec = None
        if self.plans.dag is not None and self.engine == "device":
            self._delta_exec = WorkloadExecutor(
                self.plans.dag, executor.store.stats,
                self.plans.view_infos(self.cfg.expected_batch),
                safety=self.cfg.safety, use_pallas=executor._use_pallas)
        self._repack_extents()
        # per-view extent length at the last statistics recount (the
        # cost model's RelInfo refresh is throttled to material drift)
        self._info_rows = {vid: len(executor.extents[vid].rows)
                           for vid in executor.state.views}
        # hashed extent rows for O(1) candidate dedup, and the host
        # engine's deferred-upload set (one transfer per touched view)
        self._ext_keys = {vid: set(_row_bytes(executor.extents[vid].rows))
                          for vid in executor.state.views}
        # per-predicate inverted index over view extents: the deletion
        # pass only visits extents whose view mentions a deleted
        # predicate (plus views with a variable predicate, which can
        # lose a row on any delete) — sub-linear in the view count
        # instead of scanning every candidate extent per batch
        self._pred_vids: dict[int, set[int]] = {}
        self._wild_vids: set[int] = set()
        for vid, view in executor.state.views.items():
            const_preds = [a.p.id for a in view.cq.atoms
                           if isinstance(a.p, Const)]
            if len(const_preds) < len(view.cq.atoms):
                self._wild_vids.add(vid)
            for p in const_preds:
                self._pred_vids.setdefault(p, set()).add(vid)
        self._dirty: dict[int, int] = {}  # vid -> target capacity
        self.tt_cap = capacity_for(len(executor.store),
                                   safety=self.cfg.tt_safety)
        executor.tt = E.tt_device_indexes_padded(executor.store, self.tt_cap)
        if self.drift is None:
            self.drift = DriftDetector(
                executor.store.stats, window=self.cfg.drift_window,
                rate_factor=self.cfg.drift_rate_factor,
                dist_threshold=self.cfg.drift_dist_threshold,
                min_triples=self.cfg.drift_min_triples)
        else:
            self.drift.reset(executor.store.stats)
        executor.note_maintenance(executor.store)

    def rebind(self, executor=None) -> None:
        """Re-derive delta plans after a retune/hot swap changed the view
        set.  Measured costs survive (keyed by canonical CQ key)."""
        self._bind(executor if executor is not None else self.executor)

    def _repack_extents(self) -> None:
        """Give every extent buffer append headroom: the materializer
        packs at the exact capacity class; growth_safety > 1 repacks so
        the steady state appends in place instead of growing on the
        first batch."""
        ex = self.executor
        for vid, prel in list(ex.device_views.items()):
            rows = ex.extents[vid].rows
            cap = capacity_for(len(rows), safety=self.cfg.growth_safety)
            if cap != prel.cap:
                ex.device_views[vid] = E.make_prel(rows, cap)

    # ------------------------------------------------------------------
    # the per-batch maintenance pass
    # ------------------------------------------------------------------
    def apply(self, delta: Delta) -> MaintenanceReport:
        """One maintenance pass, TRANSACTIONAL: the executor bindings
        (store, TT, extents, device buffers) and this maintainer's
        bookkeeping are snapshotted first; any failure rolls them all
        back and re-raises, so the pre-delta state keeps serving and
        the caller can requeue the delta (`UpdateStream.push_front`).
        Only the measured-cost EWMAs are not rolled back — they are
        telemetry, not serving state."""
        ex = self.executor
        hook = getattr(ex, "fault_hook", None)
        if hook is not None:
            hook.fire("maintenance_apply")
        ex_snap = ex.snapshot()
        keys_snap, rows_snap = dict(self._ext_keys), dict(self._info_rows)
        cap_snap = self.tt_cap
        try:
            return self._apply(delta)
        except Exception:
            ex.restore(ex_snap)
            self._ext_keys, self._info_rows = keys_snap, rows_snap
            self.tt_cap = cap_snap
            self._dirty = {}
            raise

    def _apply(self, delta: Delta) -> MaintenanceReport:
        ex = self.executor
        t0 = time.perf_counter()
        store = ex.store
        eff_ins, eff_del = effective_delta(store, delta.inserts, delta.deletes)
        report = MaintenanceReport(
            n_inserts=len(delta.inserts), n_deletes=len(delta.deletes),
            eff_inserts=len(eff_ins), eff_deletes=len(eff_del),
            oracle_views=len(self.plans.oracle_vids))
        new_store = store.apply_delta(delta.inserts, delta.deletes)

        oracle_vids = self.plans.oracle_vids
        if len(eff_del):
            self._delete_pass(eff_del, oracle_vids, report)

        self._upload_tt(new_store, report)
        ex.note_maintenance(new_store)

        if len(eff_ins):
            self._insert_pass(eff_ins, oracle_vids, report)
        if oracle_vids and (len(eff_ins) or len(eff_del)):
            self._oracle_pass(store, eff_ins, eff_del, oracle_vids, report)
            self.oracle_batches += 1

        # host engine: one padded upload per dirty view for the whole
        # batch (delete + insert passes coalesce into a single transfer)
        for vid, cap in self._dirty.items():
            ex.device_views[vid] = E.make_prel(ex.extents[vid].rows, cap)
        self._dirty.clear()

        self._observe_costs(report)
        report.seconds = time.perf_counter() - t0
        report.drift = self.drift.observe(
            report.eff_inserts + report.eff_deletes,
            np.concatenate([eff_ins[:, 1], eff_del[:, 1]]))
        self.batches += 1
        self.triples_applied += report.eff_inserts + report.eff_deletes
        self.seconds += report.seconds
        self.extent_growths += len(report.extent_growths)
        return report

    # -- deletion ------------------------------------------------------
    def _delete_pass(self, eff_del: np.ndarray, skip: set[int],
                     report: MaintenanceReport) -> None:
        ex = self.executor
        del_preds = set(np.unique(eff_del[:, 1]).tolist())
        # inverted index: only extents whose view can actually lose a
        # row are visited — everything else is never even iterated
        candidates = set(self._wild_vids)
        for p in del_preds:
            candidates |= self._pred_vids.get(p, set())
        for vid in sorted(candidates):
            if vid in skip:
                continue
            view = ex.state.views[vid]
            self.delete_scans += 1
            report.extents_scanned += 1
            rel = ex.extents[vid]
            keep = retract_mask(view.cq, rel.rows, eff_del)
            gone = int(len(keep) - int(keep.sum()))
            if not gone:
                continue
            prel = ex.device_views[vid]
            if self.engine == "host":
                # CPU path: defer to one padded re-upload per touched
                # view at the end of the batch (a memcpy — cheaper than
                # dispatching the compiled compact)
                self._dirty[vid] = prel.cap
            else:
                keep_dev = np.zeros(prel.cap, dtype=bool)
                keep_dev[: len(keep)] = keep
                ex.device_views[vid] = _device_delete(prel.data,
                                                      jnp.asarray(keep_dev),
                                                      prel.overflow)
            # copy-on-write: apply()'s rollback restores a shallow copy
            # of _ext_keys, so entries must be replaced, never mutated
            self._ext_keys[vid] = \
                self._ext_keys[vid] - set(_row_bytes(rel.rows[~keep]))
            ex.extents[vid] = R.Relation(rel.rows[keep], rel.cols)
            report.removed[vid] = gone

    # -- TT upload -----------------------------------------------------
    def _upload_tt(self, new_store: TripleStore,
                   report: MaintenanceReport) -> None:
        if len(new_store) > self.tt_cap:
            self.tt_cap = capacity_for(len(new_store),
                                       safety=self.cfg.tt_safety)
            report.tt_grew = True
            self.tt_growths += 1
        self.executor.tt = E.tt_device_indexes_padded(new_store, self.tt_cap)

    # -- insertion -----------------------------------------------------
    def _insert_pass(self, eff_ins: np.ndarray, skip: set[int],
                     report: MaintenanceReport) -> None:
        if self.engine == "host":
            per_vid = self._insert_candidates_host(eff_ins)
        else:
            per_vid = self._insert_candidates_device(eff_ins)
        ex = self.executor
        for vid, parts in per_vid.items():
            cand = parts[0] if len(parts) == 1 else np.concatenate(parts)
            report.delta_candidates += len(cand)
            seen = self._ext_keys[vid]
            fresh_at, fresh_keys = [], set()
            for i, b in enumerate(_row_bytes(cand)):
                if b in seen or b in fresh_keys:
                    continue
                fresh_keys.add(b)
                fresh_at.append(i)
            if not fresh_at:
                continue
            # copy-on-write (see _delete_pass): replace, never mutate
            self._ext_keys[vid] = seen | fresh_keys
            fresh = cand[np.asarray(fresh_at)]
            self._append_rows(vid, fresh, report)
            report.appended[vid] = len(fresh)

    def _insert_candidates_device(self, eff_ins: np.ndarray
                                  ) -> dict[int, list[np.ndarray]]:
        """One fused bucketed program per `delta_cap` chunk — shapes are
        batch-size-independent, so steady state never recompiles."""
        per_vid: dict[int, list[np.ndarray]] = {}
        if self._delta_exec is None:
            return per_vid
        ex = self.executor
        dcap = self.cfg.delta_cap
        for start in range(0, len(eff_ins), dcap):
            chunk = eff_ins[start: start + dcap]
            dviews = {}
            for leaf in self.plans.leaf_list():
                matched = leaf.match(chunk)
                dviews[leaf.vid] = E.make_prel(matched, dcap)
            roots = self._delta_exec.run(ex.tt, dviews)
            for name, prel in roots.items():
                vid = self.plans.root_vid[name]
                rows = E.to_numpy(prel)
                if len(rows):
                    per_vid.setdefault(vid, []).append(rows)
        return per_vid

    def _insert_candidates_host(self, eff_ins: np.ndarray
                                ) -> dict[int, list[np.ndarray]]:
        """The same delta plans evaluated with vectorized numpy joins —
        dynamic shapes, no chunking, empty-seed plans short-circuit."""
        per_vid: dict[int, list[np.ndarray]] = {}
        store = self.executor.store  # TT' (note_maintenance already ran)
        leaves = {leaf.vid: leaf.match(eff_ins)
                  for leaf in self.plans.leaf_list()}
        for name, plan in self.plans.plans.items():
            rows = execute_host(plan, store, leaves).rows
            if len(rows):
                per_vid.setdefault(self.plans.root_vid[name], []).append(rows)
        return per_vid

    def _append_rows(self, vid: int, rows: np.ndarray,
                     report: MaintenanceReport) -> None:
        """Device scatter-append + host mirror concat, growing the
        capacity class first when headroom runs out."""
        ex = self.executor
        prel = ex.device_views[vid]
        rel = ex.extents[vid]
        k, w = len(rows), prel.width
        merged = np.concatenate([rel.rows, rows])
        if self.engine == "host":
            # CPU path: the host mirror IS current — defer one padded
            # transfer per touched view to the end of the batch; the
            # Pallas kernel only pays off where dispatch amortizes
            cap = self._dirty.get(vid, prel.cap)
            if len(merged) > cap:
                cap = capacity_for(len(merged),
                                   safety=self.cfg.growth_safety)
                report.extent_growths.append(vid)
            self._dirty[vid] = cap
        else:
            n = int(prel.n)
            if n + k > prel.cap:
                new_cap = capacity_for(n + k, safety=self.cfg.growth_safety)
                data = jnp.full((new_cap, w), -1, dtype=jnp.int32)
                data = data.at[: prel.cap].set(prel.data)
                prel = E.PRel(data, prel.n, prel.overflow)
                report.extent_growths.append(vid)
            # delta buffer padded to its own class: few distinct shapes
            rcap = capacity_for(k, safety=1.0)
            rows_p = np.full((rcap, w), -1, dtype=np.int32)
            rows_p[:k] = rows
            data = kops.scatter_append(prel.data, n, jnp.asarray(rows_p), k)
            ex.device_views[vid] = E.PRel(data, jnp.int32(n + k),
                                          prel.overflow)
        ex.extents[vid] = R.Relation(merged, rel.cols)

    # -- oracle fallback (disconnected / non-full-projection views) ----
    def _oracle_pass(self, old_store: TripleStore, eff_ins, eff_del,
                     vids: set[int], report: MaintenanceReport) -> None:
        ex = self.executor
        for vid in sorted(vids):
            cq = ex.state.views[vid].cq
            rel = ex.extents[vid]
            new_rows, _ = oracle_apply_delta(cq, rel.rows, old_store,
                                             eff_ins, eff_del)
            gone = int(len(rel.rows) - _rows_in(rel.rows, new_rows).sum())
            added = int(len(new_rows) - _rows_in(new_rows, rel.rows).sum())
            if added:
                report.appended[vid] = report.appended.get(vid, 0) + added
            if gone:
                report.removed[vid] = report.removed.get(vid, 0) + gone
            if added or gone:
                ex.extents[vid] = R.Relation(new_rows, rel.cols)
                self._ext_keys[vid] = set(_row_bytes(new_rows))
                cap = max(ex.device_views[vid].cap,
                          capacity_for(len(new_rows),
                                       safety=self.cfg.growth_safety))
                ex.device_views[vid] = E.make_prel(new_rows, cap)

    # -- measured cost -------------------------------------------------
    def _observe_costs(self, report: MaintenanceReport) -> None:
        ex = self.executor
        n_upd = max(report.eff_inserts + report.eff_deletes, 1)
        if report.eff_inserts == 0 and report.eff_deletes == 0:
            return
        for vid, view in ex.state.views.items():
            touched = (report.appended.get(vid, 0)
                       + report.removed.get(vid, 0))
            self.costs.observe(view.cq, touched / n_upd)
            if not touched:
                continue
            # recount the extent's distinct statistics only once it has
            # drifted materially — a full recount per batch would put an
            # O(extent) term on the per-batch critical path
            rows = len(ex.extents[vid].rows)
            last = self._info_rows.get(vid, 0)
            if abs(rows - last) > 0.25 * max(last, 1):
                ex.infos[vid] = measured_info(ex.extents[vid])
                self._info_rows[vid] = rows

    # ------------------------------------------------------------------
    def check_alignment(self, vid: int) -> None:
        """Invariant: host mirror rows == device valid prefix, in order."""
        ex = self.executor
        prel = ex.device_views[vid]
        host = ex.extents[vid].rows
        dev = E.to_numpy(prel)
        if len(host) != len(dev) or (len(host) and not (host == dev).all()):
            raise InvariantViolation(
                f"view v{vid}: host extent mirror and device buffer "
                f"diverged ({len(host)} vs {len(dev)} rows)")

    def telemetry(self) -> dict:
        t = {
            "batches": self.batches,
            "triples_applied": self.triples_applied,
            "seconds": self.seconds,
            "extent_growths": self.extent_growths,
            "tt_growths": self.tt_growths,
            "tt_cap": self.tt_cap,
            "oracle_views": len(self.plans.oracle_vids),
            "delete_scans": self.delete_scans,
            "delta_plans": len(self.plans.plans),
            "delta_leaves": len(self.plans.leaves),
            "measured_views": len(self.costs),
            "drift_triggers": self.drift.triggers if self.drift else 0,
            "insert_engine": self.engine,
            "delta_compiles": 0,
            "delta_recompiles": 0,
            "delta_runs": 0,
        }
        if self._delta_exec is not None:
            dt = self._delta_exec.telemetry()
            t["delta_compiles"] = dt.get("compiles", 0)
            t["delta_recompiles"] = dt.get("recompiles", 0)
            t["delta_runs"] = dt.get("runs", 0)
        return t
