"""Streaming incremental view maintenance.

The subsystem that turns the tuner from a one-shot wizard into a system
that survives a write-heavy graph without stopping serving:

  * `UpdateStream` / `Delta` — batched triple insert/delete ingestion
    (stream.py);
  * `build_delta_plans` — per-view incremental plans derived from the
    view CQs, canonicalized into one shared workload DAG (delta_plan.py);
  * `ViewMaintainer` — the per-batch device maintenance pass: host
    membership deletes + Pallas scatter-append inserts over capacity-
    class buffers, measured costs into the quality model (maintainer.py);
  * `DriftDetector` — update-rate and selectivity-shift monitoring that
    recommends a retune (drift.py).

Serving integration lives in `serve/query_server.py` (staleness-bounded
refresh) and `api/session.py` (`TuningSession.ingest`, measured costs at
retune).
"""
from repro.maintenance.delta_plan import (DELTA_VID_BASE, DeltaLeaf,
                                          DeltaPlanSet, build_delta_plans,
                                          delta_plan_for_atom)
from repro.maintenance.drift import DriftDetector, DriftReport
from repro.maintenance.maintainer import (MaintenanceConfig,
                                          MaintenanceReport, ViewMaintainer)
from repro.maintenance.stream import Delta, UpdateStream

__all__ = [
    "DELTA_VID_BASE", "Delta", "DeltaLeaf", "DeltaPlanSet", "DriftDetector",
    "DriftReport", "MaintenanceConfig", "MaintenanceReport", "UpdateStream",
    "ViewMaintainer", "build_delta_plans", "delta_plan_for_atom",
]
