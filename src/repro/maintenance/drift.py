"""Workload-drift detection over the update stream.

The tuned configuration was chosen for the statistics the store had at
retune time.  Two kinds of drift invalidate it:

  * update-rate drift — the stream runs much hotter than when the
    quality function traded maintenance cost against execution cost
    (weights.update_rate), so view maintenance dominates;
  * selectivity drift — the predicate mix of the arriving deltas no
    longer matches the store's predicate distribution, so cardinality
    estimates (and with them view choice) are stale.

Both are measured over a sliding window of observed batches, host-side
and O(batch) per observation — no stats recomputation, no device work.
A triggered report is a *recommendation*; the server acts on it
(`TuningSession.retune()`) and then calls `reset()` with the fresh
statistics.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field

import numpy as np


@dataclass(frozen=True)
class DriftReport:
    triggered: bool
    reason: str            # "" | "update-rate" | "selectivity" | both
    rate_ratio: float      # recent mean batch size / baseline mean
    pred_distance: float   # total-variation distance, window vs store
    window_triples: int

    def summary(self) -> str:
        state = "DRIFT" if self.triggered else "ok"
        return (f"{state}: rate x{self.rate_ratio:.1f}, "
                f"pred-shift {self.pred_distance:.2f} "
                f"over {self.window_triples} triples"
                + (f" ({self.reason})" if self.reason else ""))


class DriftDetector:
    """Sliding-window drift detector.

    The first `window` observed batches freeze the rate baseline; after
    that, a report triggers when the recent-window mean batch size
    exceeds `rate_factor` times the baseline, or when the predicate
    histogram of the windowed deltas sits further than `dist_threshold`
    (total variation, in [0, 1]) from the store's predicate
    distribution — each guarded by `min_triples` so a trickle of odd
    triples cannot force a retune."""

    def __init__(self, stats, window: int = 8, rate_factor: float = 4.0,
                 dist_threshold: float = 0.6, min_triples: int = 64):
        self.window = int(window)
        self.rate_factor = float(rate_factor)
        self.dist_threshold = float(dist_threshold)
        self.min_triples = int(min_triples)
        self._sizes: deque[int] = deque(maxlen=self.window)
        self._preds: deque[dict[int, int]] = deque(maxlen=self.window)
        self._baseline_rate: float | None = None
        self._warmup_sizes: list[int] = []
        self.triggers = 0
        self.observed = 0
        self.reset(stats)

    # ------------------------------------------------------------------
    def reset(self, stats) -> None:
        """Re-baseline against fresh store statistics (post-retune)."""
        total = max(sum(stats.pred_count.values()), 1)
        self._base_pred = {p: c / total for p, c in stats.pred_count.items()}
        self._sizes.clear()
        self._preds.clear()
        self._baseline_rate = None
        self._warmup_sizes = []

    # ------------------------------------------------------------------
    def observe(self, n_triples: int, pred_ids: np.ndarray) -> DriftReport:
        """One maintained batch: its effective size and the predicate ids
        of every inserted/deleted triple."""
        self.observed += 1
        pred_ids = np.asarray(pred_ids).reshape(-1)
        hist: dict[int, int] = {}
        if len(pred_ids):
            vals, counts = np.unique(pred_ids, return_counts=True)
            hist = {int(p): int(c) for p, c in zip(vals, counts)}
        self._sizes.append(int(n_triples))
        self._preds.append(hist)
        if self._baseline_rate is None:
            self._warmup_sizes.append(int(n_triples))
            if len(self._warmup_sizes) >= self.window:
                self._baseline_rate = max(
                    float(np.mean(self._warmup_sizes)), 1.0)
            return DriftReport(False, "", 1.0, 0.0, sum(self._sizes))

        rate_ratio = float(np.mean(self._sizes)) / self._baseline_rate
        merged: dict[int, int] = {}
        for h in self._preds:
            for p, c in h.items():
                merged[p] = merged.get(p, 0) + c
        window_triples = sum(merged.values())
        pred_distance = 0.0
        if window_triples:
            keys = set(merged) | set(self._base_pred)
            pred_distance = 0.5 * sum(
                abs(merged.get(p, 0) / window_triples
                    - self._base_pred.get(p, 0.0))
                for p in keys)

        reasons = []
        if (rate_ratio > self.rate_factor
                and sum(self._sizes) >= self.min_triples):
            reasons.append("update-rate")
        if (pred_distance > self.dist_threshold
                and window_triples >= self.min_triples):
            reasons.append("selectivity")
        triggered = bool(reasons)
        if triggered:
            self.triggers += 1
        return DriftReport(triggered, "+".join(reasons), rate_ratio,
                           pred_distance, sum(self._sizes))
