"""Update stream: batched triple deltas queued for the maintainer.

A `Delta` is one batch of triple inserts and deletes (either side may be
empty).  The `UpdateStream` is the ingestion buffer between writers and
the staleness-bounded serving loop: `QueryServer.submit()` enqueues,
`_maybe_refresh()` drains while the pending backlog exceeds the budget.
Plain host-side bookkeeping — the device work happens in the maintainer.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field

import numpy as np


def _as_triples(arr) -> np.ndarray:
    return (np.zeros((0, 3), np.int32) if arr is None
            else np.asarray(arr, np.int32).reshape(-1, 3))


@dataclass(frozen=True)
class Delta:
    """One update batch.  `size` counts requested changes, before the
    maintainer nets them against the store (duplicate inserts / absent
    deletes may make the effective batch smaller)."""

    inserts: np.ndarray = field(default_factory=lambda: np.zeros((0, 3), np.int32))
    deletes: np.ndarray = field(default_factory=lambda: np.zeros((0, 3), np.int32))

    @staticmethod
    def of(inserts=None, deletes=None) -> "Delta":
        return Delta(_as_triples(inserts), _as_triples(deletes))

    @property
    def size(self) -> int:
        return len(self.inserts) + len(self.deletes)


class UpdateStream:
    """FIFO of pending update batches with backlog accounting."""

    def __init__(self) -> None:
        self._queue: deque[Delta] = deque()
        self.total_pushed = 0      # triples ever submitted
        self.total_batches = 0
        self.total_applied = 0     # triples handed to the maintainer

    def push(self, delta: Delta) -> None:
        if delta.size == 0:
            return
        self._queue.append(delta)
        self.total_pushed += delta.size
        self.total_batches += 1

    def pop(self) -> Delta | None:
        if not self._queue:
            return None
        delta = self._queue.popleft()
        self.total_applied += delta.size
        return delta

    def push_front(self, delta: Delta) -> None:
        """Requeue a delta whose maintenance apply failed: it goes back
        to the head of the queue (sequential semantics preserved) and is
        un-counted from `total_applied` so backlog accounting stays
        truthful while the serving layer reports staleness."""
        if delta.size == 0:
            return
        self._queue.appendleft(delta)
        self.total_applied -= delta.size

    def coalesce(self) -> Delta | None:
        """Pop and merge the whole backlog into ONE net batch (one device
        maintenance pass instead of one per submit), preserving
        sequential semantics: for a triple touched by several batches
        the LAST operation wins (within one batch, insert wins the tie,
        matching `effective_delta`), so applying the coalesced delta
        equals applying the batches in order."""
        from repro.rdf.triples import triple_keys

        if not self._queue:
            return None
        batches = list(self._queue)
        self._queue.clear()
        parts, ops = [], []
        for b in batches:  # within a batch the insert outranks the delete
            parts.extend((b.deletes, b.inserts))
            ops.extend((np.zeros(len(b.deletes), bool),
                        np.ones(len(b.inserts), bool)))
        rows = np.concatenate(parts)
        is_ins = np.concatenate(ops)
        # stable sort by triple key keeps submission order inside each
        # group; the last row of a group is that triple's final op
        order = np.argsort(triple_keys(rows), kind="stable")
        keys = triple_keys(rows)[order]
        last = np.r_[keys[1:] != keys[:-1], np.ones(1, bool)] \
            if len(keys) else np.zeros(0, bool)
        winners = order[last]
        merged = Delta(rows[winners[is_ins[winners]]],
                       rows[winners[~is_ins[winners]]])
        self.total_applied += merged.size
        return merged

    @property
    def pending_batches(self) -> int:
        return len(self._queue)

    @property
    def pending_triples(self) -> int:
        return sum(b.size for b in self._queue)

    def __len__(self) -> int:
        return len(self._queue)
