"""Data pipeline: RDF-backed token streams (+ synthetic fallback).

The integration point between the paper and the LM substrate: training
corpora stored as RDF are served THROUGH the wizard's materialized views
— the pipeline's SPARQL workload is exactly the workload the wizard
tuned for, so data loading hits rewritings instead of raw triple scans.

Verbalization: each answer row of a workload query becomes a pseudo-text
token sequence (entity/relation ids folded into the model vocab), packed
into fixed-length documents.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator

import numpy as np

BOS, EOS, SEP = 1, 2, 3
_RESERVED = 8


@dataclass
class PipelineConfig:
    seq_len: int = 128
    batch_size: int = 8
    vocab: int = 1024
    seed: int = 0


def _fold(ids: np.ndarray, vocab: int) -> np.ndarray:
    """Fold dictionary ids into the model vocab (stable hash)."""
    return (_RESERVED + (ids.astype(np.int64) * 2654435761) % (vocab - _RESERVED)
            ).astype(np.int32)


def verbalize_rows(rows: np.ndarray, vocab: int) -> np.ndarray:
    """(N,W) answer rows -> flat token stream [BOS r0c0 r0c1 .. SEP r1c0 ..]."""
    if len(rows) == 0:
        return np.zeros((0,), np.int32)
    n, w = rows.shape
    folded = _fold(rows.reshape(-1), vocab).reshape(n, w)
    seps = np.full((n, 1), SEP, np.int32)
    return np.concatenate([folded, seps], axis=1).reshape(-1)


class RDFTokenPipeline:
    """Streams training batches from a tuned QueryExecutor."""

    def __init__(self, executor, cfg: PipelineConfig):
        self.cfg = cfg
        # answer arity differs across queries: verbalize per query group
        toks = [np.array([BOS], np.int32)]
        for name in executor.groups:
            ans = sorted(executor.answer_group(name))
            if not ans:
                continue
            toks.append(verbalize_rows(np.asarray(list(ans), np.int32), cfg.vocab))
            toks.append(np.array([EOS, BOS], np.int32))
        self.stream = np.concatenate(toks)
        self.rng = np.random.default_rng(cfg.seed)

    def __iter__(self) -> Iterator[dict]:
        cfg = self.cfg
        need = cfg.batch_size * (cfg.seq_len + 1)
        stream = self.stream
        while len(stream) < need * 2:
            stream = np.concatenate([stream, self.stream])
        pos = 0
        while True:
            if pos + need > len(stream):
                pos = 0
            chunk = stream[pos: pos + need].reshape(cfg.batch_size, cfg.seq_len + 1)
            pos += need
            yield {"tokens": chunk[:, :-1].copy(), "labels": chunk[:, 1:].copy()}


class SyntheticPipeline:
    """Seeded random tokens (shape-compatible stand-in for any arch)."""

    def __init__(self, cfg: PipelineConfig):
        self.cfg = cfg
        self.rng = np.random.default_rng(cfg.seed)

    def __iter__(self) -> Iterator[dict]:
        cfg = self.cfg
        while True:
            toks = self.rng.integers(
                _RESERVED, cfg.vocab,
                size=(cfg.batch_size, cfg.seq_len + 1)).astype(np.int32)
            yield {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
