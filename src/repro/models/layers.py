"""Transformer building blocks: RMSNorm, RoPE/M-RoPE, GQA attention
(global + sliding-window, train + cached decode), SwiGLU MLP, and
capacity-bucketed MoE.

All functions are pure; parameters come from ParamSpec templates.  Logical
sharding axes used here: 'embed' (d_model), 'heads' (q heads * head_dim),
'kv' (kv heads * head_dim), 'mlp' (d_ff), 'expert' (MoE experts),
'vocab'.  Activations are constrained through
distributed/sharding.logical_constraint.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard_act
from repro.models.config import ModelConfig
from repro.models.params import ParamSpec

NEG_INF = -1e30


# ----------------------------------------------------------------------
# norm
# ----------------------------------------------------------------------
def rmsnorm_template(d: int) -> dict:
    return {"scale": ParamSpec((d,), ("embed",), init="ones")}


def rmsnorm(p, x, eps: float):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(dt)


# ----------------------------------------------------------------------
# rotary embeddings
# ----------------------------------------------------------------------
def _rope_freqs(hd: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B,S,H,hd); positions: (B,S) -> rotated x."""
    hd = x.shape[-1]
    freqs = _rope_freqs(hd, theta)                      # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B,S,hd/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jax.Array, positions: jax.Array, theta: float,
                sections: tuple[int, int, int]) -> jax.Array:
    """M-RoPE (qwen2-vl): positions (B,S,3) = (t,h,w); the half-dim rotary
    frequency bands are split into three sections, one per coordinate.
    For text tokens all three coordinates are equal -> reduces to RoPE."""
    hd = x.shape[-1]
    half = hd // 2
    assert sum(sections) == half, (sections, half)
    freqs = _rope_freqs(hd, theta)                      # (half,)
    sec_id = jnp.repeat(
        jnp.arange(3), jnp.asarray(sections), total_repeat_length=half
    )                                                   # (half,) in {0,1,2}
    pos = positions.astype(jnp.float32)[:, :, sec_id]   # (B,S,half)
    ang = pos * freqs
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def _rotate(cfg: ModelConfig, x, positions, theta):
    if cfg.mrope and positions.ndim == 3:
        return apply_mrope(x, positions, theta, cfg.mrope_sections)
    if positions.ndim == 3:
        positions = positions[..., 0]
    return apply_rope(x, positions, theta)


# ----------------------------------------------------------------------
# attention
# ----------------------------------------------------------------------
def attention_template(cfg: ModelConfig, cross: bool = False) -> dict:
    d, hd = cfg.d_model, cfg.hd
    nq, nkv = cfg.n_heads * hd, cfg.n_kv_heads * hd
    t = {
        "norm": rmsnorm_template(d),
        "wq": ParamSpec((d, nq), ("embed", "heads"), init="scaled"),
        "wk": ParamSpec((d, nkv), ("embed", "kv"), init="scaled"),
        "wv": ParamSpec((d, nkv), ("embed", "kv"), init="scaled"),
        "wo": ParamSpec((nq, d), ("heads", "embed"), init="scaled"),
    }
    if cfg.qkv_bias:
        t["bq"] = ParamSpec((nq,), ("heads",), init="zeros")
        t["bk"] = ParamSpec((nkv,), ("kv",), init="zeros")
        t["bv"] = ParamSpec((nkv,), ("kv",), init="zeros")
    return t


def _qkv(p, cfg: ModelConfig, x, kv_src=None):
    B, S, _ = x.shape
    hd = cfg.hd
    kv_src = x if kv_src is None else kv_src
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dh->bsh", kv_src, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dh->bsh", kv_src, p["wv"].astype(x.dtype))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    q = q.reshape(B, S, cfg.n_heads, hd)
    k = k.reshape(B, kv_src.shape[1], cfg.n_kv_heads, hd)
    v = v.reshape(B, kv_src.shape[1], cfg.n_kv_heads, hd)
    return q, k, v


def _gqa_scores(q, k):
    """q: (B,S,H,hd), k: (B,T,Hkv,hd) -> scores (B,Hkv,G,S,T)."""
    B, S, H, hd = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    qg = q.reshape(B, S, Hkv, G, hd)
    return jnp.einsum("bskgh,btkh->bkgst", qg, k) / jnp.sqrt(hd).astype(q.dtype)


def _gqa_out(probs, v):
    """probs: (B,Hkv,G,S,T), v: (B,T,Hkv,hd) -> (B,S,H*hd)."""
    B, Hkv, G, S, T = probs.shape
    out = jnp.einsum("bkgst,btkh->bskgh", probs, v)
    return out.reshape(B, S, Hkv * G * v.shape[-1])


def attention_train(p, cfg: ModelConfig, x, positions, window: int = 0,
                    theta: float | None = None, kv_src=None, causal=True,
                    return_kv: bool = False):
    """Full-sequence attention; window>0 = sliding window; kv_src set =
    cross attention (no mask, no rope on kv positions mismatch).

    cfg.attn_impl == "chunked" uses the flash-style online-softmax path
    (O(S*chunk) live score memory instead of O(S^2))."""
    y = rmsnorm(p["norm"], x, cfg.norm_eps)
    kv_in = rmsnorm(p["norm"], kv_src, cfg.norm_eps) if kv_src is not None else None
    q, k, v = _qkv(p, cfg, y, kv_in)
    th = theta if theta is not None else cfg.rope_theta
    cross = kv_src is not None
    if not cross:
        q = _rotate(cfg, q, positions, th)
        k = _rotate(cfg, k, positions, th)
    if (cfg.attn_impl == "chunked" and not cross and causal
            and q.shape[1] == k.shape[1] and q.shape[1] % cfg.attn_chunk == 0):
        out = _chunked_attention(q, k, v, cfg.n_kv_heads, window,
                                 cfg.attn_chunk)
    else:
        scores = _gqa_scores(q, k).astype(jnp.float32)
        S, T = scores.shape[-2], scores.shape[-1]
        if causal and not cross:
            i = jax.lax.broadcasted_iota(jnp.int32, (S, T), 0)
            j = jax.lax.broadcasted_iota(jnp.int32, (S, T), 1)
            mask = j <= i
            if window > 0:
                mask = mask & (j > i - window)
            scores = jnp.where(mask, scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
        out = _gqa_out(probs, v)
    proj = jnp.einsum("bsh,hd->bsd", out, p["wo"].astype(x.dtype))
    if return_kv:
        return proj, (k, v)
    return proj


def _chunked_attention(q, k, v, n_kv: int, window: int, chunk: int):
    """Flash-style causal attention, classic loop order: outer scan over
    Q chunks, inner scan over KV chunks with a SMALL online-softmax carry
    (m, l, acc of one q-chunk) — only (chunk x chunk) scores and a
    q-chunk-sized accumulator are ever live (the Pallas-kernel schedule,
    expressed in XLA loops).

    q: (B,S,H,hd); k,v: (B,S,Hkv,hd) -> (B,S,H*hd)
    """
    B, S, H, hd = q.shape
    G = H // n_kv
    nq = nk = S // chunk
    qc = (q.reshape(B, nq, chunk, n_kv, G, hd).astype(jnp.float32)
          / jnp.sqrt(hd)).transpose(1, 0, 2, 3, 4, 5)   # (nq,B,Cq,kv,G,hd)
    kc = k.reshape(B, nk, chunk, n_kv, hd).astype(jnp.float32
                                                  ).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, nk, chunk, n_kv, hd).astype(jnp.float32
                                                  ).transpose(1, 0, 2, 3, 4)
    rel = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) \
        - jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)

    def q_block(_, qi_i):
        qi, i = qi_i                                     # (B,Cq,kv,G,hd)

        def kv_step(carry, kj_vj_j):
            m, l, acc = carry                # (B,kv,G,Cq) x2, (B,kv,G,Cq,hd)
            kj, vj, j = kj_vj_j
            s = jnp.einsum("bskgh,btkh->bkgst", qi, kj)  # (B,kv,G,Cq,Ck)
            delta = (i - j) * chunk + rel                # q_pos - k_pos
            mask = delta >= 0
            if window > 0:
                mask = mask & (delta < window)
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            scale = jnp.exp(m - m_new)
            l_new = l * scale + jnp.sum(p, axis=-1)
            acc_new = acc * scale[..., None] + jnp.einsum(
                "bkgst,btkh->bkgsh", p, vj)
            return (m_new, l_new, acc_new), None

        init = (
            jnp.full((B, n_kv, G, chunk), NEG_INF, jnp.float32),
            jnp.zeros((B, n_kv, G, chunk), jnp.float32),
            jnp.zeros((B, n_kv, G, chunk, hd), jnp.float32),
        )
        (m, l, acc), _ = jax.lax.scan(
            kv_step, init, (kc, vc, jnp.arange(nk, dtype=jnp.int32)))
        out = acc / jnp.maximum(l, 1e-30)[..., None]     # (B,kv,G,Cq,hd)
        return None, out.transpose(0, 3, 1, 2, 4)        # (B,Cq,kv,G,hd)

    _, outs = jax.lax.scan(q_block, None,
                           (qc, jnp.arange(nq, dtype=jnp.int32)))
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, S, H * hd)
    return out.astype(q.dtype)


def kv_into_cache(k, v, cache_len: int, window: int = 0):
    """Pack full-sequence K/V (B,S,kv,hd) into a decode cache buffer.

    Full attention: positions [0,S) land at slots [0,S) of a cache of
    length cache_len >= S.  Sliding window (rolling cache of length
    T=min(window, cache_len)): slot p % T holds position p, keeping the
    last T positions — exactly the decode-side convention."""
    B, S, kv, hd = k.shape
    if window > 0:
        T = min(window, cache_len)
        take = min(T, S)
        idx = (jnp.arange(S - take, S, dtype=jnp.int32)) % T
        ck = jnp.zeros((B, T, kv, hd), jnp.bfloat16).at[:, idx].set(
            k[:, S - take:].astype(jnp.bfloat16))
        cv = jnp.zeros((B, T, kv, hd), jnp.bfloat16).at[:, idx].set(
            v[:, S - take:].astype(jnp.bfloat16))
        return ck, cv
    assert cache_len >= S, (cache_len, S)
    pad = cache_len - S
    ck = jnp.pad(k.astype(jnp.bfloat16), ((0, 0), (0, pad), (0, 0), (0, 0)))
    cv = jnp.pad(v.astype(jnp.bfloat16), ((0, 0), (0, pad), (0, 0), (0, 0)))
    return ck, cv


def attention_decode(p, cfg: ModelConfig, x, pos, cache: dict,
                     window: int = 0, theta: float | None = None):
    """One-token decode with a (possibly rolling) KV cache.

    x: (B,1,d); pos: scalar int32 (current position, 0-based)
    cache: {"k","v": (B, T_cache, Hkv, hd)}; rolling iff window>0
    (slot = pos % T_cache holds position pos).
    """
    y = rmsnorm(p["norm"], x, cfg.norm_eps)
    q, k, v = _qkv(p, cfg, y)
    th = theta if theta is not None else cfg.rope_theta
    B = x.shape[0]
    pos_b = jnp.full((B, 1), pos, dtype=jnp.int32)
    if cfg.mrope:
        pos_b = jnp.broadcast_to(pos_b[..., None], (B, 1, 3))
    q = _rotate(cfg, q, pos_b, th)
    k = _rotate(cfg, k, pos_b, th)
    T = cache["k"].shape[1]
    slot = (pos % T).astype(jnp.int32) if isinstance(pos, jax.Array) else pos % T
    ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), slot, axis=1)
    cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), slot, axis=1)
    scores = _gqa_scores(q, ck).astype(jnp.float32)     # (B,Hkv,G,1,T)
    j = jnp.arange(T, dtype=jnp.int32)
    if window > 0:
        # slot t holds position pos - ((pos - t) mod T); valid if within window
        cache_pos = pos - jnp.mod(pos - j, T)
        valid = (cache_pos >= 0) & (cache_pos > pos - window) & (cache_pos <= pos)
    else:
        valid = j <= pos
    scores = jnp.where(valid[None, None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = _gqa_out(probs, cv)
    proj = jnp.einsum("bsh,hd->bsd", out, p["wo"].astype(x.dtype))
    return proj, {"k": ck, "v": cv}


# ----------------------------------------------------------------------
# MLP (SwiGLU)
# ----------------------------------------------------------------------
def mlp_template(cfg: ModelConfig) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    return {
        "norm": rmsnorm_template(d),
        "w_gate": ParamSpec((d, f), ("embed", "mlp"), init="scaled"),
        "w_up": ParamSpec((d, f), ("embed", "mlp"), init="scaled"),
        "w_down": ParamSpec((f, d), ("mlp", "embed"), init="scaled"),
    }


def mlp(p, cfg: ModelConfig, x):
    y = rmsnorm(p["norm"], x, cfg.norm_eps)
    g = jnp.einsum("bsd,df->bsf", y, p["w_gate"].astype(x.dtype))
    u = jnp.einsum("bsd,df->bsf", y, p["w_up"].astype(x.dtype))
    h = jax.nn.silu(g) * u
    return jnp.einsum("bsf,fd->bsd", h, p["w_down"].astype(x.dtype))


# ----------------------------------------------------------------------
# MoE (token-choice top-k, capacity-bucketed dispatch)
# ----------------------------------------------------------------------
def moe_template(cfg: ModelConfig) -> dict:
    d, f, m = cfg.d_model, cfg.d_ff, cfg.moe
    t = {
        "norm": rmsnorm_template(d),
        "router": ParamSpec((d, m.n_experts), ("embed", "expert"), init="scaled"),
        "w_gate": ParamSpec((m.n_experts, d, f), ("expert", "embed", "mlp"), init="scaled"),
        "w_up": ParamSpec((m.n_experts, d, f), ("expert", "embed", "mlp"), init="scaled"),
        "w_down": ParamSpec((m.n_experts, f, d), ("expert", "mlp", "embed"), init="scaled"),
    }
    if m.n_shared_experts:
        fs = f * m.n_shared_experts
        t["ws_gate"] = ParamSpec((d, fs), ("embed", "mlp"), init="scaled")
        t["ws_up"] = ParamSpec((d, fs), ("embed", "mlp"), init="scaled")
        t["ws_down"] = ParamSpec((fs, d), ("mlp", "embed"), init="scaled")
    return t


def moe(p, cfg: ModelConfig, x):
    """Token-choice top-k MoE.

    Two paths with identical routing semantics:
      * outside a distribution context: single-device capacity-bucketed
        dispatch (sort by expert, rank, scatter, grouped einsum),
      * inside `axis_ctx`: explicit expert parallelism via shard_map —
        experts live on the 'expert' mesh axes, every device routes ITS
        token shard to its local experts, and one psum over the expert
        axes combines the outputs (GSPMD's auto-partitioner refuses to
        split the grouped einsum on its own — measured in §Perf).
    """
    from repro.distributed.sharding import active_ctx, mesh_axes_of

    ctx = active_ctx()
    if ctx is not None and mesh_axes_of("expert"):
        return _moe_expert_parallel(p, cfg, x, ctx)
    return _moe_dense(p, cfg, x)


def _moe_dense(p, cfg: ModelConfig, x):
    m = cfg.moe
    B, S, d = x.shape
    T = B * S
    y = rmsnorm(p["norm"], x, cfg.norm_eps)
    flat = y.reshape(T, d)

    logits = jnp.einsum("td,de->te", flat, p["router"].astype(x.dtype))
    logits = shard_act(logits, ("batch", None))
    gates, idx = jax.lax.top_k(logits, m.top_k)             # (T,k)
    gates = jax.nn.softmax(gates.astype(jnp.float32), axis=-1).astype(x.dtype)

    k = m.top_k
    E = m.n_experts
    cap = int(max(1, round(T * k / E * m.capacity_factor)))
    pair_e = idx.reshape(T * k)
    pair_t = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k, total_repeat_length=T * k)
    pair_g = gates.reshape(T * k)

    order = jnp.argsort(pair_e)
    se, st_, sg = pair_e[order], pair_t[order], pair_g[order]
    grp_start = jnp.searchsorted(se, se, side="left")
    rank = jnp.arange(T * k, dtype=jnp.int32) - grp_start.astype(jnp.int32)
    keep = rank < cap
    slot = jnp.where(keep, se * cap + rank, E * cap)

    # expert-parallel dispatch: the (E, cap, d) buffer is sharded over the
    # 'expert' logical axis; slot ids are expert-major so the scatter
    # routes token rows to the expert's shard (GSPMD emits the all-to-all)
    xbuf = jnp.zeros((E * cap + 1, d), x.dtype).at[slot].set(flat[st_])
    xbuf = shard_act(xbuf[:-1].reshape(E, cap, d), ("expert", None, None))
    g = jnp.einsum("ecd,edf->ecf", xbuf, p["w_gate"].astype(x.dtype))
    u = jnp.einsum("ecd,edf->ecf", xbuf, p["w_up"].astype(x.dtype))
    h = shard_act(jax.nn.silu(g) * u, ("expert", None, None))
    out = jnp.einsum("ecf,efd->ecd", h, p["w_down"].astype(x.dtype))
    out = shard_act(out, ("expert", None, None))
    out_flat = out.reshape(E * cap, d)
    gathered = out_flat[jnp.clip(slot, 0, E * cap - 1)]
    contrib = jnp.where(keep[:, None], gathered * sg[:, None], 0)
    combined = jnp.zeros((T, d), x.dtype).at[st_].add(contrib)
    combined = shard_act(combined, ("batch", None))

    if m.n_shared_experts:
        gs = jnp.einsum("td,df->tf", flat, p["ws_gate"].astype(x.dtype))
        us = jnp.einsum("td,df->tf", flat, p["ws_up"].astype(x.dtype))
        combined = combined + jnp.einsum(
            "tf,fd->td", jax.nn.silu(gs) * us, p["ws_down"].astype(x.dtype))
    return combined.reshape(B, S, d)


def _moe_expert_parallel(p, cfg: ModelConfig, x, ctx):
    """shard_map expert parallelism.

    Layout: experts sharded over the 'expert' mesh axes (weights
    replicated across the batch axes); tokens sharded over the batch
    axes (replicated across expert axes).  Each device routes its local
    tokens to its local experts; one psum over the expert axes yields
    the combined output — per layer wire cost = |tokens_loc x d|.
    """
    from jax.sharding import PartitionSpec as P

    from repro.distributed.sharding import mesh_axes_of, spec_for

    mesh, rules = ctx
    m = cfg.moe
    B, S, d = x.shape
    ep_axes = mesh_axes_of("expert")
    batch_axes = mesh_axes_of("batch")
    n_ep = 1
    for a in ep_axes:
        n_ep *= mesh.shape[a]
    n_dp = 1
    for a in batch_axes:
        n_dp *= mesh.shape[a]
    E = m.n_experts
    if E % n_ep != 0:
        return _moe_dense(p, cfg, x)
    E_loc = E // n_ep
    T_loc = max(B * S // n_dp, 1)
    k = m.top_k
    cap = int(max(1, -(-T_loc * k * m.capacity_factor // E)))

    x_spec = P(batch_axes if batch_axes else None)
    w_spec = P(ep_axes if len(ep_axes) > 1 else (ep_axes[0] if ep_axes else None))

    def body(norm_scale, router, wg, wu, wd, shared_w, xin):
        T = xin.shape[0]
        y = rmsnorm({"scale": norm_scale}, xin, cfg.norm_eps)
        logits_loc = jnp.einsum("td,de->te", y, router.astype(y.dtype))
        logits = logits_loc
        for a in ep_axes:
            logits = jax.lax.all_gather(logits, a, axis=1, tiled=True)
        gates, idx = jax.lax.top_k(logits, k)
        gates = jax.nn.softmax(gates.astype(jnp.float32), axis=-1).astype(y.dtype)

        ep_rank = jnp.int32(0)
        for a in ep_axes:
            ep_rank = ep_rank * mesh.shape[a] + jax.lax.axis_index(a)
        lo = ep_rank * E_loc

        pair_e = idx.reshape(T * k)
        pair_t = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k,
                            total_repeat_length=T * k)
        pair_g = gates.reshape(T * k)
        local = (pair_e >= lo) & (pair_e < lo + E_loc)
        le = jnp.where(local, pair_e - lo, E_loc)     # E_loc = drop bucket
        order = jnp.argsort(le)
        se, st_, sg = le[order], pair_t[order], pair_g[order]
        grp = jnp.searchsorted(se, se, side="left")
        rank = jnp.arange(T * k, dtype=jnp.int32) - grp.astype(jnp.int32)
        keep = (se < E_loc) & (rank < cap)
        slot = jnp.where(keep, se * cap + rank, E_loc * cap)

        # slot-space dispatch: build the slot->token map (int32 only) and
        # keep every d-wide tensor at E_loc*cap rows instead of T*k rows
        # (k-fold smaller HBM traffic than pair-space gathers)
        n_slots = E_loc * cap
        tok_fs = jnp.full((n_slots + 1,), T, jnp.int32).at[slot].set(st_)[:-1]
        gate_fs = jnp.zeros((n_slots + 1,), y.dtype).at[slot].set(sg)[:-1]
        filled = tok_fs < T
        xbuf = jnp.where(filled[:, None],
                         y[jnp.clip(tok_fs, 0, T - 1)], 0)
        xbuf = xbuf.reshape(E_loc, cap, -1)
        g = jnp.einsum("ecd,edf->ecf", xbuf, wg.astype(y.dtype))
        u = jnp.einsum("ecd,edf->ecf", xbuf, wu.astype(y.dtype))
        out = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u,
                         wd.astype(y.dtype))
        out_flat = out.reshape(n_slots, -1)
        contrib = out_flat * gate_fs[:, None]
        combined = jnp.zeros((T, y.shape[1]), y.dtype).at[
            jnp.clip(tok_fs, 0, T - 1)].add(
            jnp.where(filled[:, None], contrib, 0))

        if shared_w is not None:
            ws_g, ws_u, ws_d = shared_w
            gs = jnp.einsum("td,df->tf", y, ws_g.astype(y.dtype))
            us = jnp.einsum("td,df->tf", y, ws_u.astype(y.dtype))
            combined = combined + jnp.einsum(
                "tf,fd->td", jax.nn.silu(gs) * us, ws_d.astype(y.dtype))
        for a in ep_axes:
            combined = jax.lax.psum(combined, a)
        return combined

    shared_w = None
    shared_specs = None
    if m.n_shared_experts:
        # shared experts: shard d_ff over the expert axes (TP), psum folds
        # the partial down-projections into the same combine reduction
        fs_spec = P(None, w_spec[0]) if ep_axes else P()
        shared_w = (p["ws_gate"], p["ws_up"], p["ws_down"])
        shared_specs = (fs_spec, fs_spec, P(fs_spec[1], None))

    in_specs = (
        P(),                                  # norm scale
        P(None, w_spec[0]) if ep_axes else P(),  # router: experts local
        w_spec, w_spec, w_spec,               # expert weights
        shared_specs,                         # shared experts (or None)
        P(*(x_spec + (None,))),               # tokens (T_loc, d)
    )
    from repro.distributed.sharding import shard_map_compat

    smapped = shard_map_compat(
        body, mesh=mesh,
        in_specs=in_specs,
        out_specs=P(*(x_spec + (None,))),
    )
    flat = x.reshape(B * S, d)
    out = smapped(p["norm"]["scale"], p["router"], p["w_gate"], p["w_up"],
                  p["w_down"], shared_w, flat)
    return out.reshape(B, S, d)
