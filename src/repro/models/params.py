"""Parameter templates: shapes + logical sharding axes + initializers.

A model is described as a pytree of `ParamSpec`s; the same template
yields (i) materialized params, (ii) ShapeDtypeStructs for the dry-run
(no allocation), and (iii) NamedShardings via the logical-axis rules
(distributed/sharding.py).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]   # logical axis name per dim
    init: str = "normal"           # normal | zeros | ones | scaled
    scale: float = 1.0

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_spec(x: Any) -> bool:
    return isinstance(x, ParamSpec)


def tree_shapes(template, dtype=jnp.float32):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, dtype), template, is_leaf=is_spec
    )


def tree_axes(template):
    return jax.tree.map(lambda s: s.axes, template, is_leaf=is_spec)


def init_params(template, key: jax.Array, dtype=jnp.float32):
    leaves, treedef = jax.tree.flatten(template, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))

    def mk(spec: ParamSpec, k):
        if spec.init == "zeros":
            return jnp.zeros(spec.shape, dtype)
        if spec.init == "ones":
            return jnp.ones(spec.shape, dtype)
        if spec.init == "scaled":  # fan-in scaled
            fan_in = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
            std = spec.scale / math.sqrt(max(fan_in, 1))
            return (jax.random.normal(k, spec.shape, dtype) * std).astype(dtype)
        return (jax.random.normal(k, spec.shape, dtype) * 0.02 * spec.scale).astype(dtype)

    return jax.tree.unflatten(treedef, [mk(s, k) for s, k in zip(leaves, keys)])


def count_params(template) -> int:
    leaves = jax.tree.leaves(template, is_leaf=is_spec)
    return sum(int(math.prod(s.shape)) for s in leaves)
