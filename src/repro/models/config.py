"""Model configuration: one composable decoder framework, ten architectures.

`block_pattern` describes one *layer group*; the stack is
`n_groups = n_layers / len(block_pattern)` groups, scanned with
`lax.scan` over stacked group parameters (compact HLO, fast compiles for
95-layer models).  Block types:

  attn          global causal attention (GQA)
  swa           sliding-window causal attention (window=cfg.window)
  mamba2        Mamba2 SSD block (chunked scan)
  rwkv6         RWKV6 (Finch) time-mix + channel-mix
  mamba2_shared mamba2 block followed by the SHARED attention block
                (zamba2: one weight copy applied at every occurrence)
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25
    n_shared_experts: int = 0  # always-active experts (llama4-style)


@dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 64       # mamba2 N
    head_dim: int = 64        # mamba2 P / rwkv6 head size
    n_heads: int = 0          # 0 -> derived: d_inner // head_dim
    expand: int = 2           # d_inner = expand * d_model
    d_conv: int = 4           # mamba2 depthwise conv window
    chunk: int = 64           # chunked-scan block length


@dataclass(frozen=True)
class EncoderConfig:
    n_layers: int = 6
    d_input: int = 80         # mel bins (stub frontend projects to d_model)
    max_len: int = 1500


@dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0                      # 0 -> d_model // n_heads
    block_pattern: tuple[str, ...] = ("attn",)
    window: int = 0                        # swa window
    moe: MoEConfig | None = None
    ssm: SSMConfig | None = None
    encoder: EncoderConfig | None = None   # enc-dec (whisper)
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    rope_theta_local: float = 10_000.0  # swa layers (gemma3: 10k vs 1M)
    mrope: bool = False                    # 3-section M-RoPE (qwen2-vl)
    mrope_sections: tuple[int, int, int] = (16, 24, 24)
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    max_seq: int = 8192                    # serving cache default
    attn_impl: str = "dense"               # dense | chunked (flash-style
                                           # online softmax, O(S*C) memory)
    attn_chunk: int = 1024                 # kv/q chunk for attn_impl=chunked
    # which families support >=500k decode (sub-quadratic / windowed)
    long_context: bool = False
    notes: str = ""

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def vocab_padded(self) -> int:
        """Vocab rounded up to a multiple of 128 so the embedding /
        lm_head shard evenly on any production mesh axis; padded logit
        columns are masked to -inf (standard vocab padding)."""
        return -(-self.vocab // 128) * 128

    @property
    def n_groups(self) -> int:
        assert self.n_layers % len(self.block_pattern) == 0, (
            self.name, self.n_layers, self.block_pattern)
        return self.n_layers // len(self.block_pattern)

    def param_count(self) -> int:
        """Analytic parameter count (drives MODEL_FLOPS in the roofline)."""
        d, hd = self.d_model, self.hd
        n_q = self.n_heads * hd
        n_kv = self.n_kv_heads * hd
        per_type: dict[str, int] = {}
        attn = d * n_q + 2 * d * n_kv + n_q * d
        if self.qkv_bias:
            attn += n_q + 2 * n_kv
        if self.moe is not None:
            ff = self.moe.n_experts * 3 * d * self.d_ff + d * self.moe.n_experts
            ff += self.moe.n_shared_experts * 3 * d * self.d_ff
        else:
            ff = 3 * d * self.d_ff
        per_type["attn"] = attn + ff + 2 * d
        per_type["swa"] = per_type["attn"]
        if self.ssm is not None:
            s = self.ssm
            d_in = s.expand * d
            nh = s.n_heads or d_in // s.head_dim
            conv_dim = d_in + 2 * s.state_dim
            # mamba2: in_proj (z,x,B,C,dt) + conv(w,b) + A/dt/D + norms + out
            per_type["mamba2"] = (
                d * (2 * d_in + 2 * s.state_dim + nh)
                + (s.d_conv + 1) * conv_dim + 3 * nh + d_in + d + d_in * d
            )
            per_type["mamba2_shared"] = per_type["mamba2"]
        if "rwkv6" in self.block_pattern:
            # time-mix (r,k,v,g,o + decay lora) + relu^2 channel-mix
            per_type["rwkv6"] = 6 * d * d + 2 * d * 64 + 2 * d * self.d_ff + 12 * d
        total = 0
        for b in self.block_pattern:
            total += per_type[b]
        total *= self.n_groups
        if "mamba2_shared" in self.block_pattern:
            total += per_type["attn"]  # one shared attention+mlp block
        total += self.vocab_padded * d  # embedding
        if not self.tie_embeddings:
            total += self.vocab_padded * d
        if self.encoder is not None:
            e = self.encoder
            total += e.n_layers * (4 * d * d + 3 * d * self.d_ff + 2 * d)
            total += e.d_input * d + e.max_len * d  # frontend stub + positions
            # decoder cross-attention (added per decoder layer)
            total += self.n_layers * (4 * d * d + d)
        return int(total)

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: only top-k experts)."""
        if self.moe is None:
            return self.param_count()
        d = self.d_model
        full_ff = self.moe.n_experts * 3 * d * self.d_ff
        active_ff = (self.moe.top_k + self.moe.n_shared_experts) * 3 * d * self.d_ff
        return int(self.param_count() - self.n_layers * (full_ff - active_ff))
