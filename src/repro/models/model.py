"""Model facade: template + init + jit-able entry points per config."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.models.params import init_params, tree_axes, tree_shapes


@dataclass
class Model:
    cfg: ModelConfig

    def __post_init__(self):
        self.template = T.model_template(self.cfg)

    # ------------------------------------------------------------------
    def init(self, key: jax.Array, dtype=jnp.float32):
        return init_params(self.template, key, dtype)

    def param_shapes(self, dtype=jnp.bfloat16):
        return tree_shapes(self.template, dtype)

    def param_axes(self):
        return tree_axes(self.template)

    # ------------------------------------------------------------------
    def forward(self, params, tokens=None, embeds=None, positions=None,
                enc_frames=None, remat: str = "none"):
        return T.forward(self.cfg, params, tokens=tokens, embeds=embeds,
                         positions=positions, enc_frames=enc_frames,
                         remat=remat)

    def decode_step(self, params, token, pos, cache):
        return T.decode_step(self.cfg, params, token, pos, cache)

    def prefill_with_cache(self, params, tokens=None, embeds=None,
                           positions=None, enc_frames=None,
                           cache_len: int = 0):
        return T.prefill_with_cache(self.cfg, params, tokens=tokens,
                                    embeds=embeds, positions=positions,
                                    enc_frames=enc_frames,
                                    cache_len=cache_len)

    def cache_shapes(self, batch: int, cache_len: int, enc_len: int = 0):
        return T.cache_template(self.cfg, batch, cache_len, enc_len)

    def cache_axes(self):
        return T.cache_logical_axes(self.cfg)

    def init_cache(self, batch: int, cache_len: int, enc_len: int = 0):
        return jax.tree.map(
            lambda sds: jnp.zeros(sds.shape, sds.dtype),
            self.cache_shapes(batch, cache_len, enc_len),
            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
        )


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
