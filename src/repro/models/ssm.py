"""Attention-free blocks: Mamba2 (SSD, chunked) and RWKV6 (Finch).

Mamba2 uses the chunked SSD algorithm (intra-chunk quadratic matmuls +
inter-chunk state scan): MXU-dense work instead of a length-T sequential
loop — the TPU-native adaptation.  RWKV6's per-channel data-dependent
decay does not factor into chunk matmuls, so training uses a time scan
(`lax.scan`, compact HLO); decode is O(1)-state for both.

Decode state:
  mamba2: {"ssm": (B, nh, P, N), "conv": (B, d_conv-1, conv_dim)}
  rwkv6:  {"wkv": (B, H, hd, hd), "shift_t": (B, d), "shift_c": (B, d)}
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import rmsnorm, rmsnorm_template
from repro.models.params import ParamSpec


# ======================================================================
# Mamba2
# ======================================================================
def mamba2_dims(cfg: ModelConfig):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    nh = s.n_heads or d_in // s.head_dim
    conv_dim = d_in + 2 * s.state_dim
    return d_in, nh, conv_dim


def mamba2_template(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    s = cfg.ssm
    d_in, nh, conv_dim = mamba2_dims(cfg)
    return {
        "norm": rmsnorm_template(d),
        # in_proj -> [z, x, B, C, dt]
        "w_in": ParamSpec((d, 2 * d_in + 2 * s.state_dim + nh),
                          ("embed", "mlp"), init="scaled"),
        "conv_w": ParamSpec((s.d_conv, conv_dim), (None, "mlp"), init="scaled"),
        "conv_b": ParamSpec((conv_dim,), ("mlp",), init="zeros"),
        "a_log": ParamSpec((nh,), (None,), init="zeros"),
        "dt_bias": ParamSpec((nh,), (None,), init="zeros"),
        "d_skip": ParamSpec((nh,), (None,), init="ones"),
        "gate_norm": rmsnorm_template(d_in),
        "w_out": ParamSpec((d_in, d), ("mlp", "embed"), init="scaled"),
    }


def _split_in(cfg, proj):
    s = cfg.ssm
    d_in, nh, _ = mamba2_dims(cfg)
    z, x, Bm, Cm, dt = jnp.split(
        proj, [d_in, 2 * d_in, 2 * d_in + s.state_dim, 2 * d_in + 2 * s.state_dim],
        axis=-1,
    )
    return z, x, Bm, Cm, dt


def _causal_conv_train(x, w, b):
    """x: (B,S,C) depthwise causal conv, window K."""
    K = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for k in range(K):
        out = out + pad[:, k: k + x.shape[1], :] * w[k]
    return out + b


def mamba2_train(p, cfg: ModelConfig, h, return_state: bool = False):
    """h: (B,S,d) -> (B,S,d) via chunked SSD.

    return_state=True also returns the decode-ready recurrent state
    ({"ssm": final state, "conv": last d_conv-1 raw conv inputs})."""
    s = cfg.ssm
    d_in, nh, conv_dim = mamba2_dims(cfg)
    P, N, C = s.head_dim, s.state_dim, s.chunk
    B, S, _ = h.shape
    assert S % C == 0, f"seq {S} must be a multiple of chunk {C}"
    nc = S // C

    y0 = rmsnorm(p["norm"], h, cfg.norm_eps)
    proj = jnp.einsum("bsd,de->bse", y0, p["w_in"].astype(h.dtype))
    z, x, Bm, Cm, dt = _split_in(cfg, proj)
    xbc_raw = jnp.concatenate([x, Bm, Cm], axis=-1)
    xbc = jax.nn.silu(_causal_conv_train(xbc_raw, p["conv_w"].astype(h.dtype),
                                         p["conv_b"].astype(h.dtype)))
    x, Bm, Cm = jnp.split(xbc, [d_in, d_in + N], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["a_log"].astype(jnp.float32))        # (nh,) < 0
    la = dt * A                                          # log decay (B,S,nh)

    xh = x.reshape(B, S, nh, P)
    xdt = xh.astype(jnp.float32) * dt[..., None]         # B(t) x(t) dt(t)

    # chunk
    xc = xdt.reshape(B, nc, C, nh, P)
    lac = la.reshape(B, nc, C, nh)
    Bc = Bm.astype(jnp.float32).reshape(B, nc, C, N)
    Cc = Cm.astype(jnp.float32).reshape(B, nc, C, N)
    cum = jnp.cumsum(lac, axis=2)                        # inclusive (B,nc,C,nh)

    # ---- intra-chunk: y[t] += sum_{s<=t} exp(cum_t - cum_s) (C_t.B_s) x_s
    scores = jnp.einsum("bztn,bzsn->bzts", Cc, Bc)       # (B,nc,C,C)
    decay = jnp.exp(cum[:, :, :, None, :] - cum[:, :, None, :, :])  # (B,nc,t,s,nh)
    tri = jnp.tril(jnp.ones((C, C), bool))
    M = scores[..., None] * jnp.where(tri[None, None, :, :, None], decay, 0.0)
    y_intra = jnp.einsum("bztsh,bzshp->bzthp", M, xc)

    # ---- chunk states: S_z = sum_s exp(cum_last - cum_s) B_s x_s^T
    state_decay = jnp.exp(cum[:, :, -1:, :] - cum)       # (B,nc,C,nh)
    states = jnp.einsum("bzsn,bzsh,bzshp->bzhnp", Bc, state_decay, xc)

    # ---- inter-chunk scan: h_z = exp(cum_last) h_{z-1} + S_z
    chunk_decay = jnp.exp(cum[:, :, -1, :])              # (B,nc,nh)

    def step(carry, inp):
        st, dec = inp
        new = carry * dec[:, :, None, None] + st
        return new, carry                                # emit PREVIOUS state

    init = jnp.zeros((B, nh, N, P), jnp.float32)
    final_state, prev_states = jax.lax.scan(
        step, init,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)),
    )
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)   # (B,nc,nh,N,P)

    # ---- inter-chunk contribution: y[t] += exp(cum_t) C_t . h_{prev}
    in_decay = jnp.exp(cum)                              # (B,nc,C,nh)
    y_inter = jnp.einsum("bztn,bzth,bzhnp->bzthp", Cc, in_decay, prev_states)

    y = (y_intra + y_inter).reshape(B, S, nh, P)
    y = y + xh.astype(jnp.float32) * p["d_skip"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(B, S, d_in).astype(h.dtype)
    y = rmsnorm(p["gate_norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["w_out"].astype(h.dtype))
    if return_state:
        tail = xbc_raw[:, -(s.d_conv - 1):].astype(jnp.float32)
        return out, {"ssm": final_state, "conv": tail}
    return out


def mamba2_state_template(cfg: ModelConfig, batch: int):
    s = cfg.ssm
    d_in, nh, conv_dim = mamba2_dims(cfg)
    return {
        "ssm": jax.ShapeDtypeStruct((batch, nh, s.state_dim, s.head_dim), jnp.float32),
        "conv": jax.ShapeDtypeStruct((batch, s.d_conv - 1, conv_dim), jnp.float32),
    }


def mamba2_decode(p, cfg: ModelConfig, h, state):
    """h: (B,1,d); O(1) recurrent update."""
    s = cfg.ssm
    d_in, nh, conv_dim = mamba2_dims(cfg)
    P, N = s.head_dim, s.state_dim
    B = h.shape[0]
    y0 = rmsnorm(p["norm"], h, cfg.norm_eps)
    proj = jnp.einsum("bsd,de->bse", y0, p["w_in"].astype(h.dtype))
    z, x, Bm, Cm, dt = _split_in(cfg, proj)
    xbc = jnp.concatenate([x, Bm, Cm], axis=-1)[:, 0]    # (B, conv_dim)
    window = jnp.concatenate([state["conv"], xbc[:, None, :].astype(jnp.float32)], axis=1)
    conv_out = jnp.einsum("bkc,kc->bc", window, p["conv_w"].astype(jnp.float32))
    conv_out = jax.nn.silu(conv_out + p["conv_b"].astype(jnp.float32))
    x, Bm, Cm = jnp.split(conv_out, [d_in, d_in + N], axis=-1)

    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["a_log"].astype(jnp.float32))
    a = jnp.exp(dt * A)                                   # (B,nh)
    xh = x.reshape(B, nh, P).astype(jnp.float32) * dt[..., None]
    new_ssm = state["ssm"] * a[:, :, None, None] + jnp.einsum(
        "bn,bhp->bhnp", Bm, xh)
    y = jnp.einsum("bn,bhnp->bhp", Cm, new_ssm)
    y = y + x.reshape(B, nh, P).astype(jnp.float32) * p["d_skip"].astype(jnp.float32)[None, :, None]
    y = y.reshape(B, 1, d_in).astype(h.dtype)
    y = rmsnorm(p["gate_norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["w_out"].astype(h.dtype))
    new_state = {"ssm": new_ssm, "conv": window[:, 1:]}
    return out, new_state


# ======================================================================
# RWKV6 (Finch)
# ======================================================================
RWKV_LORA = 64


def rwkv6_dims(cfg: ModelConfig):
    hd = cfg.ssm.head_dim if cfg.ssm else 64
    nh = cfg.d_model // hd
    return nh, hd


def rwkv6_template(cfg: ModelConfig) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    nh, hd = rwkv6_dims(cfg)
    return {
        "norm_t": rmsnorm_template(d),
        "mu": ParamSpec((5, d), (None, "embed")),        # shift mix (r,k,v,g,w)
        "wr": ParamSpec((d, d), ("embed", "heads"), init="scaled"),
        "wk": ParamSpec((d, d), ("embed", "heads"), init="scaled"),
        "wv": ParamSpec((d, d), ("embed", "heads"), init="scaled"),
        "wg": ParamSpec((d, d), ("embed", "heads"), init="scaled"),
        "w_lora_a": ParamSpec((d, RWKV_LORA), ("embed", None), init="scaled"),
        "w_lora_b": ParamSpec((RWKV_LORA, d), (None, "heads"), init="scaled"),
        "w_base": ParamSpec((d,), ("heads",), init="zeros"),
        "u_bonus": ParamSpec((nh, hd), (None, None), init="zeros"),
        "ln_out": rmsnorm_template(d),
        "wo": ParamSpec((d, d), ("heads", "embed"), init="scaled"),
        # channel mix
        "norm_c": rmsnorm_template(d),
        "mu_c": ParamSpec((2, d), (None, "embed")),
        "wk_c": ParamSpec((d, f), ("embed", "mlp"), init="scaled"),
        "wv_c": ParamSpec((f, d), ("mlp", "embed"), init="scaled"),
        "wr_c": ParamSpec((d, d), ("embed", "embed"), init="scaled"),
    }


def _shift(x, prev=None):
    """Token shift: x_{t-1} (zero / `prev` for t=0). x: (B,S,d)."""
    if prev is None:
        prev = jnp.zeros_like(x[:, :1])
    else:
        prev = prev[:, None, :]
    return jnp.concatenate([prev, x[:, :-1]], axis=1)


def _rwkv_mix(p, cfg, x, shifted):
    """Projections with token-shift lerp; returns r,k,v,g,w (log decay)."""
    mu = p["mu"].astype(x.dtype)                          # (5,d)
    def lerp(i):
        return x + (shifted - x) * mu[i]
    r = jnp.einsum("bsd,dh->bsh", lerp(0), p["wr"].astype(x.dtype))
    k = jnp.einsum("bsd,dh->bsh", lerp(1), p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dh->bsh", lerp(2), p["wv"].astype(x.dtype))
    g = jnp.einsum("bsd,dh->bsh", lerp(3), p["wg"].astype(x.dtype))
    lora = jnp.tanh(jnp.einsum("bsd,dl->bsl", lerp(4), p["w_lora_a"].astype(x.dtype)))
    w_raw = p["w_base"].astype(jnp.float32) + jnp.einsum(
        "bsl,lh->bsh", lora, p["w_lora_b"].astype(x.dtype)).astype(jnp.float32)
    # data-dependent per-channel decay in (0,1): w = exp(-exp(w_raw))
    log_w = -jnp.exp(w_raw - 3.0)                         # (B,S,d) log decay <= 0
    return r, k, v, g, log_w


def rwkv6_time_mix_train(p, cfg: ModelConfig, h, shift_state=None, wkv_state=None):
    """(B,S,d) -> (B,S,d); sequential WKV scan over time."""
    nh, hd = rwkv6_dims(cfg)
    B, S, d = h.shape
    x = rmsnorm(p["norm_t"], h, cfg.norm_eps)
    shifted = _shift(x, shift_state)
    r, k, v, g, log_w = _rwkv_mix(p, cfg, x, shifted)
    rh = r.reshape(B, S, nh, hd).astype(jnp.float32)
    kh = k.reshape(B, S, nh, hd).astype(jnp.float32)
    vh = v.reshape(B, S, nh, hd).astype(jnp.float32)
    wh = jnp.exp(log_w.reshape(B, S, nh, hd))             # decay in (0,1)
    u = p["u_bonus"].astype(jnp.float32)                  # (nh,hd)

    def step(S_carry, inp):
        rt, kt, vt, wt = inp                              # (B,nh,hd) each
        kv = jnp.einsum("bhk,bhv->bhkv", kt, vt)
        out = jnp.einsum("bhk,bhkv->bhv", rt, S_carry + u[None, :, :, None] * kv)
        S_new = S_carry * wt[..., None] + kv
        return S_new, out

    init = (jnp.zeros((B, nh, hd, hd), jnp.float32) if wkv_state is None
            else wkv_state)
    S_fin, outs = jax.lax.scan(
        step, init,
        (rh.transpose(1, 0, 2, 3), kh.transpose(1, 0, 2, 3),
         vh.transpose(1, 0, 2, 3), wh.transpose(1, 0, 2, 3)),
    )
    out = outs.transpose(1, 0, 2, 3).reshape(B, S, d).astype(h.dtype)
    out = rmsnorm(p["ln_out"], out, cfg.norm_eps) * jax.nn.silu(g)
    out = jnp.einsum("bsh,hd->bsd", out, p["wo"].astype(h.dtype))
    return out, x[:, -1], S_fin


def rwkv6_channel_mix(p, cfg: ModelConfig, h, shift_state=None):
    x = rmsnorm(p["norm_c"], h, cfg.norm_eps)
    shifted = _shift(x, shift_state)
    mu = p["mu_c"].astype(x.dtype)
    xk = x + (shifted - x) * mu[0]
    xr = x + (shifted - x) * mu[1]
    k = jnp.einsum("bsd,df->bsf", xk, p["wk_c"].astype(x.dtype))
    k = jnp.square(jax.nn.relu(k))
    kv = jnp.einsum("bsf,fd->bsd", k, p["wv_c"].astype(x.dtype))
    r = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, p["wr_c"].astype(x.dtype)))
    return r * kv, x[:, -1]


def rwkv6_state_template(cfg: ModelConfig, batch: int):
    nh, hd = rwkv6_dims(cfg)
    d = cfg.d_model
    return {
        "wkv": jax.ShapeDtypeStruct((batch, nh, hd, hd), jnp.float32),
        "shift_t": jax.ShapeDtypeStruct((batch, d), jnp.float32),
        "shift_c": jax.ShapeDtypeStruct((batch, d), jnp.float32),
    }


def rwkv6_decode(p, cfg: ModelConfig, h, state):
    """h: (B,1,d) one-step; returns (delta_out_pair, new_state)."""
    x = rmsnorm(p["norm_t"], h, cfg.norm_eps)
    shifted = state["shift_t"][:, None, :].astype(x.dtype)
    r, k, v, g, log_w = _rwkv_mix(p, cfg, x, shifted)
    nh, hd = rwkv6_dims(cfg)
    B = h.shape[0]
    rt = r.reshape(B, nh, hd).astype(jnp.float32)
    kt = k.reshape(B, nh, hd).astype(jnp.float32)
    vt = v.reshape(B, nh, hd).astype(jnp.float32)
    wt = jnp.exp(log_w.reshape(B, nh, hd))
    u = p["u_bonus"].astype(jnp.float32)
    kv = jnp.einsum("bhk,bhv->bhkv", kt, vt)
    out = jnp.einsum("bhk,bhkv->bhv", rt, state["wkv"] + u[None, :, :, None] * kv)
    new_wkv = state["wkv"] * wt[..., None] + kv
    out = out.reshape(B, 1, cfg.d_model).astype(h.dtype)
    out = rmsnorm(p["ln_out"], out, cfg.norm_eps) * jax.nn.silu(g)
    t_out = jnp.einsum("bsh,hd->bsd", out, p["wo"].astype(h.dtype))
    h1 = h + t_out
    xc = rmsnorm(p["norm_c"], h1, cfg.norm_eps)
    shifted_c = state["shift_c"][:, None, :].astype(xc.dtype)
    mu = p["mu_c"].astype(xc.dtype)
    xk = xc + (shifted_c - xc) * mu[0]
    xr = xc + (shifted_c - xc) * mu[1]
    kc = jnp.square(jax.nn.relu(jnp.einsum("bsd,df->bsf", xk, p["wk_c"].astype(xc.dtype))))
    kvc = jnp.einsum("bsf,fd->bsd", kc, p["wv_c"].astype(xc.dtype))
    rc = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, p["wr_c"].astype(xc.dtype)))
    h2 = h1 + rc * kvc
    new_state = {
        "wkv": new_wkv,
        "shift_t": x[:, -1].astype(jnp.float32),
        "shift_c": xc[:, -1].astype(jnp.float32),
    }
    return h2 - h, new_state
