"""The composable stack: layer groups scanned over stacked parameters.

One `group` = one instance of cfg.block_pattern; the full model is
`lax.scan` over `n_groups` stacked group-parameter pytrees, keeping the
HLO compact (deepseek-67b's 95 layers compile as one loop).  Shared
blocks (zamba2) live OUTSIDE the scanned pytree and are applied inside
the group body via closure.

Three entry points:
  forward(...)          logits for a full sequence (training / prefill)
  prefill(...)          forward + KV/recurrent cache construction
  decode_step(...)      one-token serving step updating the cache
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard_act
from repro.models import layers as L
from repro.models import ssm as S
from repro.models.config import ModelConfig
from repro.models.params import ParamSpec, is_spec


# ----------------------------------------------------------------------
# templates
# ----------------------------------------------------------------------
def layer_template(cfg: ModelConfig, kind: str) -> dict:
    if kind in ("attn", "swa"):
        t = {"attn": L.attention_template(cfg)}
        if cfg.encoder is not None:
            t["xattn"] = L.attention_template(cfg, cross=True)
        t["ffn"] = L.moe_template(cfg) if cfg.moe else L.mlp_template(cfg)
        return t
    if kind == "mamba2":
        return {"mamba": S.mamba2_template(cfg)}
    if kind == "mamba2_shared":
        return {"mamba": S.mamba2_template(cfg)}  # shared attn is global
    if kind == "rwkv6":
        return {"rwkv": S.rwkv6_template(cfg)}
    raise ValueError(kind)


def group_template(cfg: ModelConfig) -> dict:
    return {
        f"{i}:{kind}": layer_template(cfg, kind)
        for i, kind in enumerate(cfg.block_pattern)
    }


def _stack_specs(t, n: int):
    return jax.tree.map(
        lambda s: ParamSpec((n,) + s.shape, ("layer",) + s.axes, s.init, s.scale),
        t, is_leaf=is_spec,
    )


def encoder_template(cfg: ModelConfig) -> dict:
    e = cfg.encoder
    layer = {
        "attn": L.attention_template(cfg),
        "ffn": L.mlp_template(cfg),
    }
    return {
        "frontend": ParamSpec((e.d_input, cfg.d_model), (None, "embed"), init="scaled"),
        "pos": ParamSpec((e.max_len, cfg.d_model), (None, "embed")),
        "layers": _stack_specs(layer, e.n_layers),
        "final_norm": L.rmsnorm_template(cfg.d_model),
    }


def model_template(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    t: dict[str, Any] = {
        "embed": ParamSpec((cfg.vocab_padded, d), ("vocab", "embed")),
        "groups": _stack_specs(group_template(cfg), cfg.n_groups),
        "final_norm": L.rmsnorm_template(d),
    }
    if not cfg.tie_embeddings:
        t["lm_head"] = ParamSpec((d, cfg.vocab_padded), ("embed", "vocab"),
                                 init="scaled")
    if "mamba2_shared" in cfg.block_pattern:
        t["shared"] = {
            "attn": L.attention_template(cfg),
            "ffn": L.mlp_template(cfg),
        }
    if cfg.encoder is not None:
        t["encoder"] = encoder_template(cfg)
    return t


# ----------------------------------------------------------------------
# layer application (train / prefill path)
# ----------------------------------------------------------------------
def _apply_layer_train(cfg: ModelConfig, kind: str, p, h, positions,
                       shared=None, enc_out=None):
    if kind in ("attn", "swa"):
        window = cfg.window if kind == "swa" else 0
        theta = cfg.rope_theta if kind == "attn" else getattr(
            cfg, "rope_theta_local", cfg.rope_theta)
        h = h + L.attention_train(p["attn"], cfg, h, positions, window=window,
                                  theta=theta)
        h = shard_act(h, ("batch", "seq", "embed"))
        if enc_out is not None and "xattn" in p:
            h = h + L.attention_train(p["xattn"], cfg, h, positions,
                                      kv_src=enc_out, causal=False)
        ffn = L.moe if cfg.moe else L.mlp
        h = h + ffn(p["ffn"], cfg, h)
        h = shard_act(h, ("batch", "seq", "embed"))
        return h
    if kind in ("mamba2", "mamba2_shared"):
        h = h + S.mamba2_train(p["mamba"], cfg, h)
        h = shard_act(h, ("batch", "seq", "embed"))
        if kind == "mamba2_shared":
            assert shared is not None
            h = h + L.attention_train(shared["attn"], cfg, h, positions)
            h = h + L.mlp(shared["ffn"], cfg, h)
            h = shard_act(h, ("batch", "seq", "embed"))
        return h
    if kind == "rwkv6":
        t_out, _, _ = S.rwkv6_time_mix_train(p["rwkv"], cfg, h)
        h = h + t_out
        c_out, _ = S.rwkv6_channel_mix(p["rwkv"], cfg, h)
        h = h + c_out
        return shard_act(h, ("batch", "seq", "embed"))
    raise ValueError(kind)


def _embed_in(cfg: ModelConfig, params, tokens=None, embeds=None):
    if embeds is None:
        embeds = jnp.take(params["embed"], tokens, axis=0)
        embeds = embeds * jnp.asarray(
            jnp.sqrt(cfg.d_model), embeds.dtype)
    return shard_act(embeds, ("batch", "seq", "embed"))


def _unembed(cfg: ModelConfig, params, h):
    h = L.rmsnorm(params["final_norm"], h, cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", h, head.astype(h.dtype))
    if cfg.vocab_padded != cfg.vocab:
        pad_mask = jnp.arange(cfg.vocab_padded) < cfg.vocab
        logits = jnp.where(pad_mask, logits, L.NEG_INF)
    return shard_act(logits, ("batch", "seq", "vocab"))


def encode(cfg: ModelConfig, params, frames):
    """Whisper-style encoder over stub frame embeddings (B,T,d_input)."""
    e = params["encoder"]
    h = jnp.einsum("bti,id->btd", frames, e["frontend"].astype(frames.dtype))
    h = h + e["pos"][: h.shape[1]].astype(h.dtype)
    h = shard_act(h, ("batch", "seq", "embed"))
    positions = jnp.broadcast_to(
        jnp.arange(h.shape[1], dtype=jnp.int32), h.shape[:2])

    def body(carry, lp):
        x = carry
        x = x + L.attention_train(lp["attn"], cfg, x, positions, causal=False)
        x = x + L.mlp(lp["ffn"], cfg, x)
        return shard_act(x, ("batch", "seq", "embed")), None

    h, _ = jax.lax.scan(body, h, e["layers"])
    return L.rmsnorm(e["final_norm"], h, cfg.norm_eps)


def forward(cfg: ModelConfig, params, tokens=None, embeds=None,
            positions=None, enc_frames=None, remat: str = "none"):
    """Full-sequence logits.  remat: none|full (checkpoint each group)."""
    h = _embed_in(cfg, params, tokens, embeds)
    B, Sq = h.shape[:2]
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(Sq, dtype=jnp.int32), (B, Sq))
        if cfg.mrope:
            positions = jnp.broadcast_to(positions[..., None], (B, Sq, 3))
    enc_out = encode(cfg, params, enc_frames) if enc_frames is not None else None
    shared = params.get("shared")

    def group_body(carry, gp):
        x = carry
        for i, kind in enumerate(cfg.block_pattern):
            x = _apply_layer_train(cfg, kind, gp[f"{i}:{kind}"], x, positions,
                                   shared=shared, enc_out=enc_out)
        return x, None

    body = group_body
    if remat == "full":
        body = jax.checkpoint(group_body, prevent_cse=False)
    elif remat == "dots":
        # save matmul results; recompute only cheap elementwise chains
        body = jax.checkpoint(
            group_body, prevent_cse=False,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    h, _ = jax.lax.scan(body, h, params["groups"])
    return _unembed(cfg, params, h)


def _apply_layer_prefill(cfg: ModelConfig, kind: str, p, h, positions,
                         cache_len: int, shared=None, enc_out=None):
    """Like _apply_layer_train but also emits the decode-ready cache
    entry for this layer (keys match _layer_cache_template)."""
    if kind in ("attn", "swa"):
        window = cfg.window if kind == "swa" else 0
        theta = cfg.rope_theta if kind == "attn" else getattr(
            cfg, "rope_theta_local", cfg.rope_theta)
        att, (k, v) = L.attention_train(p["attn"], cfg, h, positions,
                                        window=window, theta=theta,
                                        return_kv=True)
        h = h + att
        ck, cv = L.kv_into_cache(k, v, cache_len, window)
        entry = {"k": ck, "v": cv}
        if enc_out is not None and "xattn" in p:
            h = h + L.attention_train(p["xattn"], cfg, h, positions,
                                      kv_src=enc_out, causal=False)
            # cross-attention KV is computed once from the encoder output
            kv_in = L.rmsnorm(p["xattn"]["norm"], enc_out, cfg.norm_eps)
            xk = jnp.einsum("btd,dh->bth", kv_in,
                            p["xattn"]["wk"].astype(h.dtype))
            xv = jnp.einsum("btd,dh->bth", kv_in,
                            p["xattn"]["wv"].astype(h.dtype))
            B, T = xk.shape[:2]
            entry["xk"] = xk.reshape(B, T, cfg.n_kv_heads, cfg.hd
                                     ).astype(jnp.bfloat16)
            entry["xv"] = xv.reshape(B, T, cfg.n_kv_heads, cfg.hd
                                     ).astype(jnp.bfloat16)
        ffn = L.moe if cfg.moe else L.mlp
        h = h + ffn(p["ffn"], cfg, h)
        return h, entry
    if kind in ("mamba2", "mamba2_shared"):
        out, state = S.mamba2_train(p["mamba"], cfg, h, return_state=True)
        h = h + out
        entry = dict(state)
        if kind == "mamba2_shared":
            att, (k, v) = L.attention_train(shared["attn"], cfg, h, positions,
                                            return_kv=True)
            h = h + att
            h = h + L.mlp(shared["ffn"], cfg, h)
            ck, cv = L.kv_into_cache(k, v, cache_len, 0)
            entry["shared_k"] = ck
            entry["shared_v"] = cv
        return h, entry
    if kind == "rwkv6":
        t_out, x_last_t, wkv = S.rwkv6_time_mix_train(p["rwkv"], cfg, h)
        h = h + t_out
        c_out, x_last_c = S.rwkv6_channel_mix(p["rwkv"], cfg, h)
        h = h + c_out
        return h, {"wkv": wkv, "shift_t": x_last_t.astype(jnp.float32),
                   "shift_c": x_last_c.astype(jnp.float32)}
    raise ValueError(kind)


def prefill_with_cache(cfg: ModelConfig, params, tokens=None, embeds=None,
                       positions=None, enc_frames=None, cache_len: int = 0):
    """Forward pass that ALSO builds the decode cache (the production
    prefill->decode handoff).  Returns (logits, cache)."""
    h = _embed_in(cfg, params, tokens, embeds)
    B, Sq = h.shape[:2]
    assert cache_len >= Sq, "cache must hold the prefill"
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(Sq, dtype=jnp.int32), (B, Sq))
        if cfg.mrope:
            positions = jnp.broadcast_to(positions[..., None], (B, Sq, 3))
    enc_out = encode(cfg, params, enc_frames) if enc_frames is not None else None
    shared = params.get("shared")

    def group_body(carry, gp):
        x = carry
        entries = {}
        for i, kind in enumerate(cfg.block_pattern):
            x, entries[f"{i}:{kind}"] = _apply_layer_prefill(
                cfg, kind, gp[f"{i}:{kind}"], x, positions, cache_len,
                shared=shared, enc_out=enc_out)
        return x, entries

    h, cache = jax.lax.scan(group_body, h, params["groups"])
    return _unembed(cfg, params, h), cache


# ----------------------------------------------------------------------
# serving: cache templates, prefill, decode
# ----------------------------------------------------------------------
def _layer_cache_template(cfg: ModelConfig, kind: str, batch: int,
                          cache_len: int, enc_len: int = 0) -> dict:
    hd = cfg.hd
    kv = cfg.n_kv_heads
    if kind in ("attn", "swa"):
        T = min(cfg.window, cache_len) if kind == "swa" and cfg.window else cache_len
        t = {
            "k": jax.ShapeDtypeStruct((batch, T, kv, hd), jnp.bfloat16),
            "v": jax.ShapeDtypeStruct((batch, T, kv, hd), jnp.bfloat16),
        }
        if cfg.encoder is not None:
            t["xk"] = jax.ShapeDtypeStruct((batch, enc_len, kv, hd), jnp.bfloat16)
            t["xv"] = jax.ShapeDtypeStruct((batch, enc_len, kv, hd), jnp.bfloat16)
        return t
    if kind == "mamba2":
        return S.mamba2_state_template(cfg, batch)
    if kind == "mamba2_shared":
        return {
            **S.mamba2_state_template(cfg, batch),
            "shared_k": jax.ShapeDtypeStruct((batch, cache_len, kv, hd), jnp.bfloat16),
            "shared_v": jax.ShapeDtypeStruct((batch, cache_len, kv, hd), jnp.bfloat16),
        }
    if kind == "rwkv6":
        return S.rwkv6_state_template(cfg, batch)
    raise ValueError(kind)


def cache_template(cfg: ModelConfig, batch: int, cache_len: int,
                   enc_len: int = 0) -> dict:
    per_group = {
        f"{i}:{kind}": _layer_cache_template(cfg, kind, batch, cache_len, enc_len)
        for i, kind in enumerate(cfg.block_pattern)
    }
    return jax.tree.map(
        lambda sds: jax.ShapeDtypeStruct((cfg.n_groups,) + sds.shape, sds.dtype),
        per_group,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
    )


def cache_logical_axes(cfg: ModelConfig) -> dict:
    """Logical axes parallel to cache_template (for dry-run shardings)."""
    def axes_for(path_kind: str, name: str, ndim: int):
        if name in ("k", "v", "xk", "xv", "shared_k", "shared_v"):
            return ("layer", "batch", "seq_cache", "kv_heads", None)
        if name == "wkv":
            return ("layer", "batch", "kv_heads", None, "state_feat")
        if name == "ssm":
            return ("layer", "batch", "kv_heads", None, "state_feat")
        if name == "conv":
            return ("layer", "batch", None, "mlp")
        if name in ("shift_t", "shift_c"):
            return ("layer", "batch", "embed")
        return ("layer",) + (None,) * (ndim - 1)

    t = cache_template(cfg, 1, 2)
    out = {}
    for lk, entries in t.items():
        out[lk] = {
            name: axes_for(lk, name, v.ndim) for name, v in entries.items()
        }
    return out


def _apply_layer_decode(cfg: ModelConfig, kind: str, p, h, pos, cache,
                        shared=None):
    if kind in ("attn", "swa"):
        window = cfg.window if kind == "swa" else 0
        theta = cfg.rope_theta if kind == "attn" else getattr(
            cfg, "rope_theta_local", cfg.rope_theta)
        att, new_kv = L.attention_decode(p["attn"], cfg, h, pos,
                                         {"k": cache["k"], "v": cache["v"]},
                                         window=window, theta=theta)
        h = h + att
        new_cache = dict(cache)
        new_cache.update(new_kv)
        if cfg.encoder is not None and "xattn" in p:
            # cross attention against the prefilled encoder KV
            y = L.rmsnorm(p["xattn"]["norm"], h, cfg.norm_eps)
            q = jnp.einsum("bsd,dh->bsh", y, p["xattn"]["wq"].astype(h.dtype))
            B = h.shape[0]
            q = q.reshape(B, 1, cfg.n_heads, cfg.hd)
            scores = L._gqa_scores(q, cache["xk"].astype(h.dtype))
            probs = jax.nn.softmax(scores.astype(jnp.float32), -1).astype(h.dtype)
            out = L._gqa_out(probs, cache["xv"].astype(h.dtype))
            h = h + jnp.einsum("bsh,hd->bsd", out, p["xattn"]["wo"].astype(h.dtype))
        ffn = L.moe if cfg.moe else L.mlp
        h = h + ffn(p["ffn"], cfg, h)
        return h, new_cache
    if kind in ("mamba2", "mamba2_shared"):
        out, new_state = S.mamba2_decode(
            p["mamba"], cfg, h, {"ssm": cache["ssm"], "conv": cache["conv"]})
        h = h + out
        new_cache = dict(cache)
        new_cache.update(new_state)
        if kind == "mamba2_shared":
            att, new_kv = L.attention_decode(
                shared["attn"], cfg, h, pos,
                {"k": cache["shared_k"], "v": cache["shared_v"]})
            h = h + att
            h = h + L.mlp(shared["ffn"], cfg, h)
            new_cache["shared_k"] = new_kv["k"]
            new_cache["shared_v"] = new_kv["v"]
        return h, new_cache
    if kind == "rwkv6":
        delta, new_state = S.rwkv6_decode(p["rwkv"], cfg, h, cache)
        return h + delta, new_state
    raise ValueError(kind)


def decode_step(cfg: ModelConfig, params, token, pos, cache):
    """One serving step: token (B,1) int32, pos scalar int32, cache pytree
    with leading n_groups dim on every leaf.  Returns (logits, new_cache)."""
    h = _embed_in(cfg, params, token)
    shared = params.get("shared")

    def group_body(carry, xs):
        x = carry
        gp, gc = xs
        new_gc = {}
        for i, kind in enumerate(cfg.block_pattern):
            key = f"{i}:{kind}"
            x, new_gc[key] = _apply_layer_decode(cfg, kind, gp[key], x, pos,
                                                 gc[key], shared=shared)
        return x, new_gc

    h, new_cache = jax.lax.scan(group_body, h, (params["groups"], cache))
    logits = _unembed(cfg, params, h)
    return logits, new_cache
