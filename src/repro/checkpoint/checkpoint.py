"""Checkpointing: atomic, manifest-driven, elastic across mesh changes.

Layout of one checkpoint:
    <dir>/step_<N>/manifest.json     tree structure + shapes + dtypes
    <dir>/step_<N>/arrays.npz        flattened leaves by index
Writes go to `step_<N>.tmp` then rename (atomic commit: a crashed write
never yields a loadable-but-corrupt checkpoint).  `restore` device_puts
into ANY sharding pytree — restoring onto a larger/smaller mesh than the
one that saved is the elastic-rescale path (tested).
"""
from __future__ import annotations

import json
import os
import re
import shutil

import jax
import numpy as np


def _flatten_with_paths(tree):
    # jax.tree.flatten_with_path only exists in newer jax; the
    # tree_util spelling is stable across the versions we support
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = [jax.tree_util.keystr(k) for k, _ in flat]
    leaves = [v for _, v in flat]
    return paths, leaves, treedef


def save(ckpt_dir: str, step: int, state, keep: int = 3) -> str:
    paths, leaves, _ = _flatten_with_paths(state)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    arrays = {f"a{i}": np.asarray(x) for i, x in enumerate(leaves)}
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    manifest = {
        "step": step,
        "paths": paths,
        "shapes": [list(np.shape(x)) for x in leaves],
        "dtypes": [str(np.asarray(x).dtype) for x in leaves],
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic commit
    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir: str, keep: int) -> None:
    steps = sorted(list_steps(ckpt_dir))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"), ignore_errors=True)


def list_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        m = re.fullmatch(r"step_(\d+)", name)
        if m:
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(ckpt_dir: str) -> int | None:
    steps = list_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore(ckpt_dir: str, step: int, target_tree, shardings=None):
    """Restore into the structure of `target_tree`; `shardings` (optional
    pytree of NamedSharding) re-shards every leaf — the elastic path."""
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(d, "arrays.npz"))
    paths, _, treedef = _flatten_with_paths(target_tree)
    if paths != manifest["paths"]:
        raise ValueError(
            "checkpoint tree mismatch: "
            f"{set(paths) ^ set(manifest['paths'])}")
    leaves = [data[f"a{i}"] for i in range(len(paths))]
    if shardings is not None:
        sh_leaves = jax.tree.leaves(shardings)
        if len(sh_leaves) != len(leaves):
            raise ValueError(
                f"shardings tree has {len(sh_leaves)} leaves, "
                f"checkpoint has {len(leaves)}")
        leaves = [jax.device_put(x, s) for x, s in zip(leaves, sh_leaves)]
    return jax.tree.unflatten(treedef, leaves)
