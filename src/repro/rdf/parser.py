"""Minimal parsers: conjunctive SPARQL SELECT and N-Triples lines.

The demo lets users edit queries in a SPARQL editor; this is the
programmatic equivalent.  Only the conjunctive fragment is accepted
(SELECT + basic graph pattern), matching the paper's problem model.
"""
from __future__ import annotations

import re

import numpy as np

from repro.core.queries import CQ, Atom, Const, Term, Var
from repro.rdf.dictionary import Dictionary

_SELECT_RE = re.compile(
    r"SELECT\s+(?P<head>[^{]+)\s+WHERE\s*\{(?P<body>.*)\}", re.IGNORECASE | re.DOTALL
)


class SparqlParseError(ValueError):
    pass


def _term(tok: str, d: Dictionary) -> Term:
    tok = tok.strip()
    if tok.startswith("?"):
        return Var(tok[1:])
    if tok.startswith("<") and tok.endswith(">"):
        tok = tok[1:-1]
    if tok.startswith('"') and tok.endswith('"'):
        tok = tok[1:-1]
    if tok == "a":
        tok = "rdf:type"
    return Const(d.encode(tok))


def parse_sparql(text: str, d: Dictionary, name: str = "", weight: float = 1.0) -> CQ:
    m = _SELECT_RE.search(text.strip())
    if not m:
        raise SparqlParseError(f"not a conjunctive SELECT query: {text[:80]!r}")
    head_toks = m.group("head").split()
    head = []
    for tok in head_toks:
        if not tok.startswith("?"):
            raise SparqlParseError(f"head terms must be variables, got {tok!r}")
        head.append(Var(tok[1:]))
    body = m.group("body")
    atoms = []
    for part in [p.strip() for p in body.split(".") if p.strip()]:
        toks = part.split()
        if len(toks) != 3:
            raise SparqlParseError(f"triple pattern must have 3 terms: {part!r}")
        s, p, o = (_term(t, d) for t in toks)
        atoms.append(Atom(s, p, o))
    if not atoms:
        raise SparqlParseError("empty basic graph pattern")
    return CQ(tuple(head), tuple(atoms), name=name, weight=weight)


_NT_RE = re.compile(r'\s*(<[^>]*>|"[^"]*"|\S+)\s+(<[^>]*>|\S+)\s+(<[^>]*>|"[^"]*"|\S+)\s*\.\s*$')


def parse_ntriples(text: str, d: Dictionary) -> np.ndarray:
    """Parse N-Triples-ish lines into an (N,3) int32 array."""
    rows = []
    for line in text.splitlines():
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        m = _NT_RE.match(line)
        if not m:
            raise SparqlParseError(f"bad N-Triples line: {line!r}")
        ids = []
        for tok in m.groups():
            if tok.startswith("<") and tok.endswith(">"):
                tok = tok[1:-1]
            if tok.startswith('"') and tok.endswith('"'):
                tok = tok[1:-1]
            ids.append(d.encode(tok))
        rows.append(ids)
    return np.array(rows, dtype=np.int32).reshape(-1, 3)
