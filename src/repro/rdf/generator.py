"""LUBM-style synthetic RDF benchmark data + workload generator.

Mirrors the datasets the demo pre-loads (LUBM et al.): a university
ontology with an RDFS class/property hierarchy, instance data scaled by
`n_universities`, and a weighted conjunctive SPARQL workload patterned on
the published LUBM queries (conjunctive subset).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.queries import CQ, Atom, Const, Var
from repro.rdf.dictionary import Dictionary, RDF_TYPE
from repro.rdf.schema import RDFSchema
from repro.rdf.triples import TripleStore

CLASSES = [
    "ub:Person", "ub:Student", "ub:UndergraduateStudent", "ub:GraduateStudent",
    "ub:Employee", "ub:Faculty", "ub:Professor", "ub:FullProfessor",
    "ub:AssociateProfessor", "ub:Lecturer", "ub:Course", "ub:GraduateCourse",
    "ub:Department", "ub:University", "ub:Publication",
]

SUBCLASS = [
    ("ub:Student", "ub:Person"),
    ("ub:UndergraduateStudent", "ub:Student"),
    ("ub:GraduateStudent", "ub:Student"),
    ("ub:Employee", "ub:Person"),
    ("ub:Faculty", "ub:Employee"),
    ("ub:Professor", "ub:Faculty"),
    ("ub:FullProfessor", "ub:Professor"),
    ("ub:AssociateProfessor", "ub:Professor"),
    ("ub:Lecturer", "ub:Faculty"),
    ("ub:GraduateCourse", "ub:Course"),
]

PROPS = {
    # prop: (domain, range)
    "ub:takesCourse": ("ub:Student", "ub:Course"),
    "ub:teacherOf": ("ub:Faculty", "ub:Course"),
    "ub:advisor": ("ub:Student", "ub:Professor"),
    "ub:worksFor": ("ub:Employee", "ub:Department"),
    "ub:memberOf": ("ub:Person", "ub:Department"),
    "ub:subOrganizationOf": ("ub:Department", "ub:University"),
    "ub:publicationAuthor": ("ub:Publication", "ub:Person"),
    "ub:undergraduateDegreeFrom": ("ub:Person", "ub:University"),
    "ub:headOf": ("ub:Professor", "ub:Department"),
}

SUBPROP = [
    ("ub:headOf", "ub:worksFor"),
]


@dataclass
class Universe:
    store: TripleStore
    schema: RDFSchema
    dictionary: Dictionary
    type_id: int


def build_schema(d: Dictionary) -> RDFSchema:
    schema = RDFSchema()
    for child, parent in SUBCLASS:
        schema.add_subclass(d.encode(child), d.encode(parent))
    for child, parent in SUBPROP:
        schema.add_subprop(d.encode(child), d.encode(parent))
    for prop, (dom, rng) in PROPS.items():
        schema.set_domain(d.encode(prop), d.encode(dom))
        schema.set_range(d.encode(prop), d.encode(rng))
    return schema


def generate(n_universities: int = 1, seed: int = 0, dept_per_univ: int = 3,
             prof_per_dept: int = 6, stud_per_dept: int = 40,
             course_per_dept: int = 10) -> Universe:
    rng = np.random.default_rng(seed)
    d = Dictionary()
    type_id = d.encode(RDF_TYPE)
    for c in CLASSES:
        d.encode(c)
    for p in PROPS:
        d.encode(p)
    schema = build_schema(d)

    T: list[tuple[int, int, int]] = []

    def tid(name: str) -> int:
        return d.encode(name)

    def add(s: int, p: str, o: int) -> None:
        T.append((s, tid(p), o))

    def add_type(s: int, cls: str) -> None:
        T.append((s, type_id, tid(cls)))

    for u in range(n_universities):
        univ = d.encode(f"u{u}")
        add_type(univ, "ub:University")
        for dep in range(dept_per_univ):
            dept = d.encode(f"u{u}.d{dep}")
            add_type(dept, "ub:Department")
            add(dept, "ub:subOrganizationOf", univ)
            courses = []
            for c in range(course_per_dept):
                crs = d.encode(f"u{u}.d{dep}.c{c}")
                cls = "ub:GraduateCourse" if c % 3 == 0 else "ub:Course"
                add_type(crs, cls)
                courses.append(crs)
            profs = []
            for p in range(prof_per_dept):
                prof = d.encode(f"u{u}.d{dep}.p{p}")
                cls = ["ub:FullProfessor", "ub:AssociateProfessor", "ub:Lecturer"][p % 3]
                add_type(prof, cls)
                add(prof, "ub:worksFor", dept)
                taught = rng.choice(len(courses), size=min(2, len(courses)), replace=False)
                for c in taught:
                    add(prof, "ub:teacherOf", courses[c])
                profs.append(prof)
            head = profs[0]
            add(head, "ub:headOf", dept)
            for s in range(stud_per_dept):
                stu = d.encode(f"u{u}.d{dep}.s{s}")
                grad = s % 4 == 0
                add_type(stu, "ub:GraduateStudent" if grad else "ub:UndergraduateStudent")
                add(stu, "ub:memberOf", dept)
                n_courses = int(rng.integers(1, 4))
                for c in rng.choice(len(courses), size=n_courses, replace=False):
                    add(stu, "ub:takesCourse", courses[c])
                if grad:
                    add(stu, "ub:advisor", profs[int(rng.integers(0, len(profs)))])
                    add(stu, "ub:undergraduateDegreeFrom", univ)
            for pub in range(prof_per_dept * 2):
                pb = d.encode(f"u{u}.d{dep}.pub{pub}")
                add_type(pb, "ub:Publication")
                add(pb, "ub:publicationAuthor", profs[pub % len(profs)])

    store = TripleStore(np.array(T, dtype=np.int32), d)
    return Universe(store=store, schema=schema, dictionary=d, type_id=type_id)


# ----------------------------------------------------------------------
# Workload: conjunctive subset of the published LUBM queries
# ----------------------------------------------------------------------
def lubm_workload(d: Dictionary, weights: dict[str, float] | None = None) -> list[CQ]:
    """Conjunctive SPARQL workload over the generated universe."""
    w = weights or {}
    t = Const(d.encode(RDF_TYPE))

    def c(name: str) -> Const:
        return Const(d.encode(name))

    x, y, z, u_ = Var("x"), Var("y"), Var("z"), Var("u")

    qs = [
        # Q1: graduate students and the courses they take
        CQ((x, y), (
            Atom(x, t, c("ub:GraduateStudent")),
            Atom(x, c("ub:takesCourse"), y),
        ), name="q1", weight=w.get("q1", 10.0)),
        # Q2: students with an advisor who teaches a course they take
        CQ((x, y, z), (
            Atom(x, c("ub:advisor"), y),
            Atom(y, c("ub:teacherOf"), z),
            Atom(x, c("ub:takesCourse"), z),
        ), name="q2", weight=w.get("q2", 5.0)),
        # Q3: members of departments of a university, with their courses
        CQ((x, z), (
            Atom(x, c("ub:memberOf"), y),
            Atom(y, c("ub:subOrganizationOf"), z),
            Atom(x, c("ub:takesCourse"), u_),
        ), name="q3", weight=w.get("q3", 3.0)),
        # Q4: faculty (via schema: professors/lecturers) and their dept
        CQ((x, y), (
            Atom(x, t, c("ub:Faculty")),
            Atom(x, c("ub:worksFor"), y),
        ), name="q4", weight=w.get("q4", 8.0)),
        # Q5: publications of professors working in a department
        CQ((x, y), (
            Atom(x, c("ub:publicationAuthor"), y),
            Atom(y, c("ub:worksFor"), z),
        ), name="q5", weight=w.get("q5", 2.0)),
        # Q6: students taking a course taught by their dept head
        CQ((x,), (
            Atom(x, c("ub:takesCourse"), y),
            Atom(z, c("ub:teacherOf"), y),
            Atom(z, c("ub:headOf"), u_),
            Atom(x, c("ub:memberOf"), u_),
        ), name="q6", weight=w.get("q6", 1.0)),
    ]
    return qs
