"""The triple table (TT): dictionary-encoded int32 triples + sorted indexes.

Storage model (TPU adaptation of the paper's RDBMS triple table):
  * one (N, 3) int32 array of deduplicated triples,
  * three sorted copies — SPO, POS, OSP — so that every bound-prefix
    access path is a contiguous range located by binary search
    (`searchsorted` on a fused uint64 key), the vectorized analogue of a
    clustered B-tree.

`Statistics` feeds the cost model (core/quality.py) and the static
capacity planner of the JAX engine.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

# all six orders (Hexastore [7] / RDF-3X [4], both cited by the paper):
# any bound prefix is a contiguous range AND the scan can emit rows
# pre-sorted on the column a downstream merge join needs (sort elision)
_ORDERS = {
    "spo": (0, 1, 2), "pos": (1, 2, 0), "osp": (2, 0, 1),
    "pso": (1, 0, 2), "ops": (2, 1, 0), "sop": (0, 2, 1),
}


def _fuse_keys(cols: np.ndarray) -> np.ndarray:
    """Fuse 2 leading sort columns into one uint64 key (ids are < 2^31)."""
    c = cols.astype(np.uint64)
    return (c[:, 0] << np.uint64(32)) | c[:, 1]


def _order_keys(rows: np.ndarray, perm: tuple[int, int, int]) -> np.ndarray:
    """Full-row uint64 key in one index order — matches the lexsort of
    `TripleStore.__init__` exactly when every id fits in 21 bits (the
    guard in `apply_delta`), so merge positions come from searchsorted."""
    u = np.asarray(rows, np.int32).astype(np.uint64)
    return ((u[:, perm[0]] << np.uint64(42))
            | (u[:, perm[1]] << np.uint64(21)) | u[:, perm[2]])


def triple_keys(triples: np.ndarray) -> np.ndarray:
    """One comparable key per (s, p, o) row.  Dictionary-encoded ids are
    normally tiny, so the fast path packs 21 bits per position into one
    uint64; ids that don't fit fall back to a structured (void) view.
    Powers vectorized set membership for batched deltas."""
    t = np.ascontiguousarray(np.asarray(triples, np.int32).reshape(-1, 3))
    if len(t) == 0 or int(t.max(initial=0)) < (1 << 21) and int(t.min(initial=0)) >= 0:
        u = t.astype(np.uint64)
        return (u[:, 0] << np.uint64(42)) | (u[:, 1] << np.uint64(21)) | u[:, 2]
    return t.view([("s", np.int32), ("p", np.int32), ("o", np.int32)]).reshape(-1)


def triples_in(triples: np.ndarray, reference: np.ndarray) -> np.ndarray:
    """Boolean mask: which rows of `triples` appear in `reference`."""
    triples = np.asarray(triples, np.int32).reshape(-1, 3)
    reference = np.asarray(reference, np.int32).reshape(-1, 3)
    if len(triples) == 0:
        return np.zeros(0, dtype=bool)
    if len(reference) == 0:
        return np.zeros(len(triples), dtype=bool)
    both = np.concatenate([triples, reference])
    keys = triple_keys(both)  # one keying pass so both sides share a scheme
    # sort only the reference side: O((n + k) log k) beats np.isin's
    # sort-the-concatenation when one side is a small delta batch
    ref = np.sort(keys[len(triples):])
    pos = np.searchsorted(ref, keys[: len(triples)])
    ok = pos < len(ref)
    out = np.zeros(len(triples), dtype=bool)
    out[ok] = ref[pos[ok]] == keys[: len(triples)][ok]
    return out


# keep a full object-value histogram for predicates with at most this many
# distinct objects (rdf:type and other categorical predicates): exact
# per-class counts instead of uniform averages.
_HIST_MAX_DISTINCT = 256


@dataclass(frozen=True)
class Statistics:
    n_triples: int
    n_ids: int
    pred_count: dict[int, int]          # p -> #triples
    pred_distinct_s: dict[int, int]     # p -> #distinct subjects
    pred_distinct_o: dict[int, int]     # p -> #distinct objects
    distinct_s: int
    distinct_o: int
    distinct_p: int
    pred_obj_hist: dict[int, dict[int, int]]  # p -> {o -> count}, low-card preds

    def atom_card(self, s_bound: bool, p: int | None, o_bound: bool,
                  o_val: int | None = None) -> float:
        """Estimated cardinality of one triple pattern (System-R style,
        exact histogram for categorical predicates)."""
        if p is not None:
            base = float(self.pred_count.get(p, 0))
            if base == 0.0:
                return 0.0
            if o_bound:
                hist = self.pred_obj_hist.get(p)
                if hist is not None and o_val is not None:
                    base = float(hist.get(o_val, 0))
                    if base == 0.0:
                        return 0.0
                else:
                    base /= max(self.pred_distinct_o.get(p, 1), 1)
            if s_bound:
                base /= max(self.pred_distinct_s.get(p, 1), 1)
            return max(base, 1e-3)
        base = float(self.n_triples)
        if s_bound:
            base /= max(self.distinct_s, 1)
        if o_bound:
            base /= max(self.distinct_o, 1)
        return max(base, 1e-3)


class TripleStore:
    def __init__(self, triples: np.ndarray, dictionary=None):
        triples = np.asarray(triples, dtype=np.int32).reshape(-1, 3)
        # dedupe
        if len(triples):
            triples = np.unique(triples, axis=0)
        self.triples = triples
        self.dictionary = dictionary
        self._indexes: dict[str, np.ndarray] = {}
        self._keys: dict[str, np.ndarray] = {}
        for name, perm in _ORDERS.items():
            proj = triples[:, perm]
            order = np.lexsort((proj[:, 2], proj[:, 1], proj[:, 0]))
            sorted_t = triples[order]
            self._indexes[name] = sorted_t
            self._keys[name] = _fuse_keys(sorted_t[:, perm[:2]].reshape(-1, 2))
        self._stats: Statistics | None = None
        self._rk: np.ndarray | None | bool = None  # lazy sorted row keys

    @property
    def row_keys(self) -> np.ndarray | None:
        """Sorted full-row uint64 keys (spo order), or None when an id
        overflows the 21-bit packing.  Powers O(k log n) `contains`."""
        if self._rk is None:
            t = self.triples
            if len(t) and (int(t.max()) >= (1 << 21) or int(t.min()) < 0):
                self._rk = False
            else:
                self._rk = _order_keys(self._indexes["spo"], (0, 1, 2))
        return None if self._rk is False else self._rk

    def __len__(self) -> int:
        return len(self.triples)

    # ------------------------------------------------------------------
    # access paths
    # ------------------------------------------------------------------
    def index(self, name: str) -> np.ndarray:
        return self._indexes[name]

    def scan(self, s: int | None, p: int | None, o: int | None) -> np.ndarray:
        """All triples matching the (possibly unbound) pattern; (M,3)."""
        # choose the index whose sort prefix covers the bound positions
        if p is not None and o is not None:
            idx, key = "pos", (p, o)
        elif p is not None:
            idx, key = "pos", (p,)
        elif s is not None:
            idx, key = "spo", (s,) if o is None else (s,)
        elif o is not None:
            idx, key = "osp", (o,)
        else:
            res = self._indexes["spo"]
            return res
        data = self._indexes[idx]
        perm = _ORDERS[idx]
        if len(key) == 2:
            fused = self._keys[idx]
            target = (np.uint64(key[0]) << np.uint64(32)) | np.uint64(key[1])
            lo = np.searchsorted(fused, target, side="left")
            hi = np.searchsorted(fused, target, side="right")
        else:
            col = data[:, perm[0]]
            lo = np.searchsorted(col, key[0], side="left")
            hi = np.searchsorted(col, key[0], side="right")
        res = data[lo:hi]
        # residual filters for positions not covered by the index prefix
        for pos, val in (("s", s), ("p", p), ("o", o)):
            if val is None:
                continue
            col_i = {"s": 0, "p": 1, "o": 2}[pos]
            if col_i in (perm[0], perm[1])[: len(key)]:
                continue
            res = res[res[:, col_i] == val]
        return res

    # ------------------------------------------------------------------
    def insert(self, new_triples: np.ndarray) -> "TripleStore":
        """Functional insert (returns a new store); powers maintenance tests."""
        merged = np.concatenate([self.triples, np.asarray(new_triples, np.int32).reshape(-1, 3)])
        return TripleStore(merged, self.dictionary)

    def delete(self, gone_triples: np.ndarray) -> "TripleStore":
        """Functional delete (returns a new store).  Rows not present are
        ignored — deletes are idempotent, like inserts."""
        gone = np.asarray(gone_triples, np.int32).reshape(-1, 3)
        if len(gone) == 0 or len(self.triples) == 0:
            return TripleStore(self.triples, self.dictionary)
        keep = ~triples_in(self.triples, gone)
        return TripleStore(self.triples[keep], self.dictionary)

    def apply_delta(self, inserts: np.ndarray | None = None,
                    deletes: np.ndarray | None = None) -> "TripleStore":
        """TT' = (TT \\ deletes) ∪ inserts — inserts win over deletes on
        the same triple, matching the streaming-delta semantics of
        repro.maintenance.

        The six sorted copies are maintained by merge (delete mask +
        `np.insert` at searchsorted positions per order) instead of
        re-sorting the whole table: O(n + k log n) per order, the term
        that keeps a small-batch maintenance pass from paying the full
        6-lexsort rebuild every batch."""
        ins = (np.zeros((0, 3), np.int32) if inserts is None
               else np.asarray(inserts, np.int32).reshape(-1, 3))
        dels = (np.zeros((0, 3), np.int32) if deletes is None
                else np.asarray(deletes, np.int32).reshape(-1, 3))
        if len(ins) == 0 and len(dels) == 0:
            return self
        hi = max(int(ins.max(initial=0)), int(dels.max(initial=0)),
                 int(self.triples.max(initial=0)))
        lo = min(int(ins.min(initial=0)), int(dels.min(initial=0)))
        if hi >= (1 << 21) or lo < 0:  # ids too wide for fused order keys
            base = self.triples
            if len(dels):
                base = base[~triples_in(base, dels)]
            if len(ins):
                base = np.concatenate([base, ins])
            return TripleStore(base, self.dictionary)
        # net the batch: dedupe inserts, drop present inserts / absent
        # deletes, and let an insert win over a delete of the same triple
        if len(ins):
            ins = ins[np.unique(triple_keys(ins), return_index=True)[1]]
        if len(dels):
            dels = dels[self.contains(dels)]
            if len(ins):  # insert wins over delete of the same triple —
                dels = dels[~triples_in(dels, ins)]  # net BEFORE dropping
        if len(ins):      # inserts that are already present
            ins = ins[~self.contains(ins)]
        st = TripleStore.__new__(TripleStore)
        st.dictionary = self.dictionary
        st._stats = None
        st._rk = None
        st._indexes = {}
        st._keys = {}
        for name, perm in _ORDERS.items():
            data = self._indexes[name]
            keys = _order_keys(data, perm)
            if len(dels):
                pos = np.searchsorted(keys, _order_keys(dels, perm))
                keep = np.ones(len(data), dtype=bool)
                keep[pos] = False  # netted deletes are all present
                data, keys = data[keep], keys[keep]
            if len(ins):
                ik = _order_keys(ins, perm)
                io = np.argsort(ik, kind="stable")
                at = np.searchsorted(keys, ik[io])
                data = np.insert(data, at, ins[io], axis=0)
                if name == "spo":
                    st._rk = np.insert(keys, at, ik[io])
            elif name == "spo":
                st._rk = keys
            st._indexes[name] = data
            st._keys[name] = _fuse_keys(data[:, perm[:2]].reshape(-1, 2))
        st.triples = st._indexes["spo"]  # lexicographic == unique order
        return st

    def contains(self, triples: np.ndarray) -> np.ndarray:
        """Boolean membership mask for a (k, 3) batch of triples."""
        t = np.asarray(triples, np.int32).reshape(-1, 3)
        rk = self.row_keys
        if rk is None or (len(t) and (int(t.max(initial=0)) >= (1 << 21)
                                      or int(t.min(initial=0)) < 0)):
            return triples_in(t, self.triples)
        if len(t) == 0 or len(rk) == 0:
            return np.zeros(len(t), dtype=bool)
        k = _order_keys(t, (0, 1, 2))
        pos = np.searchsorted(rk, k)
        ok = pos < len(rk)
        out = np.zeros(len(t), dtype=bool)
        out[ok] = rk[pos[ok]] == k[ok]
        return out

    # ------------------------------------------------------------------
    @property
    def stats(self) -> Statistics:
        if self._stats is None:
            t = self.triples
            preds, counts = np.unique(t[:, 1], return_counts=True) if len(t) else ([], [])
            pc: dict[int, int] = {}
            pds: dict[int, int] = {}
            pdo: dict[int, int] = {}
            hist: dict[int, dict[int, int]] = {}
            for p, c in zip(np.asarray(preds).tolist(), np.asarray(counts).tolist()):
                mask = t[:, 1] == p
                pc[p] = int(c)
                pds[p] = int(len(np.unique(t[mask, 0])))
                objs, ocounts = np.unique(t[mask, 2], return_counts=True)
                pdo[p] = int(len(objs))
                if len(objs) <= _HIST_MAX_DISTINCT:
                    hist[p] = {int(o): int(n) for o, n in zip(objs, ocounts)}
            n_ids = int(t.max()) + 1 if len(t) else 0
            self._stats = Statistics(
                n_triples=len(t),
                n_ids=n_ids,
                pred_count=pc,
                pred_distinct_s=pds,
                pred_distinct_o=pdo,
                distinct_s=int(len(np.unique(t[:, 0]))) if len(t) else 0,
                distinct_o=int(len(np.unique(t[:, 2]))) if len(t) else 0,
                distinct_p=int(len(pc)),
                pred_obj_hist=hist,
            )
        return self._stats
