"""The triple table (TT): dictionary-encoded int32 triples + sorted indexes.

Storage model (TPU adaptation of the paper's RDBMS triple table):
  * one (N, 3) int32 array of deduplicated triples,
  * three sorted copies — SPO, POS, OSP — so that every bound-prefix
    access path is a contiguous range located by binary search
    (`searchsorted` on a fused uint64 key), the vectorized analogue of a
    clustered B-tree.

`Statistics` feeds the cost model (core/quality.py) and the static
capacity planner of the JAX engine.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

# all six orders (Hexastore [7] / RDF-3X [4], both cited by the paper):
# any bound prefix is a contiguous range AND the scan can emit rows
# pre-sorted on the column a downstream merge join needs (sort elision)
_ORDERS = {
    "spo": (0, 1, 2), "pos": (1, 2, 0), "osp": (2, 0, 1),
    "pso": (1, 0, 2), "ops": (2, 1, 0), "sop": (0, 2, 1),
}


def _fuse_keys(cols: np.ndarray) -> np.ndarray:
    """Fuse 2 leading sort columns into one uint64 key (ids are < 2^31)."""
    c = cols.astype(np.uint64)
    return (c[:, 0] << np.uint64(32)) | c[:, 1]


# keep a full object-value histogram for predicates with at most this many
# distinct objects (rdf:type and other categorical predicates): exact
# per-class counts instead of uniform averages.
_HIST_MAX_DISTINCT = 256


@dataclass(frozen=True)
class Statistics:
    n_triples: int
    n_ids: int
    pred_count: dict[int, int]          # p -> #triples
    pred_distinct_s: dict[int, int]     # p -> #distinct subjects
    pred_distinct_o: dict[int, int]     # p -> #distinct objects
    distinct_s: int
    distinct_o: int
    distinct_p: int
    pred_obj_hist: dict[int, dict[int, int]]  # p -> {o -> count}, low-card preds

    def atom_card(self, s_bound: bool, p: int | None, o_bound: bool,
                  o_val: int | None = None) -> float:
        """Estimated cardinality of one triple pattern (System-R style,
        exact histogram for categorical predicates)."""
        if p is not None:
            base = float(self.pred_count.get(p, 0))
            if base == 0.0:
                return 0.0
            if o_bound:
                hist = self.pred_obj_hist.get(p)
                if hist is not None and o_val is not None:
                    base = float(hist.get(o_val, 0))
                    if base == 0.0:
                        return 0.0
                else:
                    base /= max(self.pred_distinct_o.get(p, 1), 1)
            if s_bound:
                base /= max(self.pred_distinct_s.get(p, 1), 1)
            return max(base, 1e-3)
        base = float(self.n_triples)
        if s_bound:
            base /= max(self.distinct_s, 1)
        if o_bound:
            base /= max(self.distinct_o, 1)
        return max(base, 1e-3)


class TripleStore:
    def __init__(self, triples: np.ndarray, dictionary=None):
        triples = np.asarray(triples, dtype=np.int32).reshape(-1, 3)
        # dedupe
        if len(triples):
            triples = np.unique(triples, axis=0)
        self.triples = triples
        self.dictionary = dictionary
        self._indexes: dict[str, np.ndarray] = {}
        self._keys: dict[str, np.ndarray] = {}
        for name, perm in _ORDERS.items():
            proj = triples[:, perm]
            order = np.lexsort((proj[:, 2], proj[:, 1], proj[:, 0]))
            sorted_t = triples[order]
            self._indexes[name] = sorted_t
            self._keys[name] = _fuse_keys(sorted_t[:, perm[:2]].reshape(-1, 2))
        self._stats: Statistics | None = None

    def __len__(self) -> int:
        return len(self.triples)

    # ------------------------------------------------------------------
    # access paths
    # ------------------------------------------------------------------
    def index(self, name: str) -> np.ndarray:
        return self._indexes[name]

    def scan(self, s: int | None, p: int | None, o: int | None) -> np.ndarray:
        """All triples matching the (possibly unbound) pattern; (M,3)."""
        # choose the index whose sort prefix covers the bound positions
        if p is not None and o is not None:
            idx, key = "pos", (p, o)
        elif p is not None:
            idx, key = "pos", (p,)
        elif s is not None:
            idx, key = "spo", (s,) if o is None else (s,)
        elif o is not None:
            idx, key = "osp", (o,)
        else:
            res = self._indexes["spo"]
            return res
        data = self._indexes[idx]
        perm = _ORDERS[idx]
        if len(key) == 2:
            fused = self._keys[idx]
            target = (np.uint64(key[0]) << np.uint64(32)) | np.uint64(key[1])
            lo = np.searchsorted(fused, target, side="left")
            hi = np.searchsorted(fused, target, side="right")
        else:
            col = data[:, perm[0]]
            lo = np.searchsorted(col, key[0], side="left")
            hi = np.searchsorted(col, key[0], side="right")
        res = data[lo:hi]
        # residual filters for positions not covered by the index prefix
        for pos, val in (("s", s), ("p", p), ("o", o)):
            if val is None:
                continue
            col_i = {"s": 0, "p": 1, "o": 2}[pos]
            if col_i in (perm[0], perm[1])[: len(key)]:
                continue
            res = res[res[:, col_i] == val]
        return res

    # ------------------------------------------------------------------
    def insert(self, new_triples: np.ndarray) -> "TripleStore":
        """Functional insert (returns a new store); powers maintenance tests."""
        merged = np.concatenate([self.triples, np.asarray(new_triples, np.int32).reshape(-1, 3)])
        return TripleStore(merged, self.dictionary)

    # ------------------------------------------------------------------
    @property
    def stats(self) -> Statistics:
        if self._stats is None:
            t = self.triples
            preds, counts = np.unique(t[:, 1], return_counts=True) if len(t) else ([], [])
            pc: dict[int, int] = {}
            pds: dict[int, int] = {}
            pdo: dict[int, int] = {}
            hist: dict[int, dict[int, int]] = {}
            for p, c in zip(np.asarray(preds).tolist(), np.asarray(counts).tolist()):
                mask = t[:, 1] == p
                pc[p] = int(c)
                pds[p] = int(len(np.unique(t[mask, 0])))
                objs, ocounts = np.unique(t[mask, 2], return_counts=True)
                pdo[p] = int(len(objs))
                if len(objs) <= _HIST_MAX_DISTINCT:
                    hist[p] = {int(o): int(n) for o, n in zip(objs, ocounts)}
            n_ids = int(t.max()) + 1 if len(t) else 0
            self._stats = Statistics(
                n_triples=len(t),
                n_ids=n_ids,
                pred_count=pc,
                pred_distinct_s=pds,
                pred_distinct_o=pdo,
                distinct_s=int(len(np.unique(t[:, 0]))) if len(t) else 0,
                distinct_o=int(len(np.unique(t[:, 2]))) if len(t) else 0,
                distinct_p=int(len(pc)),
                pred_obj_hist=hist,
            )
        return self._stats
