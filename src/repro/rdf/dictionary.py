"""Dictionary encoding: URIs / literals <-> dense int32 ids.

Matches the paper's storage model: the triple table stores triples of
integers; all engine layers (numpy oracle, JAX engine, Pallas kernels)
operate on the encoded form only.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field


@dataclass
class Dictionary:
    _to_id: dict[str, int] = field(default_factory=dict)
    _to_str: list[str] = field(default_factory=list)

    def encode(self, s: str) -> int:
        i = self._to_id.get(s)
        if i is None:
            i = len(self._to_str)
            self._to_id[s] = i
            self._to_str.append(s)
        return i

    def encode_many(self, items) -> list[int]:
        return [self.encode(s) for s in items]

    def lookup(self, s: str) -> int | None:
        return self._to_id.get(s)

    def decode(self, i: int) -> str:
        return self._to_str[i]

    def __len__(self) -> int:
        return len(self._to_str)

    def __contains__(self, s: str) -> bool:
        return s in self._to_id

    # ------------------------------------------------------------------
    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self._to_str, f)

    @classmethod
    def load(cls, path: str) -> "Dictionary":
        with open(path) as f:
            strs = json.load(f)
        d = cls()
        for s in strs:
            d.encode(s)
        return d


RDF_TYPE = "rdf:type"
RDFS_SUBCLASS = "rdfs:subClassOf"
RDFS_SUBPROP = "rdfs:subPropertyOf"
RDFS_DOMAIN = "rdfs:domain"
RDFS_RANGE = "rdfs:range"
