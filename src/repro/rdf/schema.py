"""RDF Schema: subclass / subproperty hierarchies + domain / range.

All ids are dictionary-encoded ints.  `closure()` is reflexive-transitive;
reasoning is done once at load, then reformulation (core/reformulation.py)
consults the closed relations.
"""
from __future__ import annotations

from dataclasses import dataclass, field


def _transitive_closure(edges: dict[int, set[int]]) -> dict[int, set[int]]:
    """edges[x] = set of direct supers; returns reflexive-transitive closure
    mapping x -> all supers incl. x."""
    closed: dict[int, set[int]] = {}

    def visit(x: int, stack: set[int]) -> set[int]:
        if x in closed:
            return closed[x]
        if x in stack:  # cycle guard: treat as already-resolved
            return {x}
        stack.add(x)
        acc = {x}
        for y in edges.get(x, ()):
            acc |= visit(y, stack)
        stack.discard(x)
        closed[x] = acc
        return acc

    for x in list(edges):
        visit(x, set())
    return closed


@dataclass
class RDFSchema:
    """subclass/subproperty edges are child -> {direct parents}."""

    subclass: dict[int, set[int]] = field(default_factory=dict)
    subprop: dict[int, set[int]] = field(default_factory=dict)
    domain: dict[int, int] = field(default_factory=dict)   # prop -> class
    range_: dict[int, int] = field(default_factory=dict)   # prop -> class

    _sup_class: dict[int, set[int]] | None = None
    _sup_prop: dict[int, set[int]] | None = None
    _sub_class: dict[int, set[int]] | None = None
    _sub_prop: dict[int, set[int]] | None = None

    # ------------------------------------------------------------------
    def add_subclass(self, child: int, parent: int) -> None:
        self.subclass.setdefault(child, set()).add(parent)
        self._invalidate()

    def add_subprop(self, child: int, parent: int) -> None:
        self.subprop.setdefault(child, set()).add(parent)
        self._invalidate()

    def set_domain(self, prop: int, cls: int) -> None:
        self.domain[prop] = cls
        self._invalidate()

    def set_range(self, prop: int, cls: int) -> None:
        self.range_[prop] = cls
        self._invalidate()

    def _invalidate(self) -> None:
        self._sup_class = self._sup_prop = None
        self._sub_class = self._sub_prop = None

    # ------------------------------------------------------------------
    def _ensure_closed(self) -> None:
        if self._sup_class is None:
            self._sup_class = _transitive_closure(self.subclass)
            self._sup_prop = _transitive_closure(self.subprop)
            inv_c: dict[int, set[int]] = {}
            for c, sups in self._sup_class.items():
                for s in sups:
                    inv_c.setdefault(s, set()).add(c)
            inv_p: dict[int, set[int]] = {}
            for p, sups in self._sup_prop.items():
                for s in sups:
                    inv_p.setdefault(s, set()).add(p)
            self._sub_class = inv_c
            self._sub_prop = inv_p

    def superclasses(self, c: int) -> set[int]:
        self._ensure_closed()
        return self._sup_class.get(c, {c}) | {c}

    def subclasses(self, c: int) -> set[int]:
        """All classes C' with C' <= c (reflexive)."""
        self._ensure_closed()
        return self._sub_class.get(c, set()) | {c}

    def subproperties(self, p: int) -> set[int]:
        self._ensure_closed()
        return self._sub_prop.get(p, set()) | {p}

    def props_with_domain_under(self, c: int) -> set[int]:
        """Properties P with domain(P) <= c: (x P y) entails (x type c)."""
        subs = self.subclasses(c)
        return {p for p, d in self.domain.items() if d in subs}

    def props_with_range_under(self, c: int) -> set[int]:
        subs = self.subclasses(c)
        return {p for p, r in self.range_.items() if r in subs}

    def saturate_instance(self, triples, type_id: int):
        """Forward-chain RDFS entailment over instance triples (numpy array
        (N,3)).  Used as the ground truth that query reformulation must
        match (completeness check).  Returns an (M,3) array, M >= N.
        """
        import numpy as np

        self._ensure_closed()
        out = {tuple(t) for t in np.asarray(triples).tolist()}
        changed = True
        while changed:
            changed = False
            new: set[tuple[int, int, int]] = set()
            for s, p, o in out:
                if p == type_id:
                    for sup in self.superclasses(o):
                        t = (s, type_id, sup)
                        if t not in out:
                            new.add(t)
                else:
                    for sup in self._sup_prop.get(p, set()) | {p}:
                        if sup != p:
                            t = (s, sup, o)
                            if t not in out:
                                new.add(t)
                    d = self.domain.get(p)
                    if d is not None:
                        t = (s, type_id, d)
                        if t not in out:
                            new.add(t)
                    r = self.range_.get(p)
                    if r is not None:
                        t = (o, type_id, r)
                        if t not in out:
                            new.add(t)
            if new:
                out |= new
                changed = True
        arr = np.array(sorted(out), dtype=np.int32)
        return arr
