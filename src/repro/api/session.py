"""TuningSession: the stateful lifecycle API of the storage wizard.

The paper frames RDFViewS as a one-shot wizard; a production store is
tuned continuously.  A session owns the triple store, the RDFS schema
and an evolving workload, and drives the pipeline incrementally:

    session = TuningSession(store, workload, schema=schema)
    session.retune()            # cold: search from the initial state
    session.apply()             # materialize + compile the chosen views
    session.add_query(q_new)    # the workload drifts...
    session.retune()            # warm: search resumes from the last best
    session.apply()             # delta swap: only new views materialize
    server = session.serve()    # batched serving + online retuning
    session.save("ckpt/")       # persist; TuningSession.load resumes

`retune()` warm-starts the States Navigator from the previous best
state (grafting added queries in their initial-state shape, dropping
removed ones) instead of re-deriving everything from `initial_state` —
strictly fewer states explored for a workload perturbation.  `apply()`
diffs old vs new view configurations by canonical key so the
materializer only evaluates genuinely new views, dead extents are
dropped, and the fused executor hot-swaps its compiled workload program
in place (a `QueryServer` holding it keeps serving).

`core.wizard.tune()` remains as a one-shot compatibility shim over a
throwaway session.
"""
from __future__ import annotations

import json
import os
from dataclasses import dataclass, field, replace

import numpy as np

from repro.checkpoint import checkpoint as ckpt
from repro.core.executor import QueryExecutor
from repro.core.quality import (MaintenanceCostModel, QualityBreakdown,
                                quality)
from repro.core.queries import CQ
from repro.core.reformulation import infer_type_id, reformulate_workload
from repro.core.search import SearchResult, search
from repro.core.state import (State, drop_queries, graft_queries,
                              initial_state)
from repro.core.wizard import WizardConfig
from repro.rdf.dictionary import Dictionary
from repro.rdf.schema import RDFSchema
from repro.rdf.triples import TripleStore

from repro.api import serde

_SESSION_FILE = "session.json"
_PAYLOAD_VERSION = 1


@dataclass
class RetuneReport:
    """One navigator run inside a session."""

    result: SearchResult
    seed: State                 # state the navigator started from
    seed_quality: QualityBreakdown
    warm: bool                  # resumed from the previous best?
    added: list[str] = field(default_factory=list)    # member names grafted
    removed: list[str] = field(default_factory=list)  # member names dropped

    def summary(self) -> str:
        mode = "warm" if self.warm else "cold"
        return (f"{mode} retune (+{len(self.added)}/-{len(self.removed)} "
                f"members): seed total={self.seed_quality.total:.1f}; "
                f"{self.result.summary()}")


@dataclass
class SessionSnapshot:
    """Every binding a retune/apply cycle mutates, captured so an
    online edit can be rolled back atomically (`TuningSession.restore`).
    The executor is snapshotted alongside because `apply()` hot-swaps
    it in place."""

    workload: dict[str, CQ]
    groups: dict[str, list[str]]
    best: State | None
    best_quality: QualityBreakdown | None
    applied: State | None
    type_id: int | None
    store: TripleStore
    executor: QueryExecutor | None
    executor_snap: object | None    # core.executor.ExecutorSnapshot


@dataclass
class ApplyReport:
    """One view swap: which extents were touched."""

    materialized: list[int]     # view ids actually evaluated
    reused: list[int]           # view ids carried over by canonical key
    dropped: list[int]          # previous view ids discarded
    full: bool                  # first apply (everything materialized)

    def summary(self) -> str:
        kind = "full" if self.full else "delta"
        return (f"{kind} apply: materialized={len(self.materialized)} "
                f"reused={len(self.reused)} dropped={len(self.dropped)}")


class TuningSession:
    """Stateful wizard: evolve the workload, retune incrementally, swap
    view configurations online, persist and resume."""

    def __init__(self, store: TripleStore, workload=(),
                 schema: RDFSchema | None = None, type_id: int | None = None,
                 cfg: WizardConfig | None = None):
        self.store = store
        self.schema = schema
        self.cfg = cfg or WizardConfig()
        self._type_id = type_id
        self._workload: dict[str, CQ] = {}
        for q in workload:
            self.add_query(q)
        self._groups: dict[str, list[str]] = {}
        self._best: State | None = None
        self._best_quality: QualityBreakdown | None = None
        self._applied: State | None = None
        self.executor: QueryExecutor | None = None
        # measured per-view maintenance costs (EWMA units/triple, keyed
        # by canonical view key).  A streaming `ViewMaintainer` — bound
        # via serve(maintenance=) or ingest() — shares this object and
        # fills it in; once populated, retune() optimizes against the
        # MEASURED costs instead of the static estimate.
        self.maintenance_costs = MaintenanceCostModel()
        self._maintainer = None
        # chaos injector (duck-typed: .fire(site)); set by a QueryServer
        # constructed with chaos= so retune/apply become fault boundaries
        self.fault_hook = None

    # ------------------------------------------------------------------
    # workload evolution
    # ------------------------------------------------------------------
    def add_query(self, q: CQ) -> None:
        if not q.name:
            raise ValueError("workload queries must be named")
        if q.name in self._workload:
            raise ValueError(f"duplicate query name {q.name!r}")
        self._workload[q.name] = q

    def remove_query(self, name: str) -> CQ:
        if name not in self._workload:
            raise KeyError(f"unknown query {name!r}")
        return self._workload.pop(name)

    @property
    def workload(self) -> list[CQ]:
        return list(self._workload.values())

    @property
    def groups(self) -> dict[str, list[str]]:
        return self._groups

    @property
    def best(self) -> State | None:
        return self._best

    @property
    def best_quality(self) -> QualityBreakdown | None:
        return self._best_quality

    # ------------------------------------------------------------------
    # retune: warm-started States Navigator
    # ------------------------------------------------------------------
    def _resolve_type_id(self) -> int | None:
        if not (self.cfg.use_schema and self.schema is not None):
            return None
        if self._type_id is None:
            self._type_id = infer_type_id(self.workload, self.schema)
        if self._type_id is None:
            raise ValueError(
                "type_id is required for schema reformulation and could "
                "not be inferred unambiguously from the workload; pass "
                "type_id= explicitly")
        return self._type_id

    def _members(self) -> tuple[list[CQ], dict[str, list[str]]]:
        if self.cfg.use_schema and self.schema is not None:
            return reformulate_workload(self.workload, self.schema,
                                        self._resolve_type_id(),
                                        self.cfg.max_reformulations)
        return self.workload, {q.name: [q.name] for q in self.workload}

    def _search_cfg(self):
        """The session's search config with measured maintenance costs
        (if a maintainer has observed any) overriding the static
        estimate in the quality objective."""
        if len(self.maintenance_costs):
            return replace(self.cfg.search,
                           maint_model=self.maintenance_costs)
        return self.cfg.search

    def retune(self) -> RetuneReport:
        """Re-run the States Navigator against the current workload.

        First call searches cold from the paper's initial state; later
        calls warm-start from the previous best: kept queries retain
        their already-relaxed views and rewritings, added queries are
        grafted in initial-state shape, removed queries are dropped (and
        their now-dead views garbage-collected).
        """
        if not self._workload:
            raise ValueError("cannot retune an empty workload")
        if self.fault_hook is not None:
            self.fault_hook.fire("retune")
        members, groups = self._members()
        added: list[str] = []
        removed: list[str] = []
        if self._best is None:
            seed = initial_state(members)
            warm = False
        else:
            warm = True
            seed = self._best
            prev_names = {q.name for q in seed.queries}
            new_names = {m.name for m in members}
            removed = sorted(prev_names - new_names)
            if removed:
                seed = drop_queries(seed, set(removed))
            grafts = [m for m in members if m.name not in prev_names]
            added = [m.name for m in grafts]
            if grafts:
                seed = graft_queries(seed, grafts)
        cfg = self._search_cfg()
        seed_q = quality(seed, self.store.stats, cfg.weights,
                         cfg.maint_model)
        result = search(seed, self.store.stats, cfg)
        self._best, self._best_quality = result.best, result.best_quality
        self._groups = groups
        return RetuneReport(result=result, seed=seed, seed_quality=seed_q,
                            warm=warm, added=added, removed=removed)

    # ------------------------------------------------------------------
    # apply: delta view swap
    # ------------------------------------------------------------------
    def apply(self, warm: bool = True) -> ApplyReport:
        """Install the last retune's best configuration.

        The first apply materializes everything and compiles the fused
        executor; every later apply is a delta swap — only views whose
        canonical key changed are materialized, surviving extents are
        reused (column-permuted), dead extents dropped, and the compiled
        workload program is hot-swapped on the SAME executor object.

        With `warm=True` (default) the incoming program is pre-warmed
        before apply returns: every shape-bucket body is compiled —
        mostly hits in the persistent compile cache, since a retuned
        workload largely reuses the old program's shapes — capacities
        the old program learned adaptively are carried over, and the
        workload results are cached.  A `QueryServer` holding this
        executor therefore never pays a cold compile on the serving
        path after a retune()+apply() hot swap.
        """
        if self._best is None:
            raise RuntimeError("retune() before apply()")
        if self.fault_hook is not None:
            self.fault_hook.fire("apply")
        if self.executor is None:
            self.executor = QueryExecutor(self.store, self._best,
                                          self._groups,
                                          use_pallas=self.cfg.use_pallas,
                                          fault_hook=self.fault_hook)
            if warm:
                self.executor.warmup()
            report = ApplyReport(materialized=sorted(self._best.views),
                                 reused=[], dropped=[], full=True)
        else:
            swap = self.executor.swap_state(self._best, self._groups,
                                            warm=warm)
            report = ApplyReport(full=False, **swap)
            if self._maintainer is not None:
                # same executor object, new view set: rebuild delta plans
                # and re-establish the capacity-class invariants
                self._maintainer.rebind(self.executor)
        self._applied = self._best
        return report

    @property
    def pending(self) -> bool:
        """True when the last retune has not been applied yet."""
        return self._best is not None and self._best is not self._applied

    # ------------------------------------------------------------------
    # transactional edits
    # ------------------------------------------------------------------
    def snapshot(self) -> SessionSnapshot:
        """Capture the session (and its live executor) before an online
        edit, so a failed add/remove + retune + apply can be rolled back
        as one transaction (`restore`)."""
        return SessionSnapshot(
            workload=dict(self._workload),
            groups={k: list(v) for k, v in self._groups.items()},
            best=self._best, best_quality=self._best_quality,
            applied=self._applied, type_id=self._type_id, store=self.store,
            executor=self.executor,
            executor_snap=(self.executor.snapshot()
                           if self.executor is not None else None))

    def restore(self, snap: SessionSnapshot) -> None:
        """Roll the session back to a snapshot.  The executor OBJECT is
        restored in place (servers hold it by reference), so after a
        crashed retune/apply the previous compiled program keeps
        serving."""
        self._workload = dict(snap.workload)
        self._groups = {k: list(v) for k, v in snap.groups.items()}
        self._best, self._best_quality = snap.best, snap.best_quality
        self._applied = snap.applied
        self._type_id = snap.type_id
        self.store = snap.store
        if snap.executor is None:
            self.executor = None
        else:
            self.executor = snap.executor
            self.executor.restore(snap.executor_snap)

    # ------------------------------------------------------------------
    # answering / serving
    # ------------------------------------------------------------------
    def _ensure_applied(self) -> QueryExecutor:
        if self._best is None:
            self.retune()
        if self.executor is None or self.pending:
            self.apply()
        return self.executor

    def answer(self, name: str) -> set[tuple[int, ...]]:
        """Union-group semantics over the original workload query."""
        return self._ensure_applied().answer_group(name)

    def serve(self, maintenance=None, chaos=None, policy=None):
        """Batched query server bound to this session's executor; the
        server survives `retune()+apply()` (hot swap) and can trigger
        them itself via `QueryServer.retune_online`.

        Pass `maintenance=` (True, a `repro.maintenance.MaintenanceConfig`
        or a pre-built `ViewMaintainer`) to serve a STREAMING store: the
        server then accepts update batches (`submit`) and keeps answers
        within the configured staleness budget, with measured per-view
        maintenance costs feeding this session's retune objective.

        `chaos=` attaches a `repro.serve.chaos.FaultInjector` to every
        serving fault boundary; `policy=` overrides the degradation
        ladder's `repro.distributed.fault.RetryPolicy`."""
        from repro.serve.query_server import QueryServer

        if maintenance is True:
            from repro.maintenance import MaintenanceConfig

            maintenance = MaintenanceConfig()
        return QueryServer(self._ensure_applied(), session=self,
                           maintenance=maintenance, chaos=chaos,
                           policy=policy)

    def serve_async(self, classes=None, frontend=None, maintenance=None,
                    chaos=None, policy=None, sharded=False, mesh=None,
                    clock=None, service_model=None):
        """Async serving frontend over this session's tuned workload:
        bounded request queue, micro-batching window, per-class latency
        SLOs with admission control — the `repro.serve.frontend`
        subsystem, wired to a server bound to this session.

        `classes`: iterable of `repro.serve.frontend.QueryClass` (default
        one best-effort class).  `frontend`: a `FrontendConfig` with the
        queue/window/admission knobs.  `clock`/`service_model` inject the
        virtual clock and batch service model (tests pin both for
        determinism).

        `sharded=True` serves through a `repro.serve.sharded.
        ShardedBackend` over `mesh` (default: all local devices) instead
        of the single-device `QueryServer`: per-shard health, quorum
        rollup, host fallback for degraded shards.  The sharded backend
        is static-store, so it cannot be combined with `maintenance=`.
        """
        from repro.serve.frontend import (FrontendConfig, QueryClass,
                                          ServingFrontend)

        if classes is None:
            classes = [QueryClass("default")]
        if sharded:
            if maintenance is not None:
                raise ValueError(
                    "sharded serving is static-store: maintenance= is "
                    "only supported with sharded=False")
            from repro.serve.sharded import ShardedBackend

            server = ShardedBackend(self._ensure_applied(), mesh=mesh,
                                    policy=policy)
        else:
            server = self.serve(maintenance=maintenance, chaos=chaos,
                                policy=policy)
        return ServingFrontend(server, classes,
                               cfg=frontend or FrontendConfig(),
                               clock=clock, service_model=service_model)

    # ------------------------------------------------------------------
    # streaming ingestion (serverless path)
    # ------------------------------------------------------------------
    def maintainer(self, cfg=None):
        """The session's incremental `ViewMaintainer`, created lazily
        against the applied executor.  Shares `maintenance_costs` so
        measured costs flow into later retunes."""
        from repro.maintenance import MaintenanceConfig, ViewMaintainer

        ex = self._ensure_applied()
        if self._maintainer is None or self._maintainer.executor is not ex:
            self._maintainer = ViewMaintainer(
                ex, cfg or MaintenanceConfig(),
                costs=self.maintenance_costs)
        return self._maintainer

    def ingest(self, inserts=None, deletes=None):
        """Apply one triple delta batch incrementally: view extents and
        TT indexes are maintained in place on device (no refresh, no
        recompile in steady state) and the session's store advances to
        the post-delta table.  Returns the `MaintenanceReport`."""
        from repro.maintenance import Delta

        report = self.maintainer().apply(Delta.of(inserts, deletes))
        self.store = self.executor.store
        return report

    # ------------------------------------------------------------------
    # static verification
    # ------------------------------------------------------------------
    def verify(self, strict: bool = False):
        """Statically verify the session's current configuration — plan-IR
        soundness, capacity/recompile hazards, bucket-body lint — without
        executing anything (`repro.analysis`).  With an applied executor
        the live program (real extent statistics, learned capacities) is
        verified; after a bare `retune()` the tuned best state is
        analyzed from cost estimates.  Returns the `AnalysisReport`;
        `strict=True` raises `InvariantViolation` unless it is clean.
        """
        from repro import analysis
        from repro.errors import InvariantViolation

        report = analysis.verify_session(self)
        if strict and not report.clean():
            raise InvariantViolation(
                "session verification failed:\n" + report.format())
        return report

    # ------------------------------------------------------------------
    # persistence
    # ------------------------------------------------------------------
    def save(self, ckpt_dir: str, step: int | None = None) -> str:
        """Persist the session: triple table through the atomic array
        checkpointer, symbolic state (workload, schema, best state,
        groups) as a session.json sidecar.  Returns the step directory."""
        if step is None:
            latest = ckpt.latest_step(ckpt_dir)
            step = 0 if latest is None else latest + 1
        path = ckpt.save(ckpt_dir, step, {"triples": self.store.triples})
        d = self.store.dictionary
        payload = {
            "version": _PAYLOAD_VERSION,
            "type_id": self._type_id,
            "cfg": serde.cfg_to_json(self.cfg),
            "dictionary": list(d._to_str) if d is not None else None,
            "schema": (serde.schema_to_json(self.schema)
                       if self.schema is not None else None),
            "workload": [serde.cq_to_json(q) for q in self.workload],
            "best": (serde.state_to_json(self._best)
                     if self._best is not None else None),
            "groups": self._groups,
        }
        with open(os.path.join(path, _SESSION_FILE), "w") as f:
            json.dump(payload, f)
        return path

    @classmethod
    def load(cls, ckpt_dir: str, step: int | None = None,
             cfg: WizardConfig | None = None) -> "TuningSession":
        """Resume a saved session: the next retune() warm-starts from the
        restored best state.  The executor is rebuilt lazily on the
        first apply() (device buffers are not checkpointed).  The saved
        config — search strategy, budgets, quality weights — is restored
        with the session so the tuning objective survives the round
        trip; pass cfg= only to deliberately override it."""
        if step is None:
            step = ckpt.latest_step(ckpt_dir)
            if step is None:
                raise FileNotFoundError(f"no checkpoint under {ckpt_dir!r}")
        with open(os.path.join(ckpt_dir, f"step_{step:08d}",
                               _SESSION_FILE)) as f:
            payload = json.load(f)
        if payload["version"] != _PAYLOAD_VERSION:
            raise ValueError(
                f"unsupported session payload version {payload['version']}")
        arrays = ckpt.restore(ckpt_dir, step,
                              {"triples": np.zeros((0, 3), np.int32)})
        dictionary = None
        if payload["dictionary"] is not None:
            dictionary = Dictionary()
            dictionary.encode_many(payload["dictionary"])
        store = TripleStore(arrays["triples"], dictionary)
        schema = (serde.schema_from_json(payload["schema"])
                  if payload["schema"] is not None else None)
        if cfg is None:
            cfg = serde.cfg_from_json(payload["cfg"])
        session = cls(store,
                      workload=[serde.cq_from_json(q)
                                for q in payload["workload"]],
                      schema=schema, type_id=payload["type_id"], cfg=cfg)
        if payload["best"] is not None:
            session._best = serde.state_from_json(payload["best"])
            session._best_quality = quality(session._best, store.stats,
                                            session.cfg.search.weights)
            session._groups = {k: list(v)
                               for k, v in payload["groups"].items()}
        return session
