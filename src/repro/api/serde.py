"""JSON (de)serialization of the symbolic tuning state.

Session persistence splits into two artifacts: the triple table goes
through the array checkpointer (`checkpoint/checkpoint.py`, atomic
manifest + npz), while everything symbolic — workload CQs, the tuned
State ⟨V, R⟩ with its rewriting plans, the RDFS schema, the dictionary
— round-trips through the encoders here into a `session.json` sidecar.

Encodings are tagged dicts/lists, versioned by the session payload; the
invariant is `X_from_json(X_to_json(x)) == x` for every CQ/Plan/State.
"""
from __future__ import annotations

from repro.core.quality import QualityWeights
from repro.core.queries import CQ, Atom, Const, Term, Var
from repro.core.search import SearchConfig
from repro.core.state import State, View
from repro.core.wizard import WizardConfig
from repro.query.plan import (EquiJoin, Filter, Plan, Project, TTScan,
                              ViewRef)
from repro.rdf.schema import RDFSchema


# ----------------------------------------------------------------------
# terms / atoms / CQs
# ----------------------------------------------------------------------
def term_to_json(t: Term):
    return {"v": t.name} if isinstance(t, Var) else {"c": t.id}


def term_from_json(d) -> Term:
    return Var(d["v"]) if "v" in d else Const(int(d["c"]))


def cq_to_json(q: CQ) -> dict:
    return {
        "head": [h.name for h in q.head],
        "atoms": [[term_to_json(t) for t in a.terms()] for a in q.atoms],
        "name": q.name,
        "weight": q.weight,
    }


def cq_from_json(d: dict) -> CQ:
    return CQ(
        head=tuple(Var(n) for n in d["head"]),
        atoms=tuple(Atom(*(term_from_json(t) for t in a)) for a in d["atoms"]),
        name=d["name"],
        weight=float(d["weight"]),
    )


# ----------------------------------------------------------------------
# rewriting plans
# ----------------------------------------------------------------------
def plan_to_json(p: Plan) -> dict:
    if isinstance(p, ViewRef):
        return {"op": "view", "vid": p.view_id, "schema": list(p.schema)}
    if isinstance(p, TTScan):
        return {"op": "tt", "atom": [term_to_json(t) for t in p.atom.terms()]}
    if isinstance(p, Filter):
        return {"op": "filter", "child": plan_to_json(p.child),
                "col": p.col, "value": p.value}
    if isinstance(p, EquiJoin):
        return {"op": "join", "left": plan_to_json(p.left),
                "right": plan_to_json(p.right),
                "pairs": [list(pr) for pr in p.pairs]}
    if isinstance(p, Project):
        return {"op": "project", "child": plan_to_json(p.child),
                "cols": list(p.cols), "dedupe": p.dedupe}
    raise TypeError(type(p))


def plan_from_json(d: dict) -> Plan:
    op = d["op"]
    if op == "view":
        return ViewRef(int(d["vid"]), tuple(d["schema"]))
    if op == "tt":
        return TTScan(Atom(*(term_from_json(t) for t in d["atom"])))
    if op == "filter":
        return Filter(plan_from_json(d["child"]), d["col"], int(d["value"]))
    if op == "join":
        return EquiJoin(plan_from_json(d["left"]), plan_from_json(d["right"]),
                        tuple((l, r) for l, r in d["pairs"]))
    if op == "project":
        return Project(plan_from_json(d["child"]), tuple(d["cols"]),
                       bool(d["dedupe"]))
    raise ValueError(f"unknown plan op {op!r}")


# ----------------------------------------------------------------------
# search states
# ----------------------------------------------------------------------
def state_to_json(s: State) -> dict:
    return {
        "views": {str(vid): cq_to_json(v.cq) for vid, v in s.views.items()},
        "rewritings": {n: plan_to_json(p) for n, p in s.rewritings.items()},
        "queries": [cq_to_json(q) for q in s.queries],
        "next_view_id": s.next_view_id,
        "next_fresh": s.next_fresh,
        "path": list(s.path),
    }


def state_from_json(d: dict) -> State:
    views = {int(k): View(id=int(k), cq=cq_from_json(v))
             for k, v in d["views"].items()}
    return State(
        views=views,
        rewritings={n: plan_from_json(p) for n, p in d["rewritings"].items()},
        queries=tuple(cq_from_json(q) for q in d["queries"]),
        next_view_id=int(d["next_view_id"]),
        next_fresh=int(d["next_fresh"]),
        path=tuple(d["path"]),
    )


# ----------------------------------------------------------------------
# wizard / search configuration
# ----------------------------------------------------------------------
def cfg_to_json(cfg: WizardConfig) -> dict:
    s, w = cfg.search, cfg.search.weights
    return {
        "use_schema": cfg.use_schema,
        "max_reformulations": cfg.max_reformulations,
        "use_pallas": cfg.use_pallas,
        # SearchConfig.initial (a State) is session-transient by design:
        # the session re-seeds every retune from its restored best
        "search": {
            "strategy": s.strategy, "max_states": s.max_states,
            "max_seconds": s.max_seconds, "beam_width": s.beam_width,
            "anneal_steps": s.anneal_steps, "anneal_t0": s.anneal_t0,
            "anneal_decay": s.anneal_decay, "seed": s.seed,
            "allow_predicate_cut": s.allow_predicate_cut,
            "stop_fully_relaxed": s.stop_fully_relaxed,
        },
        "weights": {"w_exec": w.w_exec, "w_maint": w.w_maint,
                    "w_space": w.w_space, "update_rate": w.update_rate},
    }


def cfg_from_json(d: dict) -> WizardConfig:
    weights = QualityWeights(**d["weights"])
    return WizardConfig(
        search=SearchConfig(weights=weights, **d["search"]),
        use_schema=d["use_schema"],
        max_reformulations=d["max_reformulations"],
        use_pallas=d["use_pallas"],
    )


# ----------------------------------------------------------------------
# RDFS schema
# ----------------------------------------------------------------------
def schema_to_json(s: RDFSchema) -> dict:
    return {
        "subclass": {str(c): sorted(ps) for c, ps in s.subclass.items()},
        "subprop": {str(c): sorted(ps) for c, ps in s.subprop.items()},
        "domain": {str(p): c for p, c in s.domain.items()},
        "range": {str(p): c for p, c in s.range_.items()},
    }


def schema_from_json(d: dict) -> RDFSchema:
    return RDFSchema(
        subclass={int(c): set(ps) for c, ps in d["subclass"].items()},
        subprop={int(c): set(ps) for c, ps in d["subprop"].items()},
        domain={int(p): int(c) for p, c in d["domain"].items()},
        range_={int(p): int(c) for p, c in d["range"].items()},
    )
