"""Public API facade for the RDFViewS wizard.

The supported surface for applications:

    from repro.api import TuningSession, WizardConfig, SearchConfig

Everything else under `repro.*` is engine internals and may change
between releases.  `repro.core.wizard.tune` remains as a deprecated
one-shot shim over a throwaway `TuningSession`.
"""
from repro.core.quality import MaintenanceCostModel, QualityWeights
from repro.core.search import SearchConfig
from repro.core.wizard import WizardConfig
from repro.maintenance import Delta, MaintenanceConfig
# async serving frontend config surface (pure-python, no jax import)
from repro.serve.frontend import (FrontendConfig, QueryClass,  # noqa: F401
                                  ServingFrontend)
from repro.serve.loadgen import ClassSpec, TrafficConfig  # noqa: F401

from repro.api.session import (ApplyReport, RetuneReport,  # noqa: F401
                               TuningSession)

__all__ = [
    "TuningSession",
    "RetuneReport",
    "ApplyReport",
    "WizardConfig",
    "SearchConfig",
    "QualityWeights",
    "MaintenanceCostModel",
    "MaintenanceConfig",
    "Delta",
    "FrontendConfig",
    "QueryClass",
    "ServingFrontend",
    "ClassSpec",
    "TrafficConfig",
]
