"""The storage tuning wizard CLI (the demo's GUI, headless).

    PYTHONPATH=src python -m repro.launch.tune --universities 2 \
        --strategy greedy --w-exec 1 --w-maint 0.1 --w-space 0.01 --verify
"""
from __future__ import annotations

import argparse

from repro.core.quality import QualityWeights
from repro.core.search import SearchConfig
from repro.core.wizard import WizardConfig, tune
from repro.rdf.generator import generate, lubm_workload


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--universities", type=int, default=1)
    ap.add_argument("--strategy", default="greedy",
                    choices=["exhaustive_dfs", "best_first", "greedy", "beam",
                             "anneal"])
    ap.add_argument("--max-states", type=int, default=1000)
    ap.add_argument("--max-seconds", type=float, default=30.0)
    ap.add_argument("--w-exec", type=float, default=1.0)
    ap.add_argument("--w-maint", type=float, default=0.1)
    ap.add_argument("--w-space", type=float, default=0.01)
    ap.add_argument("--no-schema", action="store_true")
    ap.add_argument("--verify", action="store_true",
                    help="check view answers == direct evaluation")
    args = ap.parse_args()

    uni = generate(n_universities=args.universities, seed=0)
    workload = lubm_workload(uni.dictionary)
    cfg = WizardConfig(
        search=SearchConfig(
            strategy=args.strategy, max_states=args.max_states,
            max_seconds=args.max_seconds,
            weights=QualityWeights(w_exec=args.w_exec, w_maint=args.w_maint,
                                   w_space=args.w_space)),
        use_schema=not args.no_schema,
    )
    print(f"TT: {len(uni.store)} triples; workload: {len(workload)} queries")
    rep = tune(uni.store, workload, uni.schema, uni.type_id, cfg)
    print(rep.summary())

    if args.verify:
        ok = True
        for q in workload:
            got = rep.executor.answer_group(q.name)
            want = rep.executor.answer_group_direct(q.name)
            status = "ok" if got == want else "MISMATCH"
            ok &= got == want
            print(f"  {q.name}: {len(got)} answers [{status}]")
        print("verification:", "PASSED" if ok else "FAILED")


if __name__ == "__main__":
    main()
