"""Render the dry-run artifacts into the EXPERIMENTS.md tables.

    PYTHONPATH=src python -m repro.launch.report             # markdown
    PYTHONPATH=src python -m repro.launch.report --pick      # hillclimb picks
"""
from __future__ import annotations

import argparse
import glob
import json
import os

ART = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                   "artifacts", "dryrun")


def load_all(tag: str = "") -> list[dict]:
    out = []
    for path in sorted(glob.glob(os.path.join(ART, "*.json"))):
        base = os.path.basename(path)
        parts = base[:-5].split("__")
        if tag and not base.endswith(f".{tag}.json"):
            continue
        if not tag and len(parts[-1].split(".")) > 1:
            continue
        with open(path) as f:
            d = json.load(f)
        d["_file"] = base
        out.append(d)
    return out


def fmt_s(x: float) -> str:
    if x == 0:
        return "0"
    if x < 1e-3:
        return f"{x*1e6:.0f}µs"
    if x < 1:
        return f"{x*1e3:.1f}ms"
    return f"{x:.2f}s"


def dryrun_table(cells: list[dict]) -> str:
    rows = ["| arch | shape | mesh | status | compile | bytes/dev (args+tmp) | collective ops |",
            "|---|---|---|---|---|---|---|"]
    for d in cells:
        mesh = "2x16x16" if d.get("multi_pod") else "16x16"
        if d.get("status") == "skipped":
            rows.append(f"| {d['arch']} | {d['shape']} | {mesh} | skipped"
                        f" | — | — | — |")
            continue
        mem = d.get("memory", {})
        gb = (mem.get("argument_bytes", 0) + mem.get("temp_bytes", 0)) / 2**30
        det = d.get("roofline", {}).get("collective_detail", {})
        ops = ",".join(f"{k}:{v}" for k, v in
                       sorted(det.get("count", {}).items()))
        rows.append(
            f"| {d['arch']} | {d['shape']} | {mesh} | ok | "
            f"{d.get('compile_s', 0):.1f}s | {gb:.2f} GiB | {ops or '—'} |")
    return "\n".join(rows)


def roofline_table(cells: list[dict], multi_pod: bool = False) -> str:
    rows = ["| arch | shape | t_comp | t_mem | t_coll | bound | useful | roofline frac |",
            "|---|---|---|---|---|---|---|---|"]
    for d in cells:
        if bool(d.get("multi_pod")) != multi_pod or d.get("status") != "ok":
            continue
        r = d.get("roofline_corrected") or d.get("roofline", {})
        if not r:
            continue
        rows.append(
            f"| {d['arch']} | {d['shape']} | {fmt_s(r['t_compute_s'])} | "
            f"{fmt_s(r['t_memory_s'])} | {fmt_s(r['t_collective_s'])} | "
            f"{r['bottleneck']} | {r['useful_flops_ratio']:.2f} | "
            f"{r['roofline_fraction']:.3f} |")
    return "\n".join(rows)


def picks(cells: list[dict]) -> dict:
    """The three hillclimb cells: worst fraction, most collective-bound,
    paper-representative (the query_step is always the third)."""
    pod1 = [d for d in cells if not d.get("multi_pod")
            and d.get("status") == "ok" and d.get("kind") != "query"]

    def rc(d):
        return d.get("roofline_corrected") or d["roofline"]

    # worst fraction among heavyweight cells (train/prefill carry the flops)
    heavy = [d for d in pod1 if d["kind"] in ("train", "prefill")]
    worst = min(heavy, key=lambda d: rc(d)["roofline_fraction"])
    coll = max(pod1, key=lambda d: (rc(d)["t_collective_s"] /
                                    max(max(rc(d)["t_compute_s"],
                                            rc(d)["t_memory_s"]), 1e-12)))
    return {
        "worst_fraction": (worst["arch"], worst["shape"],
                           rc(worst)["roofline_fraction"]),
        "most_collective": (coll["arch"], coll["shape"],
                            rc(coll)["t_collective_s"] /
                            max(rc(coll)["t_compute_s"], 1e-12)),
        "paper": ("rdfviews-query-step", "star3_1000000000", None),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--pick", action="store_true")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()
    cells = load_all(args.tag)
    if args.pick:
        print(json.dumps(picks(cells), indent=1))
        return
    print("## Dry-run (single-pod 16x16 + multi-pod 2x16x16)\n")
    print(dryrun_table(cells))
    print("\n## Roofline (single-pod, trip-count-corrected)\n")
    print(roofline_table(cells, multi_pod=False))
    print("\n## Roofline (multi-pod)\n")
    print(roofline_table(cells, multi_pod=True))


if __name__ == "__main__":
    main()
