"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run launcher must set XLA_FLAGS before first jax init).

Single pod: (data=16, model=16) = 256 chips (TPU v5e pod).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the `pod` axis is
reserved for pure data parallelism (cheapest inter-pod traffic: one
gradient all-reduce per step traverses DCN/optical links).
"""
from __future__ import annotations

import jax

try:  # AxisType landed after jax 0.4; Auto is the implicit default before
    from jax.sharding import AxisType

    def _axis_kwargs(n: int) -> dict:
        return {"axis_types": (AxisType.Auto,) * n}
except ImportError:  # pragma: no cover - depends on installed jax
    def _axis_kwargs(n: int) -> dict:
        return {}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_kwargs(len(axes)))


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Generic helper with pjit-style Auto axis types."""
    return jax.make_mesh(shape, axes, **_axis_kwargs(len(axes)))


def make_host_mesh(ndev: int | None = None, axis: str = "data"):
    """A 1-D mesh over the locally visible devices (tests, examples)."""
    n = ndev or len(jax.devices())
    return make_mesh((n,), (axis,))


def data_axis_names(mesh) -> tuple[str, ...]:
    """Axes used for batch sharding: ('pod','data') when a pod axis exists."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
