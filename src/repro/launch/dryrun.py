import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape)
on the production meshes, capture memory/cost analysis + roofline terms.

    PYTHONPATH=src python -m repro.launch.dryrun                 # everything
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2.5-32b \
        --shape train_4k --multi-pod                              # one cell
    PYTHONPATH=src python -m repro.launch.dryrun --paper          # query_step

Results are cached incrementally in artifacts/dryrun/<cell>.json; use
--force to re-run.  The FIRST import above pins 512 host devices — this
module must be the process entry point (never import it from tests).
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch import roofline as RL
from repro.launch.mesh import make_production_mesh
from repro.launch.shapes import SHAPES, applicable, make_cell
from repro.configs import get_config, list_archs

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "artifacts", "dryrun")


def cell_path(arch: str, shape: str, multi_pod: bool, tag: str = "") -> str:
    pod = "pod2" if multi_pod else "pod1"
    suffix = f".{tag}" if tag else ""
    return os.path.join(ART_DIR, f"{arch}__{shape}__{pod}{suffix}.json")


def run_cell(arch: str, shape: str, multi_pod: bool, tag: str = "",
             rules: dict | None = None) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(list(mesh.shape.values())))
    ok, why = applicable(arch, shape)
    if not ok:
        return {"arch": arch, "shape": shape, "chips": chips,
                "multi_pod": multi_pod, "status": "skipped", "reason": why}
    t0 = time.monotonic()
    cell = make_cell(arch, shape, mesh, rules=rules)
    jitted = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                     donate_argnums=cell.donate)
    lowered = jitted.lower(*cell.args)
    t_lower = time.monotonic() - t0
    t0 = time.monotonic()
    compiled = lowered.compile()
    t_compile = time.monotonic() - t0

    mem = compiled.memory_analysis()
    cfg = get_config(arch)
    spec = SHAPES[shape]
    mf = RL.model_flops_for(cfg, spec["kind"], spec["batch"], spec["seq"])
    roof = RL.extract(compiled, None, chips, mf)

    result = {
        "arch": arch, "shape": shape, "chips": chips,
        "multi_pod": multi_pod, "status": "ok",
        "kind": spec["kind"], "seq": spec["seq"], "batch": spec["batch"],
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
            "generated_code_bytes": int(getattr(mem, "generated_code_size_in_bytes", 0)),
        },
        "roofline": roof.as_dict(),
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
    }
    return result


# ----------------------------------------------------------------------
# the paper's own workload: distributed query_step
# ----------------------------------------------------------------------
def run_paper_cell(multi_pod: bool, n_triples: int = 1_000_000_000,
                   copartition: bool = True) -> dict:
    """Lower the distributed evaluation of a 3-atom star-join rewriting
    over a `n_triples` TT sharded across the mesh's data axes."""
    from repro.core.queries import Atom, Const, Var
    from repro.query import distributed as D
    from repro.query.cost import RelInfo
    from repro.query.plan import EquiJoin, Project, TTScan
    from repro.rdf.triples import Statistics

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(list(mesh.shape.values())))
    # partition axes for the query engine; REPRO_QUERY_AXES=data,model
    # flattens the whole pod into the hash-partition space (§Perf C1)
    axes_env = os.environ.get("REPRO_QUERY_AXES", "data")
    axis = tuple(axes_env.split(",")) if "," in axes_env else axes_env
    names = axis if isinstance(axis, tuple) else (axis,)
    ndev = int(np.prod([mesh.shape[a] for a in names]))

    n_preds = 64
    per_pred = n_triples / n_preds
    stats = Statistics(
        n_triples=n_triples, n_ids=n_triples // 4,
        pred_count={p: int(per_pred) for p in range(n_preds)},
        pred_distinct_s={p: int(per_pred / 8) for p in range(n_preds)},
        pred_distinct_o={p: int(per_pred / 16) for p in range(n_preds)},
        distinct_s=n_triples // 8, distinct_o=n_triples // 16,
        distinct_p=n_preds, pred_obj_hist={},
    )
    x, y, z, w = Var("x"), Var("y"), Var("z"), Var("w")
    plan = Project(
        EquiJoin(
            EquiJoin(TTScan(Atom(x, Const(1), y)), TTScan(Atom(x, Const(2), z)),
                     (("x", "x"),)),
            TTScan(Atom(z, Const(3), w)),
            (("z", "z"),),
        ),
        ("x", "w"),
    )
    t0 = time.monotonic()
    fn = D.build_distributed_executor(plan, stats, {}, mesh, axis=axis,
                                      safety=2.0)
    # TT shards: per-device rows padded to pow2
    from repro.query.cost import capacity_for

    # multiple-of-1024 padding instead of pow2: pow2 wastes up to 2x on
    # the TT shards, and every column pass pays for the padding (§Perf C4)
    per_dev = int(-(-n_triples / ndev * 1.05 // 1024) * 1024)
    from repro.query import engine as QE

    tt = {k: jax.ShapeDtypeStruct((ndev * per_dev, 3), jnp.int32)
          for k in QE.INDEX_NAMES}
    from jax.sharding import NamedSharding, PartitionSpec as P

    tt_sh = {k: NamedSharding(mesh, P(axis)) for k in tt}
    jitted = jax.jit(fn, in_shardings=(tt_sh, {}))
    lowered = jitted.lower(tt, {})
    t_lower = time.monotonic() - t0
    t0 = time.monotonic()
    compiled = lowered.compile()
    t_compile = time.monotonic() - t0
    mem = compiled.memory_analysis()
    roof = RL.extract(compiled, None, chips, model_flops=0.0)
    return {
        "arch": "rdfviews-query-step", "shape": f"star3_{n_triples}",
        "chips": chips, "multi_pod": multi_pod, "status": "ok",
        "kind": "query", "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
            "generated_code_bytes": int(getattr(mem, "generated_code_size_in_bytes", 0)),
        },
        "roofline": roof.as_dict(),
    }


def run_audit(arch: str, shape: str, multi_pod: bool, tag: str = "") -> None:
    """Attach trip-count-corrected roofline terms to a cached artifact."""
    from repro.launch.flops_audit import corrected_costs

    path = cell_path(arch, shape, multi_pod, tag)
    if not os.path.exists(path):
        print(f"no artifact for {arch} {shape}; run the dry-run first")
        return
    with open(path) as f:
        res = json.load(f)
    if res.get("status") != "ok":
        return
    if "roofline_corrected" in res:
        print(f"audited {arch} {shape} pod={'2' if multi_pod else '1'}")
        return
    mesh = make_production_mesh(multi_pod=multi_pod)
    c = corrected_costs(arch, shape, mesh)
    roof = RL.Roofline(flops=c["flops"], hbm_bytes=c["bytes"],
                       collective_bytes=c["coll"],
                       chips=res["chips"],
                       model_flops=res["roofline"]["model_flops"])
    res["roofline_corrected"] = roof.as_dict()
    res["audit_detail"] = {k: c[k] for k in ("stem", "per_group",
                                             "loop_correction")}
    with open(path, "w") as f:
        json.dump(res, f, indent=1)
    r = res["roofline_corrected"]
    print(f"AUDIT {arch} {shape} pod={'2' if multi_pod else '1'}: "
          f"bottleneck={r['bottleneck']} frac={r['roofline_fraction']:.3f} "
          f"useful={r['useful_flops_ratio']:.2f}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="one architecture id")
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--paper", action="store_true",
                    help="lower the paper's distributed query_step")
    ap.add_argument("--audit", action="store_true",
                    help="add trip-count-corrected roofline to artifacts")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--tag", default="", help="result filename suffix")
    args = ap.parse_args()

    os.makedirs(ART_DIR, exist_ok=True)
    archs = [args.arch] if args.arch else list_archs()
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    if args.audit:
        for mp in meshes:
            for arch in archs:
                for shape in shapes:
                    try:
                        run_audit(arch, shape, mp, args.tag)
                    except Exception as e:  # noqa: BLE001
                        print(f"AUDIT-FAIL {arch} {shape}: {e}")
                        traceback.print_exc()
        return

    if args.paper:
        for mp in meshes:
            path = cell_path("rdfviews-query-step", "star3", mp, args.tag)
            if os.path.exists(path) and not args.force:
                print(f"cached {path}")
                continue
            res = run_paper_cell(mp)
            with open(path, "w") as f:
                json.dump(res, f, indent=1)
            print(f"PAPER pod={'2' if mp else '1'} "
                  f"compile={res['compile_s']}s "
                  f"bottleneck={res['roofline']['bottleneck']}")
        return

    failures = []
    for mp in meshes:
        for arch in archs:
            for shape in shapes:
                path = cell_path(arch, shape, mp, args.tag)
                if os.path.exists(path) and not args.force:
                    print(f"cached {arch} {shape} pod={'2' if mp else '1'}")
                    continue
                label = f"{arch} {shape} pod={'2' if mp else '1'}"
                try:
                    res = run_cell(arch, shape, mp)
                except Exception as e:  # noqa: BLE001 - report and continue
                    failures.append(label)
                    print(f"FAIL  {label}: {e}")
                    traceback.print_exc()
                    continue
                with open(path, "w") as f:
                    json.dump(res, f, indent=1)
                if res["status"] == "skipped":
                    print(f"SKIP  {label}: {res['reason'][:60]}")
                else:
                    r = res["roofline"]
                    print(f"OK    {label}: compile={res['compile_s']}s "
                          f"bottleneck={r['bottleneck']} "
                          f"frac={r['roofline_fraction']:.3f}")
    if failures:
        print(f"\n{len(failures)} FAILURES: {failures}")
        raise SystemExit(1)
    print("\nall cells complete")


if __name__ == "__main__":
    main()
