"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch rwkv6-3b --smoke \
        --steps 20 --batch 4 --seq 64 --data rdf --ckpt /tmp/ck

Runs on the locally visible devices (1-D data mesh); on a real TPU pod
the same entry point runs under `jax.distributed` with the production
mesh from launch/mesh.py.  Fault tolerance: periodic checkpoints +
resume, straggler watermarks per step.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.data.pipeline import PipelineConfig, SyntheticPipeline
from repro.distributed.fault import StragglerMonitor, TrainSupervisor
from repro.launch.mesh import make_host_mesh
from repro.models.model import build_model
from repro.train.optimizer import OptConfig
from repro.train.train_step import (TrainConfig, init_train_state,
                                    make_train_step)


def build_pipeline(args, cfg):
    if args.data == "rdf":
        from repro.core.search import SearchConfig
        from repro.core.wizard import WizardConfig, tune
        from repro.data.pipeline import RDFTokenPipeline
        from repro.rdf.generator import generate, lubm_workload

        uni = generate(n_universities=args.universities, seed=0)
        rep = tune(uni.store, lubm_workload(uni.dictionary), uni.schema,
                   uni.type_id,
                   WizardConfig(search=SearchConfig(strategy="greedy",
                                                    max_states=200)))
        print("wizard:", rep.result.summary())
        return RDFTokenPipeline(
            rep.executor, PipelineConfig(seq_len=args.seq,
                                         batch_size=args.batch,
                                         vocab=cfg.vocab))
    return SyntheticPipeline(PipelineConfig(seq_len=args.seq,
                                            batch_size=args.batch,
                                            vocab=cfg.vocab))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="rwkv6-3b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--data", choices=["rdf", "synthetic"], default="synthetic")
    ap.add_argument("--universities", type=int, default=1)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--save-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if cfg.ssm is not None and args.seq % cfg.ssm.chunk != 0:
        args.seq = max(cfg.ssm.chunk, (args.seq // cfg.ssm.chunk) * cfg.ssm.chunk)
    model = build_model(cfg)
    tc = TrainConfig(opt=OptConfig(lr=args.lr, total_steps=args.steps,
                                   warmup_steps=max(args.steps // 20, 1)),
                     remat="none" if args.smoke else "full",
                     accum_steps=args.accum)
    step_fn = jax.jit(make_train_step(model, tc))
    pipe = iter(build_pipeline(args, cfg))

    start = 0
    if args.ckpt:
        sup = TrainSupervisor(args.ckpt, save_every=args.save_every)
        state, start = sup.resume_or_init(
            lambda: init_train_state(model, tc, jax.random.key(0)))
        if start:
            print(f"resumed from step {start}")
    else:
        sup = None
        state = init_train_state(model, tc, jax.random.key(0))

    mon = StragglerMonitor()
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(state["params"]))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M steps={args.steps}")

    for i in range(start + 1, args.steps + 1):
        batch = {k: jnp.asarray(v) for k, v in next(pipe).items()}
        t0 = time.perf_counter()
        state, metrics = step_fn(state, batch)
        loss = float(metrics["loss"])
        dt = time.perf_counter() - t0
        mon.record(jax.process_index(), dt)
        if i % 5 == 0 or i == args.steps:
            tps = args.batch * args.seq / dt
            print(f"step {i:5d} loss {loss:8.4f} "
                  f"lr {float(metrics['lr']):.2e} {dt*1e3:7.1f} ms "
                  f"({tps:,.0f} tok/s)")
        if sup is not None:
            sup.maybe_save(i, state)
    slow = mon.check()
    if slow:
        print(f"straggler hosts flagged: {sorted(slow)}")
    print("done")


if __name__ == "__main__":
    main()
