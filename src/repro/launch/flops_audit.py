"""Trip-count-corrected cost analysis.

XLA's `cost_analysis()` counts a while-loop body ONCE, so a scanned
N-group model under-reports flops/bytes/collective-bytes by ~N.  We
recover honest totals by a variant decomposition — lower the same cell
with 0 groups (stem) and 1 group (stem+body):

    corrected = stem + G * (body1 - stem)  [+ E * (enc1 - stem)]

which is exact for homogeneous scanned groups (cross-group fusion is
impossible across a loop boundary).  Two in-body sequential loops are
additionally corrected analytically, since even body1 counts them once:

  * RWKV6's WKV time scan (seq steps)     — ~7*nh*hd^2 flops/token/blk
  * Mamba2's inter-chunk state scan       — 3*nh*N*P flops/chunk/blk

The audit runs per (arch x shape x mesh) and is attached to the dry-run
artifact as `roofline_corrected`.
"""
from __future__ import annotations

import dataclasses
from dataclasses import replace

import jax
import numpy as np

from repro.configs import get_config
from repro.launch import roofline as RL
from repro.launch.shapes import SHAPES, env_cfg, make_cell, rules_for
from repro.models.ssm import mamba2_dims, rwkv6_dims


def _variant(cfg, n_groups: int, enc_layers: int | None = None):
    c = replace(cfg, n_layers=n_groups * len(cfg.block_pattern))
    if cfg.encoder is not None:
        e = enc_layers if enc_layers is not None else cfg.encoder.n_layers
        c = replace(c, encoder=replace(cfg.encoder, n_layers=e))
    return c


def _measure(arch: str, shape: str, mesh, rules, cfg) -> dict:
    cell = make_cell(arch, shape, mesh, rules=rules, cfg=cfg)
    jitted = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                     donate_argnums=cell.donate)
    compiled = jitted.lower(*cell.args).compile()
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    coll = RL.parse_collectives(compiled.as_text())
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
        "coll": float(coll.total_bytes),
        "coll_by_op": dict(coll.bytes_by_op),
    }


def _batch_shards(mesh, rules) -> int:
    names = rules.get("batch") or ()
    names = names if isinstance(names, tuple) else (names,)
    n = 1
    for a in names:
        if a in mesh.axis_names:
            n *= mesh.shape[a]
    return max(n, 1)


def _loop_corrections(cfg, kind: str, batch: int, seq: int, mesh, rules
                      ) -> tuple[float, float]:
    """Per-device (flops, bytes) to add for in-body sequential loops."""
    if kind == "decode":
        return 0.0, 0.0  # one step: no time/chunk loops execute
    b_loc = max(batch // _batch_shards(mesh, rules), 1)
    mult = 4.0 if kind == "train" else 1.0  # fwd+bwd+remat recompute
    flops = 0.0
    bytes_ = 0.0
    G = cfg.n_groups
    if "rwkv6" in cfg.block_pattern:
        nh, hd = rwkv6_dims(cfg)
        n_blk = cfg.block_pattern.count("rwkv6") * G
        steps = seq - 1  # body1 already counts one step
        per_step_f = 7.0 * nh * hd * hd * b_loc
        per_step_b = b_loc * (2 * nh * hd * hd * 4        # state rw (fp32)
                              + 4 * nh * hd * 4)          # r,k,v,w reads
        flops += n_blk * steps * per_step_f * mult
        bytes_ += n_blk * steps * per_step_b * mult
    if cfg.ssm is not None and any(
            b in cfg.block_pattern for b in ("mamba2", "mamba2_shared")):
        d_in, nh, _ = mamba2_dims(cfg)
        N, P = cfg.ssm.state_dim, cfg.ssm.head_dim
        n_blk = (cfg.block_pattern.count("mamba2")
                 + cfg.block_pattern.count("mamba2_shared")) * G
        nc = max(seq // cfg.ssm.chunk, 1) - 1
        per_trip_f = 3.0 * nh * N * P * b_loc
        per_trip_b = b_loc * 3 * nh * N * P * 4
        flops += n_blk * nc * per_trip_f * mult
        bytes_ += n_blk * nc * per_trip_b * mult
    if cfg.attn_impl == "chunked":
        # nested q/kv chunk scans: the (qi, kj) tile body is counted once;
        # add the remaining nq*nk - 1 tile trips analytically.  The tile
        # einsums are head-sharded over the 'heads' mesh axes, so the
        # per-device tile touches H_loc (not H) heads.
        n_attn = sum(1 for b in cfg.block_pattern if b in ("attn", "swa")) * G
        if "mamba2_shared" in cfg.block_pattern:
            n_attn += cfg.block_pattern.count("mamba2_shared") * G
        C = cfg.attn_chunk
        if n_attn and seq % C == 0:
            h_axes = rules.get("heads")
            h_axes = h_axes if isinstance(h_axes, tuple) else (h_axes,)
            n_h = 1
            for a in h_axes:
                if a is not None and a in mesh.axis_names:
                    n_h *= mesh.shape[a]
            H_loc = max(-(-cfg.n_heads // n_h), 1)
            kv_loc = max(-(-cfg.n_kv_heads // n_h), 1)
            hd = cfg.hd
            trips = (seq // C) ** 2 - 1
            per_tile_f = b_loc * H_loc * C * C * (4.0 * hd + 8.0)
            per_tile_b = b_loc * (
                H_loc * C * C * 4 * 3               # score tile passes (f32)
                + H_loc * C * hd * 4 * 2            # q tile + acc update
                + 2 * kv_loc * C * hd * 4)          # k,v tiles
            flops += n_attn * trips * per_tile_f * mult
            bytes_ += n_attn * trips * per_tile_b * mult
    return flops, bytes_


def corrected_costs(arch: str, shape: str, mesh, rules=None) -> dict:
    """Per-device corrected (flops, bytes, collective bytes) + detail."""
    cfg = env_cfg(get_config(arch))
    rules = rules or rules_for(arch, shape)
    spec = SHAPES[shape]
    G = cfg.n_groups
    E = cfg.encoder.n_layers if cfg.encoder is not None else 0

    stem = _measure(arch, shape, mesh, rules, _variant(cfg, 0, 0 if E else None))
    body = _measure(arch, shape, mesh, rules, _variant(cfg, 1, 0 if E else None))
    out = {}
    for k in ("flops", "bytes", "coll"):
        out[k] = stem[k] + G * (body[k] - stem[k])
    if E:
        enc = _measure(arch, shape, mesh, rules, _variant(cfg, 0, 1))
        for k in ("flops", "bytes", "coll"):
            out[k] += E * (enc[k] - stem[k])
    lf, lb = _loop_corrections(cfg, spec["kind"], spec["batch"], spec["seq"],
                               mesh, rules)
    out["flops"] += lf
    out["bytes"] += lb
    out["loop_correction"] = {"flops": lf, "bytes": lb}
    out["stem"] = {k: stem[k] for k in ("flops", "bytes", "coll")}
    out["per_group"] = {k: body[k] - stem[k] for k in ("flops", "bytes", "coll")}
    return out


def corrected_roofline(arch: str, shape: str, mesh, rules=None) -> RL.Roofline:
    cfg = get_config(arch)
    spec = SHAPES[shape]
    chips = int(np.prod(list(mesh.shape.values())))
    c = corrected_costs(arch, shape, mesh, rules)
    mf = RL.model_flops_for(cfg, spec["kind"], spec["batch"], spec["seq"])
    return RL.Roofline(flops=c["flops"], hbm_bytes=c["bytes"],
                       collective_bytes=c["coll"], chips=chips,
                       model_flops=mf)
