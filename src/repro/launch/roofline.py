"""Roofline terms from a compiled dry-run artifact.

  compute    = HLO_FLOPs / (chips * peak_FLOPs)
  memory     = HLO_bytes / (chips * HBM_bw)
  collective = collective_bytes / (chips * link_bw)

cost_analysis() provides FLOPs / bytes; collective bytes are parsed from
the optimized HLO text (operand sizes of all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

# TPU v5e per chip
PEAK_FLOPS = 197e12      # bf16
HBM_BW = 819e9           # B/s
LINK_BW = 50e9           # B/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}

_SHAPE_RE = re.compile(r"(pred|bf16|f16|f32|f64|s8|u8|s16|u16|s32|u32|s64|u64)\[([\d,]*)\]")
_COLL_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute")


def _bytes_of_shape(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


@dataclass
class CollectiveStats:
    bytes_by_op: dict[str, int] = field(default_factory=dict)
    count_by_op: dict[str, int] = field(default_factory=dict)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_op.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum OUTPUT shape bytes of every collective op line.

    HLO lines look like:
      %ag = bf16[256,4096,5120] all-gather(%x), ...
    The output shape is a good proxy for wire bytes (all-reduce moves
    ~2x in a ring; we report raw operand bytes and note the convention).
    """
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        stripped = line.strip()
        # match '<shape> <op-name>(' on def lines, including fusions' roots
        for op in _COLL_OPS:
            if f" {op}(" not in stripped and f"{op}-start(" not in stripped:
                continue
            m = _SHAPE_RE.search(stripped.split("=", 1)[0] if "=" in stripped else stripped)
            if m is None:
                # shape appears after '=' for most HLO dumps
                rhs = stripped.split("=", 1)[-1]
                m = _SHAPE_RE.search(rhs)
            if m is None:
                continue
            b = _bytes_of_shape(m.group(1), m.group(2))
            stats.bytes_by_op[op] = stats.bytes_by_op.get(op, 0) + b
            stats.count_by_op[op] = stats.count_by_op.get(op, 0) + 1
            break
    return stats


@dataclass
class Roofline:
    """All byte/flop inputs are PER-DEVICE (XLA analyzes the SPMD
    per-partition module); `global_flops = flops * chips` recovers the
    whole-program numbers, making the three terms below exactly the
    HLO_total / (chips * peak) forms of the assignment."""

    flops: float                 # per-device HLO flops
    hbm_bytes: float             # per-device bytes accessed
    collective_bytes: float      # per-device collective bytes
    chips: int
    model_flops: float = 0.0     # analytic 6*N*D (or 6*N_active*D), GLOBAL
    collectives: CollectiveStats | None = None

    @property
    def global_flops(self) -> float:
        return self.flops * self.chips

    @property
    def t_compute(self) -> float:
        return self.global_flops / (self.chips * PEAK_FLOPS)

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes * self.chips / (self.chips * HBM_BW)

    @property
    def t_collective(self) -> float:
        return self.collective_bytes * self.chips / (self.chips * LINK_BW)

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        return self.model_flops / self.global_flops if self.flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """useful work time / achievable step time (all terms overlap-free)."""
        denom = max(self.t_compute, self.t_memory, self.t_collective)
        ideal = self.model_flops / (self.chips * PEAK_FLOPS)
        return ideal / denom if denom else 0.0

    def as_dict(self) -> dict:
        return {
            "flops_per_device": self.flops,
            "flops_global": self.global_flops,
            "hbm_bytes_per_device": self.hbm_bytes,
            "collective_bytes_per_device": self.collective_bytes,
            "chips": self.chips,
            "model_flops": self.model_flops,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
            "collective_detail": (
                {"bytes": self.collectives.bytes_by_op,
                 "count": self.collectives.count_by_op}
                if self.collectives else {}),
        }


def model_flops_for(cfg, kind: str, batch: int, seq: int) -> float:
    """Analytic MODEL_FLOPS: 6*N_active*D for training, 2*N_active*D for
    inference (forward-only), per executed step."""
    n = cfg.active_param_count()
    if kind == "train":
        tokens = batch * seq
        return 6.0 * n * tokens
    if kind == "prefill":
        tokens = batch * seq
        return 2.0 * n * tokens
    # decode: one token per sequence per step
    return 2.0 * n * batch


def extract(compiled, lowered_text: str | None, chips: int,
            model_flops: float) -> Roofline:
    ca = compiled.cost_analysis()
    if isinstance(ca, list):  # older API returns [dict]
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    hbm = float(ca.get("bytes accessed", 0.0))
    text = compiled.as_text() if lowered_text is None else lowered_text
    coll = parse_collectives(text)
    return Roofline(flops=flops, hbm_bytes=hbm,
                    collective_bytes=float(coll.total_bytes), chips=chips,
                    model_flops=model_flops, collectives=coll)
