"""Dry-run cell definitions: (architecture x input shape) -> lowering spec.

Shapes (assigned):
  train_4k     seq=4096   global_batch=256   train_step
  prefill_32k  seq=32768  global_batch=32    prefill (forward)
  decode_32k   seq=32768  global_batch=128   serve decode (1 token, KV=32k)
  long_500k    seq=524288 global_batch=1     long-context decode
               (runs only for long_context archs: gemma3/rwkv6/zamba2)

`input_specs(arch, shape)` returns ShapeDtypeStruct stand-ins for every
input (weak-type-correct, shardable, zero allocation).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs import get_config, list_archs
from repro.distributed.sharding import (DECODE_RULES, DEFAULT_RULES,
                                        FSDP_RULES, LONG_RULES,
                                        param_shardings, spec_for)
from repro.models.model import Model, build_model
from repro.train.optimizer import OptConfig
from repro.train.train_step import TrainConfig, train_state_shapes

SHAPES = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1),
}

# >=20B-param configs need FSDP so optimizer state fits 16 GB/chip
_FSDP_ARCHS = {"llama4-maverick-400b-a17b", "qwen2.5-32b", "deepseek-67b",
               "granite-20b"}

# whisper's stub frontend length comes from cfg.encoder.max_len (1536 =
# 30s window padded so the cross-attention KV shards evenly on the mesh)


def applicable(arch: str, shape: str) -> tuple[bool, str]:
    cfg = get_config(arch)
    if shape == "long_500k" and not cfg.long_context:
        return False, ("pure full-attention architecture: 500k decode needs "
                       "sub-quadratic attention / windowed KV (see DESIGN.md)")
    return True, ""


def rules_for(arch: str, shape: str) -> dict:
    if SHAPES[shape]["kind"] == "decode":
        return LONG_RULES if SHAPES[shape]["batch"] == 1 else DECODE_RULES
    if SHAPES[shape]["kind"] == "train" and arch in _FSDP_ARCHS:
        return FSDP_RULES
    return DEFAULT_RULES


@dataclass
class Cell:
    arch: str
    shape: str
    kind: str
    fn: Any                  # function to lower
    args: tuple              # ShapeDtypeStruct pytrees
    in_shardings: tuple
    model: Model
    rules: dict
    donate: tuple = ()


def _batch_specs(cfg, batch: int, seq: int, with_labels: bool):
    b = {
        "tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
    }
    if with_labels:
        b["labels"] = jax.ShapeDtypeStruct((batch, seq), jnp.int32)
    if cfg.mrope:
        b["positions"] = jax.ShapeDtypeStruct((batch, seq, 3), jnp.int32)
    if cfg.encoder is not None:
        b["enc_frames"] = jax.ShapeDtypeStruct(
            (batch, cfg.encoder.max_len, cfg.encoder.d_input), jnp.bfloat16)
    return b


def _batch_shardings(mesh, batch_tree, rules):
    from jax.sharding import NamedSharding

    def for_leaf(x):
        axes = ("batch",) + (None,) * (len(x.shape) - 1)
        return NamedSharding(mesh, spec_for(axes, rules, mesh))

    return jax.tree.map(for_leaf, batch_tree)


def env_cfg(cfg):
    """Apply perf-iteration overrides from the environment:
    REPRO_ATTN=chunked|dense, REPRO_ATTN_CHUNK=<int>."""
    import dataclasses
    import os

    impl = os.environ.get("REPRO_ATTN")
    if impl:
        cfg = dataclasses.replace(cfg, attn_impl=impl)
    ck = os.environ.get("REPRO_ATTN_CHUNK")
    if ck:
        cfg = dataclasses.replace(cfg, attn_chunk=int(ck))
    return cfg


def make_cell(arch: str, shape: str, mesh, rules: dict | None = None,
              tc: TrainConfig | None = None, cfg=None) -> Cell:
    from jax.sharding import NamedSharding, PartitionSpec as P

    cfg = cfg if cfg is not None else get_config(arch)
    cfg = env_cfg(cfg)
    model = build_model(cfg)
    spec = SHAPES[shape]
    rules = rules or rules_for(arch, shape)
    kind = spec["kind"]
    seq, batch = spec["seq"], spec["batch"]

    if kind == "train":
        import os

        import jax.numpy as _jnp
        m_dt = {"bf16": _jnp.bfloat16, "f32": _jnp.float32}[
            os.environ.get("REPRO_OPT_M_DTYPE", "f32")]
        v_dt = {"bf16": _jnp.bfloat16, "f32": _jnp.float32}[
            os.environ.get("REPRO_OPT_V_DTYPE", "f32")]
        tc = tc or TrainConfig(opt=OptConfig(m_dtype=m_dt, v_dtype=v_dt),
                               remat=os.environ.get("REPRO_REMAT", "full"))
        from repro.train.train_step import (make_train_step,
                                            train_state_shardings)

        step = make_train_step(model, tc, mesh, rules)
        state = train_state_shapes(model, tc, dtype=jnp.bfloat16)
        batch_specs = _batch_specs(cfg, batch, seq, with_labels=True)
        state_sh = train_state_shardings(model, tc, mesh, rules)
        args = (state, batch_specs)
        in_sh = (state_sh, _batch_shardings(mesh, batch_specs, rules))
        return Cell(arch, shape, kind, step, args, in_sh, model, rules,
                    donate=(0,))

    params = model.param_shapes(jnp.bfloat16)
    p_sh = param_shardings(model.template, rules, mesh)

    if kind == "prefill":
        def prefill(params, batch):
            kw = {k: v for k, v in batch.items() if k != "tokens"}
            from repro.distributed.sharding import axis_ctx

            with axis_ctx(mesh, rules):
                return model.forward(params, tokens=batch["tokens"], **kw)

        batch_specs = _batch_specs(cfg, batch, seq, with_labels=False)
        args = (params, batch_specs)
        in_sh = (p_sh, _batch_shardings(mesh, batch_specs, rules))
        return Cell(arch, shape, kind, prefill, args, in_sh, model, rules)

    # decode: one token against a cache of length `seq`
    enc_len = cfg.encoder.max_len if cfg.encoder is not None else 0
    cache = model.cache_shapes(batch, seq, enc_len)
    cache_axes = model.cache_axes()
    cache_sh = jax.tree.map(
        lambda sds, axes: NamedSharding(mesh, spec_for(axes, rules, mesh)),
        cache, cache_axes,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
    )
    tok = jax.ShapeDtypeStruct((batch, 1), jnp.int32)
    pos = jax.ShapeDtypeStruct((), jnp.int32)

    def decode(params, token, pos, cache):
        from repro.distributed.sharding import axis_ctx

        with axis_ctx(mesh, rules):
            return model.decode_step(params, token, pos, cache)

    args = (params, tok, pos, cache)
    in_sh = (p_sh,
             NamedSharding(mesh, spec_for(("batch", None), rules, mesh)),
             NamedSharding(mesh, P()),
             cache_sh)
    return Cell(arch, shape, kind, decode, args, in_sh, model, rules,
                donate=(3,))


def all_cells() -> list[tuple[str, str]]:
    return [(a, s) for a in list_archs() for s in SHAPES]
