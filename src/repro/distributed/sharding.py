"""Logical-axis sharding: one rules table maps logical axes to mesh axes.

MaxText-style: params and activations carry logical axis names
('embed', 'heads', 'mlp', 'vocab', 'expert', 'batch', 'seq', ...); a
RULES dict maps them onto physical mesh axes.  Changing distribution
strategy = changing the table (this is the main §Perf knob).

`axis_ctx` threads (mesh, rules) to the model code so layers can request
activation constraints without importing distribution machinery.
"""
from __future__ import annotations

import contextlib
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.params import ParamSpec, is_spec


def shard_map_compat(f, mesh, in_specs, out_specs, check: bool = False):
    """`jax.shard_map` across jax versions: older releases only ship it
    as `jax.experimental.shard_map` with `check_rep` instead of
    `check_vma`."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check)
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check)

# default: TP on the feature axes, DP (pod x data) on batch, params replicated
DEFAULT_RULES: dict[str, Any] = {
    "batch": ("pod", "data"),
    "seq": None,
    "embed": None,
    "heads": "model",
    "kv": "model",
    "kv_heads": "model",
    "mlp": "model",
    "vocab": "model",
    "expert": "model",
    "layer": None,
    "seq_cache": None,
}

# FSDP: additionally shard the params' embed dim over ALL data-parallel
# axes (ZeRO-3 style; GSPMD inserts the all-gathers) — needed for >=20B
# configs.  'pod' is dropped automatically on the single-pod mesh.
FSDP_RULES = {**DEFAULT_RULES, "embed": ("pod", "data")}

# sequence parallelism for activations (long-context prefill)
SEQ_RULES = {**DEFAULT_RULES, "seq": "data"}

# decode: KV caches shard on their length (flash-decode style partial
# softmax; GSPMD inserts the reductions) because kv_heads (often 8) do
# not divide the model axis; recurrent-state features shard over model
DECODE_RULES = {**DEFAULT_RULES, "seq_cache": "model", "kv_heads": None,
                "state_feat": "model"}

# long-context decode (batch=1): parallelism comes from the cache length,
# not the batch — shard every KV cache over ALL mesh axes
LONG_RULES = {**DEFAULT_RULES, "batch": None, "kv_heads": None,
              "seq_cache": ("pod", "data", "model"), "state_feat": "model"}


def spec_for(axes: tuple[str | None, ...], rules: dict, mesh: Mesh) -> P:
    """PartitionSpec for logical axes; drops axes absent from the mesh and
    resolves conflicts (a mesh axis may appear only once) left-to-right."""
    used: set[str] = set()
    parts: list = []
    for ax in axes:
        m = rules.get(ax) if ax is not None else None
        if m is None:
            parts.append(None)
            continue
        names = m if isinstance(m, tuple) else (m,)
        names = tuple(n for n in names if n in mesh.axis_names and n not in used)
        if not names:
            parts.append(None)
        elif len(names) == 1:
            parts.append(names[0])
            used.add(names[0])
        else:
            parts.append(names)
            used.update(names)
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def param_shardings(template, rules: dict, mesh: Mesh):
    """NamedSharding pytree parallel to a ParamSpec template."""
    return jax.tree.map(
        lambda s: NamedSharding(mesh, spec_for(s.axes, rules, mesh)),
        template, is_leaf=is_spec,
    )


# ----------------------------------------------------------------------
# activation-constraint context
# ----------------------------------------------------------------------
_ACTIVE: list[tuple[Mesh, dict]] = []


@contextlib.contextmanager
def axis_ctx(mesh: Mesh, rules: dict):
    _ACTIVE.append((mesh, rules))
    try:
        yield
    finally:
        _ACTIVE.pop()


def shard_act(x: jax.Array, axes: tuple[str | None, ...]) -> jax.Array:
    """Constrain an activation to the active rules (no-op outside ctx)."""
    if not _ACTIVE:
        return x
    mesh, rules = _ACTIVE[-1]
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, spec_for(axes, rules, mesh))
    )


def active_ctx() -> tuple[Mesh, dict] | None:
    """The (mesh, rules) pair threaded by axis_ctx, if any."""
    return _ACTIVE[-1] if _ACTIVE else None


def mesh_axes_of(logical: str) -> tuple[str, ...]:
    """Physical mesh axes a logical axis maps to under the active rules."""
    ctx = active_ctx()
    if ctx is None:
        return ()
    mesh, rules = ctx
    m = rules.get(logical)
    if m is None:
        return ()
    names = m if isinstance(m, tuple) else (m,)
    return tuple(n for n in names if n in mesh.axis_names)
