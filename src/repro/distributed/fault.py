"""Fault tolerance + straggler mitigation hooks.

On a real multi-host cluster this wraps jax.distributed; the logic here
is host-count agnostic and fully exercised in tests:

  * TrainSupervisor — checkpoint cadence, preemption-safe resume
    (restart continues bit-exactly from the last committed step),
  * StragglerMonitor — per-step timing watermarks; hosts slower than
    `threshold x median` over a window are flagged for replacement
    (the action hook is pluggable: on TPU pods this triggers a
    re-slice / hot-spare swap).
"""
from __future__ import annotations

import statistics
import time
from dataclasses import dataclass, field
from typing import Callable

from repro.checkpoint import checkpoint as C


@dataclass
class StragglerMonitor:
    window: int = 20
    threshold: float = 2.0
    _times: dict[int, list[float]] = field(default_factory=dict)
    flagged: set[int] = field(default_factory=set)

    def record(self, host: int, step_seconds: float) -> None:
        self._times.setdefault(host, []).append(step_seconds)
        self._times[host] = self._times[host][-self.window:]

    def check(self) -> set[int]:
        medians = {
            h: statistics.median(ts) for h, ts in self._times.items() if ts
        }
        if len(medians) < 2:
            return set()
        global_median = statistics.median(medians.values())
        self.flagged = {
            h for h, m in medians.items() if m > self.threshold * global_median
        }
        return self.flagged


@dataclass
class TrainSupervisor:
    ckpt_dir: str
    save_every: int = 50
    keep: int = 3

    def resume_or_init(self, init_fn: Callable[[], dict], target_shapes=None,
                       shardings=None) -> tuple[dict, int]:
        """Returns (state, start_step).  After a preemption, training
        resumes from the last committed checkpoint."""
        last = C.latest_step(self.ckpt_dir)
        if last is None:
            return init_fn(), 0
        target = target_shapes if target_shapes is not None else init_fn()
        state = C.restore(self.ckpt_dir, last, target, shardings)
        return state, last

    def maybe_save(self, step: int, state) -> str | None:
        if step % self.save_every == 0 and step > 0:
            return C.save(self.ckpt_dir, step, state, keep=self.keep)
        return None
