"""Fault tolerance: training supervision AND the serving supervisor.

On a real multi-host cluster this wraps jax.distributed; the logic here
is host-count agnostic and fully exercised in tests:

  * TrainSupervisor — checkpoint cadence, preemption-safe resume
    (restart continues bit-exactly from the last committed step),
  * StragglerMonitor — per-step timing watermarks; hosts slower than
    `threshold x median` over a window are flagged for replacement
    (the action hook is pluggable: on TPU pods this triggers a
    re-slice / hot-spare swap),
  * CircuitBreaker / ServingSupervisor — the generic half of the
    serving-side fault tolerance used by `repro.serve.query_server`:
    a deterministic (batch-counted, no wall clock) breaker over the
    fused device path and an explicit health state machine
    (HEALTHY / DEGRADED / STALE_ONLY / DOWN) with a transition log.
    Deliberately free of any serving imports so the training and
    serving layers share one fault vocabulary.

Health states:

  HEALTHY     the fused device path serves, answers fresh
  DEGRADED    a fallback tier serves (per-query / host reference
              engine), or answers exceed the staleness budget — every
              answer is still exact for the snapshot it was computed on
  STALE_ONLY  only last-known-good cached answers are servable
  DOWN        nothing servable; requests fail loudly
"""
from __future__ import annotations

import statistics
import time
from dataclasses import dataclass, field
from typing import Callable

from repro.checkpoint import checkpoint as C

HEALTHY = "HEALTHY"
DEGRADED = "DEGRADED"
STALE_ONLY = "STALE_ONLY"
DOWN = "DOWN"

# severity order for rollups over shard health maps
_SEVERITY = {HEALTHY: 0, DEGRADED: 1, STALE_ONLY: 2, DOWN: 3}


def _tier_health(tier: int | None, stale: bool, degraded: bool = False) -> str:
    """Map one served ladder tier onto a health state (shared by the
    whole-server `observe` and the per-shard `observe_shard`)."""
    if tier is None:
        return DOWN
    if tier >= 3:
        return STALE_ONLY
    if tier > 0 or stale or degraded:
        return DEGRADED
    return HEALTHY


@dataclass(frozen=True)
class RetryPolicy:
    """Retry/backoff policy for the serving ladder.

    All quantities are deterministic batch counts, never wall-clock
    sleeps: a serving batch is the supervisor's clock tick, so tests
    and the chaos harness replay identically.
    """

    max_attempts: int = 2        # in-batch retries of the fused path
    failure_threshold: int = 1   # consecutive failed batches to open
    cooldown_batches: int = 1    # open-state batches before a probe
    backoff_factor: float = 2.0  # cooldown growth per re-open
    max_cooldown: int = 8        # backoff ceiling (batches)
    call_timeout_seconds: float | None = None  # fused-call soft budget

    def __post_init__(self):
        if self.max_attempts < 1:
            raise ValueError("max_attempts must be >= 1")
        if self.failure_threshold < 1:
            raise ValueError("failure_threshold must be >= 1")
        if self.cooldown_batches < 1:
            raise ValueError("cooldown_batches must be >= 1")


class CircuitBreaker:
    """closed -> open -> half_open breaker, clocked in batches.

    `allow()` is called once per batch before the protected path runs;
    while open it burns one cooldown tick and refuses.  The half-open
    state admits exactly one probe: success closes the breaker and
    resets the cooldown, failure re-opens it with the cooldown grown by
    `backoff_factor` (capped), so a persistent fault is probed ever
    more rarely instead of hammered.
    """

    def __init__(self, policy: RetryPolicy | None = None):
        self.policy = policy or RetryPolicy()
        self.state = "closed"
        self.failures = 0            # consecutive failures while closed
        self.opens = 0               # lifetime open transitions
        self._cooldown = self.policy.cooldown_batches
        self._wait = 0

    def allow(self) -> bool:
        if self.state == "closed":
            return True
        if self.state == "open":
            self._wait -= 1
            if self._wait > 0:
                return False
            self.state = "half_open"
        return True  # half_open: one probe

    def record_success(self) -> None:
        self.state = "closed"
        self.failures = 0
        self._cooldown = self.policy.cooldown_batches

    def record_failure(self) -> None:
        if self.state == "half_open":
            # failed probe: back off harder
            self._cooldown = min(
                max(int(self._cooldown * self.policy.backoff_factor),
                    self._cooldown + 1),
                self.policy.max_cooldown)
            self._open()
            return
        self.failures += 1
        if self.failures >= self.policy.failure_threshold:
            self._open()

    def _open(self) -> None:
        self.state = "open"
        self.failures = 0
        self._wait = self._cooldown
        self.opens += 1


@dataclass(frozen=True)
class HealthTransition:
    batch: int
    previous: str
    health: str
    reason: str


class ServingSupervisor:
    """Health state machine for a degradation-ladder server.

    The server reports which tier answered each batch (0 fused,
    1 per-query, 2 reference engine, 3 last-known-good cache) and
    whether the batch was stale; the supervisor owns the breaker over
    the fused path and the HEALTHY/DEGRADED/STALE_ONLY/DOWN state with
    a bounded transition log.
    """

    MAX_TRANSITIONS = 64

    def __init__(self, policy: RetryPolicy | None = None):
        self.policy = policy or RetryPolicy()
        self.fused = CircuitBreaker(self.policy)
        self.health = HEALTHY
        self.batches = 0
        self.transitions: list[HealthTransition] = []
        # shard-indexed health map (sharded serving backends): shard id
        # -> HEALTHY/DEGRADED/STALE_ONLY/DOWN, folded into the overall
        # health via `rollup()` so one bad shard degrades the server
        # instead of taking it DOWN.
        self.shard_health: dict[int, str] = {}

    def begin_batch(self) -> int:
        self.batches += 1
        return self.batches

    def observe(self, tier: int | None, stale: bool,
                reason: str = "", degraded: bool = False) -> str:
        """Fold one served batch into the health state.  `tier=None`
        means the batch could not be served at all; `degraded=True`
        forces at least DEGRADED even for a tier-0 batch (e.g. one that
        only served after an integrity repair)."""
        to = _tier_health(tier, stale, degraded)
        self._set(to, reason or f"served by tier {tier}"
                  + (" (stale)" if stale else ""))
        return self.health

    # ------------------------------------------------------------------
    # per-shard health (sharded serving)
    # ------------------------------------------------------------------
    def observe_shard(self, shard: int, tier: int | None,
                      stale: bool = False) -> str:
        """Record which ladder tier served shard `shard`'s partition
        this batch — the same tier vocabulary as `observe` (0 device
        program, 1-2 exact fallback, 3 stale cache, None unservable) —
        without touching the overall health; call `rollup()` once per
        batch to fold the map in."""
        h = _tier_health(tier, stale)
        self.shard_health[shard] = h
        return h

    def worst(self) -> str:
        """Worst health across the shard map (HEALTHY when untracked)."""
        if not self.shard_health:
            return HEALTHY
        return max(self.shard_health.values(), key=_SEVERITY.__getitem__)

    def quorum(self, minimum: int | None = None) -> bool:
        """True while at least `minimum` shards (default: a strict
        majority) can serve EXACT answers for their partition (HEALTHY
        or DEGRADED — a degraded shard serves via host fallback but its
        answers are still exact)."""
        if not self.shard_health:
            return True
        need = (len(self.shard_health) // 2 + 1
                if minimum is None else minimum)
        exact = sum(1 for h in self.shard_health.values()
                    if _SEVERITY[h] <= _SEVERITY[DEGRADED])
        return exact >= need

    def rollup(self, stale: bool = False, reason: str = "") -> str:
        """Fold the shard health map into the overall state: all shards
        HEALTHY -> HEALTHY; any shard below HEALTHY while a quorum still
        serves exact answers -> DEGRADED (the server keeps answering
        from the remaining shards plus host fallback for the missing
        partitions — one bad shard must not read as whole-server DOWN);
        quorum lost but some shard still servable -> STALE_ONLY; every
        shard unservable -> DOWN."""
        w = self.worst()
        if w == HEALTHY and not stale:
            to = HEALTHY
        elif self.quorum():
            to = DEGRADED
        elif any(_SEVERITY[h] < _SEVERITY[DOWN]
                 for h in self.shard_health.values()):
            to = STALE_ONLY
        else:
            to = DOWN
        self._set(to, reason or f"shard rollup (worst={w})")
        return self.health

    def _set(self, to: str, reason: str) -> None:
        if to == self.health:
            return
        self.transitions.append(HealthTransition(
            self.batches, self.health, to, reason))
        del self.transitions[:-self.MAX_TRANSITIONS]
        self.health = to

    def ready(self) -> bool:
        """Readiness: the server can answer something (possibly stale)."""
        return self.health != DOWN


@dataclass
class StragglerMonitor:
    window: int = 20
    threshold: float = 2.0
    _times: dict[int, list[float]] = field(default_factory=dict)
    flagged: set[int] = field(default_factory=set)

    def record(self, host: int, step_seconds: float) -> None:
        self._times.setdefault(host, []).append(step_seconds)
        self._times[host] = self._times[host][-self.window:]

    def check(self) -> set[int]:
        medians = {
            h: statistics.median(ts) for h, ts in self._times.items() if ts
        }
        if len(medians) < 2:
            return set()
        global_median = statistics.median(medians.values())
        self.flagged = {
            h for h, m in medians.items() if m > self.threshold * global_median
        }
        return self.flagged


@dataclass
class TrainSupervisor:
    ckpt_dir: str
    save_every: int = 50
    keep: int = 3

    def resume_or_init(self, init_fn: Callable[[], dict], target_shapes=None,
                       shardings=None) -> tuple[dict, int]:
        """Returns (state, start_step).  After a preemption, training
        resumes from the last committed checkpoint."""
        last = C.latest_step(self.ckpt_dir)
        if last is None:
            return init_fn(), 0
        target = target_shapes if target_shapes is not None else init_fn()
        state = C.restore(self.ckpt_dir, last, target, shardings)
        return state, last

    def maybe_save(self, step: int, state) -> str | None:
        if step % self.save_every == 0 and step > 0:
            return C.save(self.ckpt_dir, step, state, keep=self.keep)
        return None
