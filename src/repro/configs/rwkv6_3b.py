"""rwkv6-3b (Finch) [ssm]: 32L d=2560 attention-free d_ff=8960 vocab=65536,
data-dependent per-channel decay.  [arXiv:2404.05892; hf]
"""
from repro.models.config import ModelConfig, SSMConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-3b",
        n_layers=32, d_model=2560, n_heads=40, n_kv_heads=40,
        d_ff=8960, vocab=65536,
        block_pattern=("rwkv6",),
        ssm=SSMConfig(head_dim=64),
        long_context=True,  # O(1) recurrent state
        notes="RWKV6 Finch: time-mix WKV recurrence + relu^2 channel-mix",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-smoke",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab=128,
        block_pattern=("rwkv6",),
        ssm=SSMConfig(head_dim=16),
        long_context=True,
    )
