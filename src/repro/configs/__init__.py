"""Architecture registry: one module per assigned architecture.

`get_config(name)` -> full published ModelConfig;
`get_smoke_config(name)` -> reduced same-family config for CPU smoke tests.
"""
from __future__ import annotations

import importlib

ARCHS = [
    "granite_moe_1b",
    "llama4_maverick",
    "qwen2_5_32b",
    "deepseek_67b",
    "gemma3_12b",
    "granite_20b",
    "rwkv6_3b",
    "qwen2_vl_2b",
    "whisper_base",
    "zamba2_1_2b",
]

# canonical ids from the assignment -> module names
ALIASES = {
    "granite-moe-1b-a400m": "granite_moe_1b",
    "llama4-maverick-400b-a17b": "llama4_maverick",
    "qwen2.5-32b": "qwen2_5_32b",
    "deepseek-67b": "deepseek_67b",
    "gemma3-12b": "gemma3_12b",
    "granite-20b": "granite_20b",
    "rwkv6-3b": "rwkv6_3b",
    "qwen2-vl-2b": "qwen2_vl_2b",
    "whisper-base": "whisper_base",
    "zamba2-1.2b": "zamba2_1_2b",
}


def _module(name: str):
    mod_name = ALIASES.get(name, name.replace("-", "_").replace(".", "_"))
    return importlib.import_module(f"repro.configs.{mod_name}")


def get_config(name: str):
    return _module(name).config()


def get_smoke_config(name: str):
    return _module(name).smoke_config()


def list_archs() -> list[str]:
    return list(ALIASES)
