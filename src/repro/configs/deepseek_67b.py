"""deepseek-67b [dense]: 95L d=8192 64H (GQA kv=8) d_ff=22016 vocab=102400,
llama-architecture.  [arXiv:2401.02954; hf]
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-67b",
        n_layers=95, d_model=8192, n_heads=64, n_kv_heads=8,
        d_ff=22016, vocab=102400, head_dim=128,
        notes="llama-arch dense; 95 layers stress scan compile",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-smoke",
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab=128, head_dim=16,
    )
