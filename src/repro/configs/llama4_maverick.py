"""llama4-maverick-400b-a17b [moe]: 48L d=5120 40H (GQA kv=8) d_ff=8192
vocab=202048, MoE 128 experts top-1 + one shared expert, early fusion.
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]
"""
from repro.models.config import ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama4-maverick-400b-a17b",
        n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
        d_ff=8192, vocab=202048, head_dim=128,
        moe=MoEConfig(n_experts=128, top_k=1, n_shared_experts=1),
        rope_theta=500_000.0,
        notes=("top-1 routed + always-on shared expert (llama4); early "
               "fusion = text+image tokens share the backbone (vision "
               "frontend stubbed per assignment)"),
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="llama4-maverick-smoke",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=64, vocab=128, head_dim=16,
        moe=MoEConfig(n_experts=4, top_k=1, n_shared_experts=1, capacity_factor=4.0),
        rope_theta=500_000.0,
    )
