"""qwen2-vl-2b [vlm]: 28L d=1536 12H (GQA kv=2) d_ff=8960 vocab=151936,
M-RoPE + dynamic resolution (vision tower stubbed: input_specs provide
precomputed patch embeddings).  [arXiv:2409.12191; hf]
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-2b",
        n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2,
        d_ff=8960, vocab=151936, head_dim=128,
        qkv_bias=True, mrope=True, mrope_sections=(16, 24, 24),
        rope_theta=1_000_000.0,
        notes="M-RoPE (t/h/w) backbone; patch-embedding frontend is a stub",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-smoke",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab=128, head_dim=16,
        qkv_bias=True, mrope=True, mrope_sections=(2, 3, 3),
    )
