"""granite-20b [dense]: 52L d=6144 48H (MQA kv=1) d_ff=24576 vocab=49152,
llama-arch, code model.  [arXiv:2405.04324; hf]
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-20b",
        n_layers=52, d_model=6144, n_heads=48, n_kv_heads=1,
        d_ff=24576, vocab=49152, head_dim=128,
        notes="multi-query attention (single KV head)",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="granite-20b-smoke",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=1,
        d_ff=128, vocab=128, head_dim=16,
    )
