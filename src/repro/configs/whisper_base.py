"""whisper-base [audio]: 6L enc + 6L dec, d=512 8H d_ff=2048 vocab=51865,
encoder-decoder with conv frontend STUB (input_specs provide precomputed
frame embeddings).  [arXiv:2212.04356; unverified]

Adaptation note: whisper uses LayerNorm + learned positions; this
framework uses RMSNorm + RoPE for the decoder self-attention and learned
positions in the encoder — recorded in DESIGN.md.
"""
from repro.models.config import EncoderConfig, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-base",
        n_layers=6, d_model=512, n_heads=8, n_kv_heads=8,
        d_ff=2048, vocab=51865,
        encoder=EncoderConfig(n_layers=6, d_input=80, max_len=1536),
        notes="enc-dec; conv frontend stubbed to frame embeddings",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="whisper-smoke",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab=128,
        encoder=EncoderConfig(n_layers=2, d_input=16, max_len=64),
    )
