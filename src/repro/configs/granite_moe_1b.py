"""granite-moe-1b-a400m [moe]: 24L d=1024 16H (GQA kv=8) d_ff=512
vocab=49155, MoE 32 experts top-8.
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]
"""
from repro.models.config import ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-1b-a400m",
        n_layers=24, d_model=1024, n_heads=16, n_kv_heads=8,
        d_ff=512, vocab=49155,
        moe=MoEConfig(n_experts=32, top_k=8),
        tie_embeddings=True,
        notes="granite 3.0 MoE; per-expert d_ff=512, top-8 routing",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-1b-a400m-smoke",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=32, vocab=128,
        moe=MoEConfig(n_experts=4, top_k=2, capacity_factor=4.0),
        tie_embeddings=True,
    )
