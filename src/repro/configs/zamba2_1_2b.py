"""zamba2-1.2b [hybrid]: 38L d=2048 32H (kv=32) d_ff=8192 ssm_state=64,
Mamba2 blocks + ONE shared attention block applied every second block.
[arXiv:2411.15242; hf]
"""
from repro.models.config import ModelConfig, SSMConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-1.2b",
        n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32,
        d_ff=8192, vocab=32000, head_dim=64,
        block_pattern=("mamba2", "mamba2_shared"),
        ssm=SSMConfig(state_dim=64, head_dim=64, expand=2),
        long_context=True,  # constant SSM state; shared attn is 1-in-2
        notes=("19 groups of (mamba2, mamba2+shared-attn); the attention "
               "block weights are shared across all 19 applications"),
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-smoke",
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab=128, head_dim=16,
        block_pattern=("mamba2", "mamba2_shared"),
        ssm=SSMConfig(state_dim=8, head_dim=16, expand=2, chunk=8),
        long_context=True,
    )
