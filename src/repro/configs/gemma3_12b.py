"""gemma3-12b [dense]: 48L d=3840 16H (GQA kv=8) d_ff=15360 vocab=262144,
5:1 local:global attention, 128k context, head_dim=256.
[hf:google/gemma-3-1b-pt; unverified]
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-12b",
        n_layers=48, d_model=3840, n_heads=16, n_kv_heads=8,
        d_ff=15360, vocab=262144, head_dim=256,
        block_pattern=("swa", "swa", "swa", "swa", "swa", "attn"),
        window=1024,
        rope_theta=1_000_000.0, rope_theta_local=10_000.0,
        tie_embeddings=True,
        long_context=True,  # windowed KV for 5/6 layers => 500k decode runs
        notes="5 sliding-window layers per global layer; window=1024",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-smoke",
        n_layers=6, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab=128, head_dim=16,
        block_pattern=("swa", "swa", "swa", "swa", "swa", "attn"),
        window=8, tie_embeddings=True, long_context=True,
    )
