"""Incremental view maintenance (single-triple inserts).

delta(V, t) = ∪_i  eval( V with atom_i unified against t )  over TT ∪ {t}

The quality function only needs the *cost estimate*
(core/quality.view_maintenance_cost); this module implements the actual
maintenance so the estimate is validated against reality in tests.
"""
from __future__ import annotations

import numpy as np

from repro.core.queries import CQ, Atom, Const, Term, Var
from repro.query import ref_engine as R
from repro.rdf.triples import TripleStore


def _unify(atom: Atom, triple: tuple[int, int, int]) -> dict[Var, Const] | None:
    mapping: dict[Var, Const] = {}
    for t, val in zip(atom.terms(), triple):
        if isinstance(t, Const):
            if t.id != val:
                return None
        else:
            if t in mapping and mapping[t].id != val:
                return None
            mapping[t] = Const(int(val))
    return mapping


def delta_rows(view_cq: CQ, new_store: TripleStore,
               triple: tuple[int, int, int]) -> np.ndarray:
    """Rows added to the view extent by inserting `triple` (the store
    passed in must already contain it)."""
    out: set[tuple[int, ...]] = set()
    for i, atom in enumerate(view_cq.atoms):
        mapping = _unify(atom, triple)
        if mapping is None:
            continue
        rest = [a.substitute(mapping) for j, a in enumerate(view_cq.atoms) if j != i]
        if not rest:
            row = tuple(mapping[h].id for h in view_cq.head)
            out.add(row)
            continue
        sub_head = tuple(
            h for h in view_cq.head if h not in mapping
        )
        sub_cq = CQ(sub_head, tuple(rest), name="_delta")
        rel = R.evaluate_cq(sub_cq, new_store)
        col = {c: k for k, c in enumerate(rel.cols)}
        for r in rel.rows.tolist():
            row = tuple(
                mapping[h].id if h in mapping else r[col[h.name]]
                for h in view_cq.head
            )
            out.add(row)
    if not out:
        return np.zeros((0, len(view_cq.head)), np.int32)
    return np.array(sorted(out), dtype=np.int32)


def maintain(view_cq: CQ, old_extent: np.ndarray, store: TripleStore,
             triple: tuple[int, int, int]) -> tuple[np.ndarray, TripleStore, int]:
    """Insert `triple` into the store and maintain the extent.

    Returns (new_extent, new_store, delta_size)."""
    new_store = store.insert(np.array([triple], np.int32))
    if len(new_store) == len(store):  # duplicate insert: no-op
        return old_extent, new_store, 0
    delta = delta_rows(view_cq, new_store, triple)
    if len(delta) == 0:
        return old_extent, new_store, 0
    merged = np.unique(
        np.concatenate([old_extent.reshape(-1, len(view_cq.head)), delta]), axis=0
    )
    return merged, new_store, int(len(merged) - len(old_extent))
