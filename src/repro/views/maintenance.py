"""Incremental view maintenance reference oracle.

Single-triple inserts (the original oracle, kept verbatim for the
transition suite):

    delta(V, t) = ∪_i  eval( V with atom_i unified against t )  over TT ∪ {t}

Batched deltas (`apply_delta`) extend it to insert+delete streams and
serve as the correctness oracle for the device subsystem in
`repro.maintenance`:

  * effective deletes  Δ⁻ₑ = (TT ∩ Δ⁻) \\ Δ⁺   (insert wins on a tie)
  * effective inserts  Δ⁺ₑ = Δ⁺ \\ TT
  * TT' = (TT \\ Δ⁻) ∪ Δ⁺
  * deletions: views here are full projections (head == all body vars),
    so every extent row IS a total variable assignment and has exactly
    one derivation — a row dies iff any of its instantiated atom
    triples is in Δ⁻ₑ.  No re-derivation or counting needed.
  * insertions: per-atom unification against the batch, rest evaluated
    over TT' (covers multi-delta derivations: every atom of a new
    derivation is either in TT' already or arrives in the same batch).

The quality function only needs the *cost estimate*
(core/quality.view_maintenance_cost); this module implements the actual
maintenance so the estimate is validated against reality in tests.
"""
from __future__ import annotations

import numpy as np

from repro.core.queries import CQ, Atom, Const, Term, Var
from repro.query import ref_engine as R
from repro.rdf.triples import TripleStore, triples_in


def _unify(atom: Atom, triple: tuple[int, int, int]) -> dict[Var, Const] | None:
    mapping: dict[Var, Const] = {}
    for t, val in zip(atom.terms(), triple):
        if isinstance(t, Const):
            if t.id != val:
                return None
        else:
            if t in mapping and mapping[t].id != val:
                return None
            mapping[t] = Const(int(val))
    return mapping


def delta_rows(view_cq: CQ, new_store: TripleStore,
               triple: tuple[int, int, int]) -> np.ndarray:
    """Rows added to the view extent by inserting `triple` (the store
    passed in must already contain it)."""
    out: set[tuple[int, ...]] = set()
    for i, atom in enumerate(view_cq.atoms):
        mapping = _unify(atom, triple)
        if mapping is None:
            continue
        rest = [a.substitute(mapping) for j, a in enumerate(view_cq.atoms) if j != i]
        if not rest:
            row = tuple(mapping[h].id for h in view_cq.head)
            out.add(row)
            continue
        sub_head = tuple(
            h for h in view_cq.head if h not in mapping
        )
        sub_cq = CQ(sub_head, tuple(rest), name="_delta")
        rel = R.evaluate_cq(sub_cq, new_store)
        col = {c: k for k, c in enumerate(rel.cols)}
        for r in rel.rows.tolist():
            row = tuple(
                mapping[h].id if h in mapping else r[col[h.name]]
                for h in view_cq.head
            )
            out.add(row)
    if not out:
        return np.zeros((0, len(view_cq.head)), np.int32)
    return np.array(sorted(out), dtype=np.int32)


def maintain(view_cq: CQ, old_extent: np.ndarray, store: TripleStore,
             triple: tuple[int, int, int]) -> tuple[np.ndarray, TripleStore, int]:
    """Insert `triple` into the store and maintain the extent.

    Returns (new_extent, new_store, delta_size)."""
    new_store = store.insert(np.array([triple], np.int32))
    if len(new_store) == len(store):  # duplicate insert: no-op
        return old_extent, new_store, 0
    delta = delta_rows(view_cq, new_store, triple)
    if len(delta) == 0:
        return old_extent, new_store, 0
    merged = np.unique(
        np.concatenate([old_extent.reshape(-1, len(view_cq.head)), delta]), axis=0
    )
    return merged, new_store, int(len(merged) - len(old_extent))


# ----------------------------------------------------------------------
# batched insert/delete deltas
# ----------------------------------------------------------------------
def is_full_projection(view_cq: CQ) -> bool:
    """Head covers every body variable (the shape the wizard's views
    always have) — the precondition for membership-based deletion."""
    return tuple(view_cq.head) == view_cq.all_vars()


def instantiate_atoms(view_cq: CQ, extent: np.ndarray) -> list[np.ndarray]:
    """Per atom, the (n, 3) concrete triples each extent row derives it
    from.  Only valid for full-projection views (total assignments)."""
    extent = np.asarray(extent, np.int32).reshape(-1, len(view_cq.head))
    col = {h.name: k for k, h in enumerate(view_cq.head)}
    out = []
    n = len(extent)
    for atom in view_cq.atoms:
        cols = []
        for t in atom.terms():
            if isinstance(t, Const):
                cols.append(np.full(n, t.id, np.int32))
            else:
                cols.append(extent[:, col[t.name]])
        out.append(np.stack(cols, axis=1) if n else np.zeros((0, 3), np.int32))
    return out


def retract_mask(view_cq: CQ, extent: np.ndarray,
                 eff_deletes: np.ndarray) -> np.ndarray:
    """Boolean mask of extent rows that survive the effective deletes."""
    extent = np.asarray(extent, np.int32).reshape(-1, len(view_cq.head))
    keep = np.ones(len(extent), dtype=bool)
    if len(extent) == 0 or len(eff_deletes) == 0:
        return keep
    for inst in instantiate_atoms(view_cq, extent):
        keep &= ~triples_in(inst, eff_deletes)
    return keep


def effective_delta(store: TripleStore, inserts: np.ndarray | None,
                    deletes: np.ndarray | None
                    ) -> tuple[np.ndarray, np.ndarray]:
    """(effective_inserts, effective_deletes) vs the current store:
    duplicates of existing triples and deletes of absent triples are
    dropped; an insert and delete of the same triple in one batch nets
    to the insert."""
    ins = (np.zeros((0, 3), np.int32) if inserts is None
           else np.unique(np.asarray(inserts, np.int32).reshape(-1, 3), axis=0))
    dels = (np.zeros((0, 3), np.int32) if deletes is None
            else np.unique(np.asarray(deletes, np.int32).reshape(-1, 3), axis=0))
    if len(dels):
        dels = dels[store.contains(dels)]
        if len(ins):
            dels = dels[~triples_in(dels, ins)]
    if len(ins):
        ins = ins[~store.contains(ins)]
    return ins, dels


def apply_delta(view_cq: CQ, old_extent: np.ndarray, store: TripleStore,
                inserts: np.ndarray | None = None,
                deletes: np.ndarray | None = None
                ) -> tuple[np.ndarray, TripleStore]:
    """Batched-delta oracle: maintain `old_extent` (rows in head order)
    through one insert/delete batch.  Returns (new_extent, new_store).

    Views that are not full projections fall back to re-evaluation for
    the delete side (no way to attribute derivations from the extent
    alone); the wizard never produces such views."""
    width = len(view_cq.head)
    old_extent = np.asarray(old_extent, np.int32).reshape(-1, width)
    eff_ins, eff_del = effective_delta(store, inserts, deletes)
    new_store = store.apply_delta(inserts, deletes)

    if len(eff_del):
        if is_full_projection(view_cq):
            extent = old_extent[retract_mask(view_cq, old_extent, eff_del)]
        else:
            extent = R.evaluate_cq(view_cq, new_store).rows.reshape(-1, width)
            extent = np.unique(np.asarray(extent, np.int32), axis=0)
            return extent, new_store
    else:
        extent = old_extent

    if len(eff_ins):
        parts = [extent]
        for t in eff_ins:
            parts.append(delta_rows(view_cq, new_store, tuple(int(v) for v in t)))
        extent = np.unique(np.concatenate(parts), axis=0) if len(parts) > 1 else extent
    elif len(eff_del):
        extent = np.unique(extent, axis=0) if len(extent) else extent
    return extent, new_store
