"""View Materializer: compute + store view extents.

Extents are evaluated with the oracle engine (host-side batch job) and
packaged as padded device relations for the JAX Query Executor, with
measured statistics (rows + per-column distincts) that replace the
estimates once available — mirroring the paper's ANALYZE-after-CREATE.
"""
from __future__ import annotations

import numpy as np

from repro.core.state import State
from repro.query import engine as E
from repro.query import ref_engine as R
from repro.query.cost import RelInfo, capacity_for
from repro.query.plan import plan_for_cq
from repro.rdf.triples import TripleStore


def materialize_view(cq, store: TripleStore) -> R.Relation:
    """Evaluate the view CQ over the TT (full projection, set semantics)."""
    return R.evaluate_cq(cq, store)


def measured_info(rel: R.Relation) -> RelInfo:
    rows = float(len(rel.rows))
    distinct = {
        c: (float(len(np.unique(rel.rows[:, i]))) if len(rel.rows) else 1.0)
        for i, c in enumerate(rel.cols)
    }
    return RelInfo(max(rows, 1e-3), distinct)


def materialize_state(state: State, store: TripleStore):
    """Materialize every view of a state.

    Returns (extents_np, device_views, infos):
      extents_np:  {vid: oracle Relation}
      device_views: {vid: PRel} padded device buffers
      infos:       {vid: RelInfo} measured statistics
    """
    extents: dict[int, R.Relation] = {}
    device: dict[int, E.PRel] = {}
    infos: dict[int, RelInfo] = {}
    for vid, view in state.views.items():
        ext = materialize_view(view.cq, store)
        extents[vid] = ext
        infos[vid] = measured_info(ext)
        cap = capacity_for(len(ext.rows), safety=1.0)
        device[vid] = E.make_prel(ext.rows, cap)
    return extents, device, infos
