"""View Materializer: compute + store view extents.

Two paths with identical extents:

  * `materialize_state` — oracle engine (host-side batch job), the
    original path;
  * `materialize_state_device` — the view CQs are planned as TT-scan
    trees, canonicalized into one shared-subplan DAG, and evaluated by
    the same fused workload compiler the Query Executor uses
    (`query/workload.py`): one device call materializes every extent,
    with scans/joins shared across views and capacity overflow
    recovered adaptively.

Either way extents are packaged as padded device relations with
measured statistics (rows + per-column distincts) that replace the
estimates once available — mirroring the paper's ANALYZE-after-CREATE.
"""
from __future__ import annotations

import numpy as np

from repro.core.state import State
from repro.errors import InvariantViolation
from repro.query import engine as E
from repro.query import ref_engine as R
from repro.query.cost import RelInfo, capacity_for
from repro.query.plan import plan_for_cq
from repro.rdf.triples import TripleStore


def materialize_view(cq, store: TripleStore) -> R.Relation:
    """Evaluate the view CQ over the TT (full projection, set semantics)."""
    return R.evaluate_cq(cq, store)


def measured_info(rel: R.Relation) -> RelInfo:
    rows = float(len(rel.rows))
    distinct = {
        c: (float(len(np.unique(rel.rows[:, i]))) if len(rel.rows) else 1.0)
        for i, c in enumerate(rel.cols)
    }
    return RelInfo(max(rows, 1e-3), distinct)


def materialize_state(state: State, store: TripleStore):
    """Materialize every view of a state.

    Returns (extents_np, device_views, infos):
      extents_np:  {vid: oracle Relation}
      device_views: {vid: PRel} padded device buffers
      infos:       {vid: RelInfo} measured statistics
    """
    extents: dict[int, R.Relation] = {}
    device: dict[int, E.PRel] = {}
    infos: dict[int, RelInfo] = {}
    for vid, view in state.views.items():
        ext = materialize_view(view.cq, store)
        extents[vid] = ext
        infos[vid] = measured_info(ext)
        cap = capacity_for(len(ext.rows), safety=1.0)
        device[vid] = E.make_prel(ext.rows, cap)
    return extents, device, infos


def materialize_state_delta(state: State, store: TripleStore,
                            prev_state: State,
                            prev_extents: dict[int, R.Relation],
                            prev_infos: dict[int, RelInfo] | None = None,
                            prev_device: dict[int, E.PRel] | None = None):
    """Delta path for an online view swap: materialize ONLY the views of
    `state` whose canonical key is new; views isomorphic to a previous
    view (same key, possibly different id / variable names / column
    order) reuse the old extent through a column permutation.  Under an
    identity permutation (the common case: the view simply survived the
    retune) the previous device buffer is carried over as-is — no host
    copy, no re-upload.

    Returns (extents, device, infos, reused, fresh, dropped):
      reused:  {new_vid: prev_vid} carried over without evaluation
      fresh:   [new_vid] actually materialized
      dropped: [prev_vid] dead extents the swap discards
    """
    from repro.core.queries import isomorphism

    # multiset match: one previous extent satisfies one new view
    by_key: dict = {}
    for pvid in sorted(prev_state.views):
        by_key.setdefault(prev_state.views[pvid].cq.canonical_key(),
                          []).append(pvid)

    extents: dict[int, R.Relation] = {}
    device: dict[int, E.PRel] = {}
    infos: dict[int, RelInfo] = {}
    reused: dict[int, int] = {}
    fresh: list[int] = []
    for vid, view in state.views.items():
        candidates = by_key.get(view.cq.canonical_key())
        pvid = candidates.pop(0) if candidates else None
        if pvid is not None:
            prev_view = prev_state.views[pvid]
            iso = isomorphism(prev_view.cq, view.cq)  # prev var -> new var
            if iso is None:
                raise InvariantViolation(
                    "equal canonical keys must be isomorphic")
            old_idx = {h.name: i for i, h in enumerate(prev_view.cq.head)}
            inv = {nv: pv for pv, nv in iso.items()}
            perm = [old_idx[inv[h].name] for h in view.cq.head]
            prev_rel = prev_extents[pvid]
            identity = perm == list(range(len(perm)))
            if identity and tuple(h.name for h in view.cq.head) == prev_rel.cols:
                ext = prev_rel
            else:
                rows = prev_rel.rows[:, perm] if len(prev_rel.rows) else \
                    prev_rel.rows.reshape(0, len(perm))
                ext = R.Relation(np.ascontiguousarray(rows),
                                 tuple(h.name for h in view.cq.head))
            reused[vid] = pvid
            if prev_infos is not None and pvid in prev_infos:
                pinfo = prev_infos[pvid]
                distinct = {h.name: pinfo.distinct[inv[h].name]
                            for h in view.cq.head}
                infos[vid] = RelInfo(pinfo.rows, distinct)
            else:
                infos[vid] = measured_info(ext)
            if identity and prev_device is not None and pvid in prev_device:
                device[vid] = prev_device[pvid]  # buffer survives as-is
            else:
                device[vid] = E.make_prel(
                    ext.rows, capacity_for(len(ext.rows), safety=1.0))
        else:
            ext = materialize_view(view.cq, store)
            fresh.append(vid)
            infos[vid] = measured_info(ext)
            device[vid] = E.make_prel(
                ext.rows, capacity_for(len(ext.rows), safety=1.0))
        extents[vid] = ext
    matched = set(reused.values())
    dropped = [pvid for pvid in sorted(prev_state.views) if pvid not in matched]
    return extents, device, infos, reused, fresh, dropped


def materialize_state_device(state: State, store: TripleStore,
                             safety: float = 4.0, use_pallas: bool = False,
                             max_retries: int = 12):
    """Device path: materialize every view extent in one fused device
    call through the shared-subplan workload compiler.

    Same return contract as `materialize_state`.  View CQs of one state
    frequently share triple patterns (fusion produces overlapping
    bodies); the DAG computes each shared scan/join once for all views.
    """
    from repro.query.dag import build_dag
    from repro.query.plan import has_cartesian
    from repro.query.workload import WorkloadExecutor

    plans: dict[str, object] = {}
    oracle_vids: list[int] = []
    for vid, view in state.views.items():
        p = plan_for_cq(view.cq)
        if has_cartesian(p):  # disconnected view body: oracle only
            oracle_vids.append(vid)
        else:
            plans[f"v{vid}"] = p
    extents: dict[int, R.Relation] = {}
    device: dict[int, E.PRel] = {}
    infos: dict[int, RelInfo] = {}
    roots: dict[str, E.PRel] = {}
    if plans:
        dag = build_dag(plans)
        wl = WorkloadExecutor(dag, store.stats, {}, safety=safety,
                              use_pallas=use_pallas, max_retries=max_retries)
        roots = wl.run(E.tt_device_indexes(store), {})
    for vid, view in state.views.items():
        if vid in oracle_vids:
            ext = materialize_view(view.cq, store)
        else:
            rows = E.to_numpy(roots[f"v{vid}"])
            ext = R.Relation(rows, tuple(h.name for h in view.cq.head))
        extents[vid] = ext
        infos[vid] = measured_info(ext)
        device[vid] = E.make_prel(
            ext.rows, capacity_for(len(ext.rows), safety=1.0))
    return extents, device, infos
