"""`python -m repro.analysis` — the static verification gate.

Runs the full analyzer stack with no device execution: tunes a
reference workload (search only — nothing materializes, nothing
compiles), statically verifies the resulting plan IR / capacities /
bucket bodies, and lints the library source with the AST repo rules.

    PYTHONPATH=src python -m repro.analysis --strict
    PYTHONPATH=src python -m repro.analysis --workload lubm --json
    PYTHONPATH=src python -m repro.analysis --rules-only

Exit status: 0 when the run passes the selected bar — `--strict`
demands ZERO findings (warnings included; the CI bar), the default
demands zero errors.
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.analysis.driver import analyze_repo, verify_session
from repro.analysis.findings import AnalysisReport

WORKLOADS = ("quickstart", "lubm", "none")


def build_session(workload: str, max_states: int,
                  universities: int | None = None):
    """Generate the reference universe and tune it (search only)."""
    from repro.api.session import TuningSession
    from repro.core.quality import QualityWeights
    from repro.core.search import SearchConfig
    from repro.core.wizard import WizardConfig
    from repro.rdf.generator import generate, lubm_workload

    if universities is None:
        universities = 1 if workload == "quickstart" else 2
    uni = generate(n_universities=universities, seed=0)
    queries = lubm_workload(uni.dictionary)
    cfg = WizardConfig(
        search=SearchConfig(strategy="greedy", max_states=max_states,
                            weights=QualityWeights()))
    session = TuningSession(uni.store, queries, schema=uni.schema,
                            type_id=uni.type_id, cfg=cfg)
    session.retune()
    return session


def run(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="static verification of the tuning pipeline")
    ap.add_argument("--workload", default="quickstart", choices=WORKLOADS,
                    help="reference workload to tune and verify "
                         "(none: skip the workload analyzers)")
    ap.add_argument("--strict", action="store_true",
                    help="fail on ANY finding, warnings included (CI bar)")
    ap.add_argument("--rules-only", action="store_true",
                    help="run only the AST repo rules")
    ap.add_argument("--no-rules", action="store_true",
                    help="skip the AST repo rules")
    ap.add_argument("--root", default=None,
                    help="library root for the repo rules "
                         "(default: the installed repro package)")
    ap.add_argument("--json", action="store_true",
                    help="emit the report as JSON")
    ap.add_argument("--max-states", type=int, default=80,
                    help="search budget for the reference tuning run")
    ap.add_argument("--universities", type=int, default=None,
                    help="scale of the generated universe")
    args = ap.parse_args(argv)

    report = AnalysisReport()
    if not args.rules_only and args.workload != "none":
        session = build_session(args.workload, args.max_states,
                                args.universities)
        wl = verify_session(session)
        report.findings.extend(wl.findings)
        report.checked.update(wl.checked)
        report.checked["workload_members"] = len(session.groups) or \
            len(session.workload)
    if not args.no_rules:
        rr = analyze_repo(args.root)
        report.findings.extend(rr.findings)
        report.checked.update(rr.checked)

    if args.json:
        print(json.dumps(report.to_json(), indent=2))
    else:
        print(report.format())
    passed = report.clean() if args.strict else report.ok
    return 0 if passed else 1


def main() -> None:
    sys.exit(run())
