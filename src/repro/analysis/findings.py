"""Finding / report model shared by every analyzer family.

A `Finding` is one violated (or hazarded) invariant: which analyzer saw
it, a stable rule id, where it points (a DAG node, a bucket label, a
file:line), and what is wrong.  Analyzers return lists of findings;
`AnalysisReport` aggregates them for the CLI, `TuningSession.verify()`
and the CI gate.
"""
from __future__ import annotations

from dataclasses import dataclass, field

# severity ladder: "error" breaks the completeness guarantee (wrong
# answers / crash), "warning" is a serve-time hazard (recompile storm,
# unbounded growth), "info" is advisory.
SEVERITIES = ("error", "warning", "info")


@dataclass(frozen=True)
class Finding:
    analyzer: str        # "ir" | "capacity" | "jaxpr" | "rules"
    rule: str            # stable rule id, e.g. "ir/key-collision"
    severity: str        # one of SEVERITIES
    message: str
    location: str = ""   # "node 7", "bucket w1:join:...", "file.py:42"

    def __post_init__(self) -> None:
        if self.severity not in SEVERITIES:
            raise ValueError(f"unknown severity {self.severity!r}")

    def format(self) -> str:
        loc = f" [{self.location}]" if self.location else ""
        return f"{self.severity:>7}  {self.rule}{loc}: {self.message}"


@dataclass
class AnalysisReport:
    """Aggregated findings of one analysis run."""

    findings: list[Finding] = field(default_factory=list)
    # how much was analyzed (for "zero findings" to mean something)
    checked: dict[str, int] = field(default_factory=dict)

    def extend(self, findings, analyzer: str | None = None,
               count_key: str | None = None, count: int = 0) -> None:
        self.findings.extend(findings)
        if count_key is not None:
            self.checked[count_key] = self.checked.get(count_key, 0) + count
        del analyzer  # kept for call-site readability

    def by_analyzer(self, analyzer: str) -> list[Finding]:
        return [f for f in self.findings if f.analyzer == analyzer]

    def errors(self) -> list[Finding]:
        return [f for f in self.findings if f.severity == "error"]

    def warnings(self) -> list[Finding]:
        return [f for f in self.findings if f.severity == "warning"]

    @property
    def ok(self) -> bool:
        """No errors (warnings allowed outside --strict)."""
        return not self.errors()

    def clean(self) -> bool:
        """No findings at all (the --strict bar)."""
        return not self.findings

    def summary(self) -> str:
        n_err, n_warn = len(self.errors()), len(self.warnings())
        n_info = len(self.findings) - n_err - n_warn
        scope = ", ".join(f"{k}={v}" for k, v in sorted(self.checked.items()))
        status = "clean" if self.clean() else \
            f"{n_err} error(s), {n_warn} warning(s), {n_info} info"
        return f"analysis: {status}" + (f" ({scope})" if scope else "")

    def format(self) -> str:
        lines = [f.format() for f in sorted(
            self.findings,
            key=lambda f: (SEVERITIES.index(f.severity), f.analyzer, f.rule))]
        lines.append(self.summary())
        return "\n".join(lines)

    def to_json(self) -> dict:
        return {
            "findings": [vars(f) for f in self.findings],
            "checked": dict(self.checked),
            "summary": self.summary(),
        }
