"""Repo rules: AST lint over the library source itself.

The runtime analyzers check what a program IS; these rules check what
the source says, catching patterns that only bite later:

  rules/bare-assert        `assert` in library code — stripped under
                           `python -O`, so the invariant silently stops
                           being checked (use repro.errors instead)
  rules/mutable-default    mutable default argument (shared across
                           calls; classic aliasing bug)
  rules/unhashable-static  a jit static argument with a mutable default
                           — tracing would crash (or worse, cache on
                           object identity) the first time the default
                           is used
  rules/swallowed-exception  in the serving/maintenance/api packages, a
                           broad handler (`except:` / `except Exception`)
                           whose body neither re-raises nor calls
                           anything — the fault-tolerant serving core
                           must degrade, roll back, or at least record
                           a fault; silently eating one hides exactly
                           the failures the degradation ladder exists
                           to surface (opt-out: ``# lint: allow-swallow``
                           on the except line)
  rules/unbounded-queue    in the serve package, container growth with
                           no visible bound: a `deque()` without
                           `maxlen`, or `.append/.appendleft/.extend`
                           on persistent state (an attribute) whose
                           module never trims it (`del x[...]`), slices
                           it back, or length-guards it — a serving
                           process runs indefinitely, so an unbounded
                           queue is a slow memory leak and an unbounded
                           latency backlog (opt-out:
                           ``# lint: allow-unbounded``)

Scope: the pipeline packages (`core`, `query`, `api`, `views`, `rdf`,
`serve`, `kernels`, `checkpoint`, `analysis`, the top-level modules).
The ML-substrate packages inherited from the seed (`models`, `launch`,
`train`, `configs`, `distributed`, `data`) are excluded — they run
under tracing where asserts act as shape guards — as are tests.  A
line-level opt-out exists: append ``# lint: allow-assert``.
"""
from __future__ import annotations

import ast
import os

from repro.analysis.findings import Finding

EXCLUDED_DIRS = frozenset(
    {"models", "launch", "train", "configs", "distributed", "data",
     "tests", "__pycache__"})
ALLOW_MARKER = "lint: allow-assert"
SWALLOW_MARKER = "lint: allow-swallow"
UNBOUNDED_MARKER = "lint: allow-unbounded"
# packages where a silently-swallowed exception defeats fault tolerance
SWALLOW_SCOPE = frozenset({"serve", "maintenance", "api"})
# packages where an unbounded queue is a memory leak / latency backlog
QUEUE_SCOPE = frozenset({"serve"})
_GROW_METHODS = ("append", "appendleft", "extend")

_MUTABLE_CALLS = ("list", "dict", "set", "bytearray")


def _f(rule: str, message: str, location: str,
       severity: str = "error") -> Finding:
    return Finding("rules", rule, severity, message, location)


def _is_mutable_literal(node: ast.expr) -> bool:
    if isinstance(node, (ast.List, ast.Dict, ast.Set, ast.ListComp,
                         ast.DictComp, ast.SetComp)):
        return True
    if isinstance(node, ast.Call) and isinstance(node.func, ast.Name):
        return node.func.id in _MUTABLE_CALLS
    return False


def _defaults_by_param(fn: ast.FunctionDef | ast.AsyncFunctionDef
                       ) -> dict[str, ast.expr]:
    """param name -> default expression (positional + kw-only)."""
    out: dict[str, ast.expr] = {}
    pos = fn.args.posonlyargs + fn.args.args
    for arg, default in zip(pos[len(pos) - len(fn.args.defaults):],
                            fn.args.defaults):
        out[arg.arg] = default
    for arg, default in zip(fn.args.kwonlyargs, fn.args.kw_defaults):
        if default is not None:
            out[arg.arg] = default
    return out


def _param_names(fn: ast.FunctionDef | ast.AsyncFunctionDef) -> list[str]:
    return [a.arg for a in fn.args.posonlyargs + fn.args.args]


def _is_jit_ref(node: ast.expr) -> bool:
    """`jax.jit`, `jit`, `pjit`, `jax.pmap` references."""
    if isinstance(node, ast.Name):
        return node.id in ("jit", "pjit", "pmap")
    if isinstance(node, ast.Attribute):
        return node.attr in ("jit", "pjit", "pmap")
    return False


def _static_params(call: ast.Call, fn: ast.FunctionDef | None
                   ) -> list[str] | None:
    """Parameter names a jit call marks static, or None if not a jit
    call with static arguments."""
    if not (_is_jit_ref(call.func)
            or (isinstance(call.func, ast.Attribute)
                and call.func.attr == "partial"
                and call.args and _is_jit_ref(call.args[0]))
            or (isinstance(call.func, ast.Name)
                and call.func.id == "partial"
                and call.args and _is_jit_ref(call.args[0]))):
        return None
    names: list[str] = []
    for kw in call.keywords:
        if kw.arg == "static_argnames":
            for elt in ast.walk(kw.value):
                if isinstance(elt, ast.Constant) and isinstance(elt.value,
                                                                str):
                    names.append(elt.value)
        elif kw.arg == "static_argnums" and fn is not None:
            params = _param_names(fn)
            for elt in ast.walk(kw.value):
                if isinstance(elt, ast.Constant) and isinstance(elt.value,
                                                                int):
                    if 0 <= elt.value < len(params):
                        names.append(params[elt.value])
    return names


def _catches_broad(handler: ast.ExceptHandler) -> bool:
    """`except:`, `except Exception`, `except BaseException` (possibly
    inside a tuple)."""
    if handler.type is None:
        return True
    for node in ast.walk(handler.type):
        if isinstance(node, ast.Name) \
                and node.id in ("Exception", "BaseException"):
            return True
        if isinstance(node, ast.Attribute) \
                and node.attr in ("Exception", "BaseException"):
            return True
    return False


def _swallows(handler: ast.ExceptHandler) -> bool:
    """True when the handler body neither re-raises nor calls anything
    (no rollback, no fault log, no fallback) — the failure vanishes."""
    for stmt in handler.body:
        for node in ast.walk(stmt):
            if isinstance(node, (ast.Raise, ast.Call)):
                return False
    return True


def _container_attr(node: ast.expr) -> str | None:
    """Name of the persistent attribute a container expression lives on,
    unwrapping subscripts: `self.log` -> "log", `self.produced[i]` ->
    "produced", `self.stats.faults` -> "faults".  None for plain local
    names (function-scoped lists are bounded by the call)."""
    while isinstance(node, ast.Subscript):
        node = node.value
    if isinstance(node, ast.Attribute):
        return node.attr
    return None


def _is_deque_call(node: ast.Call) -> bool:
    if isinstance(node.func, ast.Name):
        return node.func.id == "deque"
    if isinstance(node.func, ast.Attribute):
        return node.func.attr == "deque"
    return False


def _bounded_attrs(tree: ast.AST) -> set[str]:
    """Attributes the module visibly bounds: trimmed with `del x[...]`,
    reassigned through a slice of themselves, or length-guarded with
    `len(...)` anywhere (the guard is assumed to enforce a cap)."""
    bounded: set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Delete):
            for t in node.targets:
                if isinstance(t, ast.Subscript):
                    attr = _container_attr(t)
                    if attr:
                        bounded.add(attr)
        elif isinstance(node, ast.Call) and isinstance(node.func, ast.Name) \
                and node.func.id == "len" and node.args:
            attr = _container_attr(node.args[0])
            if attr:
                bounded.add(attr)
        elif isinstance(node, ast.Assign):
            for t in node.targets:
                attr = _container_attr(t) if isinstance(t, (ast.Subscript,
                                                            ast.Attribute)) \
                    else None
                if not attr:
                    continue
                if isinstance(node.value, ast.Subscript) \
                        and _container_attr(node.value) == attr:
                    bounded.add(attr)  # x = x[-n:] style self-trim
                if isinstance(node.value, ast.Call) \
                        and _is_deque_call(node.value) \
                        and any(kw.arg == "maxlen"
                                for kw in node.value.keywords):
                    bounded.add(attr)  # deque(maxlen=...) self-bounds
    return bounded


def _check_unbounded(tree: ast.AST, lines: list[str],
                     path: str) -> list[Finding]:
    out: list[Finding] = []
    bounded = _bounded_attrs(tree)

    def marked(lineno: int) -> bool:
        line = lines[lineno - 1] if lineno <= len(lines) else ""
        return UNBOUNDED_MARKER in line

    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        if _is_deque_call(node):
            if not any(kw.arg == "maxlen" for kw in node.keywords) \
                    and not marked(node.lineno):
                out.append(_f(
                    "rules/unbounded-queue",
                    "deque without maxlen in serving code — give it a "
                    "cap or opt out with `# lint: allow-unbounded`",
                    f"{path}:{node.lineno}"))
            continue
        if isinstance(node.func, ast.Attribute) \
                and node.func.attr in _GROW_METHODS:
            attr = _container_attr(node.func.value)
            if attr and attr not in bounded and not marked(node.lineno):
                out.append(_f(
                    "rules/unbounded-queue",
                    f"`.{node.func.attr}` grows persistent container "
                    f"{attr!r} with no visible bound in this module "
                    "(no del-trim, slice-trim, or len() guard) — a "
                    "serving process runs forever, so cap it or opt "
                    "out with `# lint: allow-unbounded`",
                    f"{path}:{node.lineno}"))
    return out


def check_source(source: str, path: str) -> list[Finding]:
    """Run every rule over one module's source."""
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as e:
        return [_f("rules/bare-assert", f"unparseable module: {e}",
                   f"{path}:{e.lineno or 0}")]
    lines = source.splitlines()
    out: list[Finding] = []
    top_pkg = path.replace(os.sep, "/").split("/")[0]
    swallow_scope = top_pkg in SWALLOW_SCOPE
    if top_pkg in QUEUE_SCOPE:
        out.extend(_check_unbounded(tree, lines, path))

    functions: dict[str, ast.FunctionDef | ast.AsyncFunctionDef] = {}
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            functions.setdefault(node.name, node)

    for node in ast.walk(tree):
        # rule: bare assert ------------------------------------------------
        if isinstance(node, ast.Assert):
            line = lines[node.lineno - 1] if node.lineno <= len(lines) else ""
            if ALLOW_MARKER not in line:
                out.append(_f(
                    "rules/bare-assert",
                    "bare `assert` in library code — stripped under "
                    "`python -O`; raise repro.errors.InvariantViolation "
                    "(or a typed exception) instead",
                    f"{path}:{node.lineno}"))
        # rule: mutable default -------------------------------------------
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for pname, default in _defaults_by_param(node).items():
                if _is_mutable_literal(default):
                    out.append(_f(
                        "rules/mutable-default",
                        f"parameter {pname!r} of {node.name}() has a "
                        "mutable default — shared across every call; "
                        "default to None and construct inside",
                        f"{path}:{node.lineno}"))
            # decorator form: @partial(jax.jit, static_argnames=...)
            for dec in node.decorator_list:
                if isinstance(dec, ast.Call):
                    statics = _static_params(dec, node)
                    if statics:
                        out.extend(_check_static_defaults(
                            node, statics, path))
        # rule: swallowed exception ----------------------------------------
        if isinstance(node, ast.ExceptHandler) and swallow_scope:
            line = lines[node.lineno - 1] if node.lineno <= len(lines) else ""
            if (SWALLOW_MARKER not in line and _catches_broad(node)
                    and _swallows(node)):
                out.append(_f(
                    "rules/swallowed-exception",
                    "broad except handler silently swallows the failure — "
                    "serving/maintenance code must re-raise, roll back, "
                    "degrade, or record a fault (repro.serve telemetry); "
                    "opt out with `# lint: allow-swallow` if the silence "
                    "is the contract",
                    f"{path}:{node.lineno}"))
        # rule: jit(f, static_...) call form -------------------------------
        if isinstance(node, ast.Call):
            target = None
            if node.args and isinstance(node.args[0], ast.Name):
                target = functions.get(node.args[0].id)
            statics = _static_params(node, target)
            if statics and target is not None:
                out.extend(_check_static_defaults(target, statics, path))
    return out


def _check_static_defaults(fn, statics: list[str],
                           path: str) -> list[Finding]:
    out: list[Finding] = []
    defaults = _defaults_by_param(fn)
    for pname in statics:
        default = defaults.get(pname)
        if default is not None and _is_mutable_literal(default):
            out.append(_f(
                "rules/unhashable-static",
                f"static argument {pname!r} of jitted {fn.name}() defaults "
                "to an unhashable value — the jit cache keys on hash() and "
                "will crash the first time the default is used",
                f"{path}:{fn.lineno}"))
    return out


def iter_library_files(root: str):
    """Python files of the pipeline packages under `root` (the `repro`
    package directory), honoring EXCLUDED_DIRS."""
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames[:] = sorted(d for d in dirnames if d not in EXCLUDED_DIRS)
        for name in sorted(filenames):
            if name.endswith(".py"):
                yield os.path.join(dirpath, name)


def run_repo_rules(root: str) -> tuple[list[Finding], int]:
    """Run every rule over the library tree; returns (findings, n_files)."""
    findings: list[Finding] = []
    n = 0
    for path in iter_library_files(root):
        with open(path, encoding="utf-8") as f:
            source = f.read()
        findings.extend(check_source(source, os.path.relpath(path, root)))
        n += 1
    return findings, n
