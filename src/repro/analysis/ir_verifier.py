"""Plan-IR verifier: structural invariants of a `WorkloadDAG`.

The whole fused pipeline trusts the DAG blindly: the workload compiler
indexes children positionally, buckets batch nodes by spec, and — most
dangerously — the interner's canonical keys decide which subtrees SHARE
one buffer.  A silent key collision means two different subplans read
the same result and some query returns wrong answers with no error
anywhere.  This module re-derives every one of those structural facts
from first principles and reports divergences as findings:

  ir/cycle            child ids must strictly precede the node (DAG-ness)
  ir/child-bounds     child ids and spec column indexes must be in range
  ir/width            declared width == operator-derived output width
  ir/spec             operator spec well-formed for its kind
  ir/key-structure    `DagNode.key` consistent with (kind, spec, children)
  ir/key-collision    two distinct nodes share a canonical content key
  ir/key-instability  re-interning the representative plan changes keys
  ir/root-coverage    every expected member has a root; roots resolve
  ir/orphan           node unreachable from any root (dead weight)
  ir/consumers        consumer counts match actual edges
  ir/plan             representative plan tree malformed
"""
from __future__ import annotations

from repro.analysis.findings import Finding
from repro.query.dag import WorkloadDAG, derived_width
from repro.query.plan import TTScan, ViewRef, validate_plan

_KINDS = ("scan", "view", "filter", "join", "project")


def _f(rule: str, severity: str, message: str, location: str = "") -> Finding:
    return Finding("ir", rule, severity, message, location)


def verify_dag(dag: WorkloadDAG,
               expected_members: set[str] | None = None) -> list[Finding]:
    """Statically verify a workload DAG; returns findings (empty = sound)."""
    out: list[Finding] = []
    n = len(dag.nodes)

    # ---- per-node structure ------------------------------------------
    for node in dag.nodes:
        loc = f"node {node.id} ({node.kind})"
        if node.kind not in _KINDS:
            out.append(_f("ir/spec", "error",
                          f"unknown operator kind {node.kind!r}", loc))
            continue
        if node.id >= n or dag.nodes[node.id] is not node:
            out.append(_f("ir/child-bounds", "error",
                          "node id does not match its position", loc))
            continue
        # acyclicity: the interner numbers children before parents, and
        # every downstream pass (waves, execution order, content keys)
        # relies on exactly that
        bad_child = False
        for c in node.child_ids:
            if not (0 <= c < n):
                out.append(_f("ir/child-bounds", "error",
                              f"child id {c} out of range [0, {n})", loc))
                bad_child = True
            elif c >= node.id:
                out.append(_f("ir/cycle", "error",
                              f"child id {c} does not precede the node — "
                              "topological order (and acyclicity) broken",
                              loc))
                bad_child = True
        if bad_child:
            continue
        out.extend(_verify_spec(dag, node, loc))
        out.extend(_verify_width(dag, node, loc))
        out.extend(_verify_key_structure(node, loc))
        if node.plan is not None:
            problems = validate_plan(node.plan)
            out.extend(_f("ir/plan", "error", p, loc) for p in problems)

    # ---- consumer-count consistency ----------------------------------
    true_consumers = {nid: 0 for nid in range(n)}
    for node in dag.nodes:
        for c in node.child_ids:
            if 0 <= c < n:
                true_consumers[c] += 1
    for nid in dag.roots.values():
        if 0 <= nid < n:
            true_consumers[nid] += 1
    for nid, expected in true_consumers.items():
        got = dag.consumers.get(nid, 0)
        if got != expected:
            out.append(_f(
                "ir/consumers", "error",
                f"consumer count {got} != actual edge count {expected} "
                "(sharing telemetry and reuse accounting are wrong)",
                f"node {nid}"))

    # ---- root coverage + reachability --------------------------------
    reachable: set[int] = set()
    for name, rid in dag.roots.items():
        if not (0 <= rid < n):
            out.append(_f("ir/root-coverage", "error",
                          f"root id {rid} out of range", f"root {name!r}"))
            continue
        stack = [rid]
        while stack:
            cur = stack.pop()
            if cur in reachable:
                continue
            reachable.add(cur)
            stack.extend(c for c in dag.nodes[cur].child_ids
                         if 0 <= c < n)
    if expected_members is not None:
        missing = expected_members - set(dag.roots)
        for name in sorted(missing):
            out.append(_f(
                "ir/root-coverage", "error",
                "workload member has no root in the DAG — its query is "
                "silently unanswered", f"root {name!r}"))
    for nid in range(n):
        if nid not in reachable:
            out.append(_f("ir/orphan", "warning",
                          "node unreachable from any root (computed every "
                          "execute, read by nobody)", f"node {nid}"))

    # ---- canonical-key soundness -------------------------------------
    out.extend(_verify_keys(dag))
    return out


def _verify_spec(dag: WorkloadDAG, node, loc: str) -> list[Finding]:
    out: list[Finding] = []
    widths = [dag.nodes[c].width for c in node.child_ids]
    if node.kind == "scan":
        if node.child_ids:
            out.append(_f("ir/spec", "error", "scan must be a leaf", loc))
    elif node.kind == "view":
        if node.child_ids:
            out.append(_f("ir/spec", "error", "view must be a leaf", loc))
        if not isinstance(node.spec, int):
            out.append(_f("ir/spec", "error",
                          f"view spec must be a view id, got "
                          f"{type(node.spec).__name__}", loc))
    elif node.kind == "filter":
        if len(node.child_ids) != 1:
            out.append(_f("ir/spec", "error",
                          f"filter needs 1 child, has {len(node.child_ids)}",
                          loc))
        else:
            ci, _value = node.spec
            if not (0 <= ci < widths[0]):
                out.append(_f("ir/child-bounds", "error",
                              f"filter column {ci} out of child width "
                              f"{widths[0]}", loc))
    elif node.kind == "join":
        if len(node.child_ids) != 2:
            out.append(_f("ir/spec", "error",
                          f"join needs 2 children, has {len(node.child_ids)}",
                          loc))
        else:
            if not node.spec:
                out.append(_f("ir/spec", "error",
                              "join with no equality pairs (cartesian "
                              "products never reach the device DAG)", loc))
            for l, r in node.spec:
                if not (0 <= l < widths[0]):
                    out.append(_f("ir/child-bounds", "error",
                                  f"join left column {l} out of width "
                                  f"{widths[0]}", loc))
                if not (0 <= r < widths[1]):
                    out.append(_f("ir/child-bounds", "error",
                                  f"join right column {r} out of width "
                                  f"{widths[1]}", loc))
    elif node.kind == "project":
        if len(node.child_ids) != 1:
            out.append(_f("ir/spec", "error",
                          f"project needs 1 child, has "
                          f"{len(node.child_ids)}", loc))
        else:
            idxs, dedupe = node.spec
            if not isinstance(dedupe, bool):
                out.append(_f("ir/spec", "error",
                              "project dedupe flag must be bool", loc))
            for i in idxs:
                if not (0 <= i < widths[0]):
                    out.append(_f("ir/child-bounds", "error",
                                  f"project column {i} out of child width "
                                  f"{widths[0]}", loc))
    return out


def _verify_width(dag: WorkloadDAG, node, loc: str) -> list[Finding]:
    if node.kind == "view":
        # not derivable from the spec; check against the representative
        if isinstance(node.plan, ViewRef) and \
                len(node.plan.schema) != node.width:
            return [_f("ir/width", "error",
                       f"declared width {node.width} != representative "
                       f"schema arity {len(node.plan.schema)}", loc)]
        return []
    try:
        want = derived_width(
            node.kind, node.spec,
            tuple(dag.nodes[c].width for c in node.child_ids))
    except (TypeError, IndexError, ValueError) as e:
        return [_f("ir/spec", "error",
                   f"width underivable from spec: {e}", loc)]
    if want != node.width:
        return [_f("ir/width", "error",
                   f"declared width {node.width} != operator-derived width "
                   f"{want} — consumers index a misaligned buffer", loc)]
    return []


def _verify_key_structure(node, loc: str) -> list[Finding]:
    """`DagNode.key` must encode exactly (kind, spec, child ids): a key
    that drifted from the node's actual structure is how two different
    subplans end up interned together."""
    key = node.key
    if not isinstance(key, tuple) or not key or key[0] != node.kind:
        return [_f("ir/key-structure", "error",
                   f"key {key!r} does not lead with the node kind", loc)]
    ok = True
    if node.kind == "filter":
        ci, value = node.spec
        ok = key[1:] == (node.child_ids[0], ci, value)
    elif node.kind == "join":
        ok = (len(key) == 4 and key[1] == node.child_ids[0]
              and key[2] == node.child_ids[1]
              and key[3] == tuple(sorted(node.spec)))
    elif node.kind == "project":
        idxs, dedupe = node.spec
        ok = key[1:] == (node.child_ids[0], idxs, dedupe)
    elif node.kind == "view":
        ok = key[1:] == (node.spec,)
    # scan keys hold the renaming-invariant atom encoding; checked via
    # re-interning in _verify_keys
    if not ok:
        return [_f("ir/key-structure", "error",
                   f"key {key!r} inconsistent with spec {node.spec!r} / "
                   f"children {node.child_ids}", loc)]
    return []


def _verify_keys(dag: WorkloadDAG) -> list[Finding]:
    """Canonical-key soundness: recompute keys from the representative
    plans and detect collisions/instabilities.

    * collision — two distinct live nodes with equal fully-recursive
      content keys should have been ONE node; if their plans differ
      semantically the shared buffer returns wrong answers for one of
      them.
    * instability — re-interning every root's representative plan into
      a fresh DAG must reproduce each root's content key; divergence
      means interning depends on construction order, so swap/retune
      rebuilds silently re-wire consumers.
    """
    out: list[Finding] = []
    try:
        keys = dag.content_keys()
    except (TypeError, IndexError) as e:
        return [_f("ir/key-structure", "error",
                   f"content keys uncomputable: {e}")]
    seen: dict = {}
    for nid, key in enumerate(keys):
        if key in seen:
            out.append(_f(
                "ir/key-collision", "error",
                f"nodes {seen[key]} and {nid} share canonical content key "
                "— the interner should have merged them; two subplans are "
                "aliasing one buffer", f"node {nid}"))
        else:
            seen[key] = nid

    if any(node.plan is None for node in dag.nodes):
        return out  # synthetic DAG without representatives
    fresh = WorkloadDAG()
    try:
        for name in sorted(dag.roots):
            fresh.add_root(name, dag.nodes[dag.roots[name]].plan)
        fresh_keys = fresh.content_keys()
    except Exception as e:  # interning itself blew up on a corrupt plan
        return out + [_f("ir/key-instability", "error",
                         f"re-interning representative plans failed: {e}")]
    for name in sorted(dag.roots):
        old = keys[dag.roots[name]]
        new = fresh_keys[fresh.roots[name]]
        if old != new:
            out.append(_f(
                "ir/key-instability", "error",
                "re-interning the representative plan yields a different "
                "canonical key — interning is order-dependent",
                f"root {name!r}"))
    return out
