"""Static verification of the tuning pipeline (no execution).

Four analyzer families over a tuned workload and the library source:

  * `ir_verifier`  — structural soundness of the shared-subplan DAG,
    including canonical-key collision/instability detection
  * `capacity`     — predicted buffer overflows and recompile hazards
    from the cost model, before anything runs
  * `jaxpr_lint`   — abstract traces of every bucket body checked
    against the engine contract (int32/bool, static shapes, no host
    callbacks) plus compile-cache key soundness
  * `maintenance_check` — streaming-update envelope: delta capacity
    classes, extent/TT growth headroom under the configured update
    rate, oracle-fallback maintenance, host/device alignment
  * `repo_rules`   — AST lint of the library source (bare asserts,
    mutable defaults, unhashable jit static args)

Entry points: `analyze_workload` / `analyze_state` / `verify_session` /
`analyze_repo` (driver.py), `WorkloadExecutor.analyze()`,
`TuningSession.verify()`, and the `python -m repro.analysis` CLI.
"""
from repro.analysis.capacity import analyze_capacity
from repro.analysis.driver import (analyze_repo, analyze_state,
                                   analyze_workload, verify_session)
from repro.analysis.findings import SEVERITIES, AnalysisReport, Finding
from repro.analysis.ir_verifier import verify_dag
from repro.analysis.jaxpr_lint import check_cache_keys, lint_program, lint_traced
from repro.analysis.maintenance_check import analyze_maintenance
from repro.analysis.repo_rules import check_source, run_repo_rules

__all__ = [
    "SEVERITIES", "AnalysisReport", "Finding",
    "analyze_capacity", "analyze_maintenance", "analyze_repo",
    "analyze_state", "analyze_workload", "check_cache_keys",
    "check_source", "lint_program", "lint_traced", "run_repo_rules",
    "verify_dag", "verify_session",
]
