"""Maintenance-plan analysis: streaming-update hazards, before serving.

The incremental maintainer (`repro.maintenance`) keeps steady-state
maintenance recompile-free by construction — fixed delta capacity
classes, padded TT uploads, extent headroom at attach.  Those guarantees
hold only under a configuration + update-rate envelope; this analyzer
checks the envelope statically, in the same spirit as `capacity.py`:

  maint/delta-cap        delta_cap is not a positive power-of-two class
                         (error: every batch re-buckets and recompiles)
                         or the expected batch exceeds it (warning: each
                         batch splits into multiple device passes)
  maint/extent-headroom  a view extent's capacity class is projected to
                         be outgrown within the hazard horizon at the
                         configured update rate — every growth promotes
                         the class and recompiles the consumer buckets
  maint/tt-headroom      the padded triple-table class itself is
                         projected to be outgrown within the horizon —
                         a TT class promotion recompiles EVERY bucket
  maint/oracle-fallback  a view is maintained by the host oracle (not a
                         full projection, or its delta plan would be
                         cartesian): per-batch re-evaluation and a full
                         extent re-upload (info)
  maint/alignment        live maintainer only: the host extent mirror
                         diverged from the device valid prefix — the
                         delete path would scrub the wrong rows (error)

Static mode (a tuned `State` + statistics) simulates the maintainer's
attach packing — `capacity_for(est_rows, growth_safety)` — so a default
`MaintenanceConfig` over a sane store analyzes clean by construction;
live mode (a bound `ViewMaintainer`) checks the REAL buffer classes,
row counts and measured per-triple costs instead of estimates.
"""
from __future__ import annotations

import math

from repro.analysis.findings import Finding
from repro.query import cost as cost_mod
from repro.query.buckets import CAP_CEIL

# warn when a capacity class is projected to be outgrown within this
# many update batches at the configured rate
GROWTH_HORIZON = 8


def _f(rule: str, severity: str, message: str, location: str = "") -> Finding:
    return Finding("maint", rule, severity, message, location)


def _check_delta_cap(cfg) -> list[Finding]:
    out: list[Finding] = []
    dcap = int(cfg.delta_cap)
    if dcap <= 0 or (dcap & (dcap - 1)) != 0:
        out.append(_f(
            "maint/delta-cap", "error",
            f"delta_cap {dcap} is not a positive power of two: delta "
            "relations would leave the capacity-class system and every "
            "batch would compile its own program"))
        return out
    if dcap > CAP_CEIL:
        out.append(_f(
            "maint/delta-cap", "error",
            f"delta_cap {dcap} exceeds the capacity ceiling {CAP_CEIL}"))
        return out
    if int(cfg.expected_batch) > dcap:
        passes = math.ceil(int(cfg.expected_batch) / dcap)
        out.append(_f(
            "maint/delta-cap", "warning",
            f"expected update batch ({cfg.expected_batch} triples) "
            f"exceeds delta_cap {dcap}: every batch splits into "
            f"{passes} chunked device passes — raise delta_cap to "
            "amortize the per-pass overhead"))
    return out


def _headroom_finding(rule: str, what: str, cap: int, rows: float,
                      growth_per_batch: float, horizon: int,
                      consequence: str, location: str) -> Finding | None:
    """Warn when `cap` is projected to be outgrown within `horizon`
    batches; None when the envelope holds."""
    if growth_per_batch <= 0:
        return None
    batches = (cap - rows) / growth_per_batch
    if batches >= horizon:
        return None
    return _f(
        rule, "warning",
        f"{what}: capacity class {cap} holds {rows:.0f} rows with "
        f"~{growth_per_batch:.1f} rows/batch projected growth — outgrown "
        f"in ~{max(batches, 0.0):.1f} batches (< horizon {horizon}); "
        f"{consequence}", location)


def analyze_maintenance(state=None, stats=None, cfg=None, *,
                        maintainer=None, update_rate: float | None = None,
                        horizon: int = GROWTH_HORIZON) -> list[Finding]:
    """Check a maintenance configuration against an update-rate envelope.

    Static mode: pass a tuned `state` + `stats` (+ optionally a
    `MaintenanceConfig`); extent sizes come from the cost estimates and
    capacities from the simulated attach packing.  Live mode: pass
    `maintainer=` (a bound `ViewMaintainer`); real device buffer
    classes, host mirrors and measured per-triple costs are checked.
    `update_rate` is triples per batch (defaults to the config's
    `expected_batch`).
    """
    from repro.maintenance import MaintenanceConfig, build_delta_plans

    live = maintainer is not None
    if live:
        ex = maintainer.executor
        state, stats, cfg = ex.state, ex.store.stats, maintainer.cfg
        plans = maintainer.plans
    else:
        if state is None or stats is None:
            raise ValueError("static mode needs state= and stats=")
        cfg = cfg or MaintenanceConfig()
        plans = build_delta_plans(state)
    rate = float(update_rate if update_rate is not None
                 else cfg.expected_batch)

    out: list[Finding] = []
    out.extend(_check_delta_cap(cfg))

    n_tt = max(float(stats.n_triples), 1.0)
    for vid in sorted(state.views):
        cq = state.views[vid].cq
        loc = f"view {vid}"
        if vid in plans.oracle_vids:
            out.append(_f(
                "maint/oracle-fallback", "info",
                "maintained by the host oracle (not a full projection or "
                "cartesian delta plan): every batch re-evaluates the view "
                "and re-uploads its extent", loc))
            continue
        if live:
            rel = maintainer.executor.device_views.get(vid)
            if rel is None:
                continue
            cap = int(rel.data.shape[0])
            rows = float(len(maintainer.executor.extents[vid].rows))
            host_rows = rows
            dev_n = float(int(rel.n))
            if host_rows != dev_n:
                out.append(_f(
                    "maint/alignment", "error",
                    f"host extent mirror has {host_rows:.0f} rows but the "
                    f"device valid prefix is {dev_n:.0f}: the delete mask "
                    "would scrub the wrong rows", loc))
            units = maintainer.costs.measured.get(cq.canonical_key())
            growth = rate * (units if units is not None
                             else rows / n_tt)
        else:
            rows = cost_mod.cq_rel_info(cq, stats).rows
            cap = cost_mod.capacity_for(rows, cfg.growth_safety)
            growth = rate * rows / n_tt
        f = _headroom_finding(
            "maint/extent-headroom", "extent growth", cap, rows, growth,
            horizon,
            "each class promotion recompiles the consumer buckets; "
            "raise growth_safety or re-attach with more headroom", loc)
        if f is not None:
            out.append(f)

    # the padded triple-table class: inserts land here every batch, and
    # outgrowing it re-buckets every scan in the program
    if live:
        tt_cap = int(maintainer.tt_cap)
        tt_rows = float(len(maintainer.executor.store))
    else:
        tt_cap = cost_mod.capacity_for(n_tt, cfg.tt_safety)
        tt_rows = n_tt
    f = _headroom_finding(
        "maint/tt-headroom", "triple-table growth", tt_cap, tt_rows,
        rate, horizon,
        "a TT class promotion recompiles every bucket of the serving "
        "program; raise tt_safety", "tt")
    if f is not None:
        out.append(f)
    return out
