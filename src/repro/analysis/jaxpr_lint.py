"""Jaxpr lint: trace every bucket body abstractly and check the traced
program obeys the engine's hard rules.

The bucketed executor compiles each bucket body once and reuses it for
every member via a persistent, process-global cache keyed on hand-built
signatures.  Two classes of silent failure live here: (1) the traced
program itself drifts from the engine contract — a float64 promotion
(2x memory + TPU-hostile), a host callback (breaks AOT serving), a
dynamic shape (cannot compile); (2) the cache keys collide or stop
being hashable, in which case one compiled body silently serves a
different bucket's members.  Everything is checked by TRACING ONLY
(`jax.make_jaxpr` over `ShapeDtypeStruct`s) — no device execution, no
XLA compile.

  jaxpr/float64       a 64-bit float/complex dtype appears in the trace
  jaxpr/weak-float    any float dtype in a query-engine body (the
                      engine is pure int32/bool)
  jaxpr/callback      host callback primitive in the traced body
  jaxpr/dynamic-shape non-static dimension in a traced aval
  jaxpr/trace-error   the body failed to trace at all
  jaxpr/key-unhashable a compile-cache key is not hashable
  jaxpr/key-collision  two buckets with different signatures map to the
                       same compile-cache key
"""
from __future__ import annotations

import jax
import numpy as np

from repro.analysis.findings import Finding
from repro.query.buckets import BucketedProgram, body_builder

_CALLBACK_PRIMITIVES = ("pure_callback", "io_callback", "debug_callback",
                        "outside_call", "host_callback")


def _f(rule: str, severity: str, message: str, location: str = "") -> Finding:
    return Finding("jaxpr", rule, severity, message, location)


def iter_eqns(jaxpr):
    """All equations of a (closed) jaxpr, descending into sub-jaxprs
    (scan/cond/while bodies and custom-call wrappers)."""
    inner = getattr(jaxpr, "jaxpr", jaxpr)
    for eqn in inner.eqns:
        yield eqn
        for v in eqn.params.values():
            for sub in (v if isinstance(v, (list, tuple)) else (v,)):
                if hasattr(sub, "eqns") or hasattr(sub, "jaxpr"):
                    yield from iter_eqns(sub)


def lint_traced(fn, arg_specs, location: str = "",
                forbid_floats: bool = True) -> list[Finding]:
    """Trace `fn` over abstract `arg_specs` and lint the jaxpr.

    `forbid_floats=True` applies the query-engine contract (int32/bool
    only); pass False for numeric kernels where f32 is expected and only
    64-bit promotion is an error.
    """
    out: list[Finding] = []
    try:
        closed = jax.make_jaxpr(fn)(*arg_specs)
    except Exception as e:
        return [_f("jaxpr/trace-error", "error",
                   f"body failed to trace: {type(e).__name__}: {e}",
                   location)]

    seen_dtypes: set[str] = set()
    for eqn in iter_eqns(closed):
        prim = eqn.primitive.name
        if any(cb in prim for cb in _CALLBACK_PRIMITIVES):
            out.append(_f(
                "jaxpr/callback", "error",
                f"host callback primitive {prim!r} in a compiled body — "
                "breaks AOT serving and device portability", location))
        for var in tuple(eqn.invars) + tuple(eqn.outvars):
            aval = getattr(var, "aval", None)
            if aval is None:
                continue
            shape = getattr(aval, "shape", ())
            for dim in shape:
                if not isinstance(dim, (int, np.integer)):
                    out.append(_f(
                        "jaxpr/dynamic-shape", "error",
                        f"non-static dimension {dim!r} in {prim}",
                        location))
            dtype = getattr(aval, "dtype", None)
            if dtype is not None:
                seen_dtypes.add(np.dtype(dtype).name)

    for name in sorted(seen_dtypes):
        if name in ("float64", "complex128"):
            out.append(_f(
                "jaxpr/float64", "error",
                f"{name} appears in the traced body — 64-bit promotion "
                "(check jax_enable_x64 and literal dtypes)", location))
        elif forbid_floats and name.startswith(("float", "complex",
                                                "bfloat")):
            out.append(_f(
                "jaxpr/weak-float", "error",
                f"{name} appears in a query-engine body that must be "
                "pure int32/bool — a float literal leaked into the "
                "relational path", location))
    return out


def check_cache_keys(keyed: list[tuple[object, object, str]]
                     ) -> list[Finding]:
    """`keyed` is [(signature, cache_key, location)]: every key must be
    hashable, and distinct signatures must yield distinct keys."""
    out: list[Finding] = []
    by_key: dict = {}
    for sig, key, loc in keyed:
        try:
            hash(key)
        except TypeError as e:
            out.append(_f(
                "jaxpr/key-unhashable", "error",
                f"compile-cache key is unhashable ({e}) — every lookup "
                "would crash or, worse, fall back to identity", loc))
            continue
        prev = by_key.get(key)
        if prev is not None and prev[0] != sig:
            out.append(_f(
                "jaxpr/key-collision", "error",
                f"cache key collides with {prev[1]} despite different "
                "static signatures — one compiled body would serve both",
                loc))
        else:
            by_key[key] = (sig, loc)
    return out


def lint_program(program: BucketedProgram, n_tt: int,
                 view_caps: dict[int, int] | None = None) -> list[Finding]:
    """Lint every bucket body of a `BucketedProgram` without executing:
    trace each body over abstract operands and check the compile-cache
    keys the program would use for them."""
    out: list[Finding] = []
    eff = program.static_eff_caps(view_caps)
    keyed: list[tuple[object, object, str]] = []
    for bucket in program.buckets:
        loc = f"bucket {bucket.label}"
        specs = program.abstract_args(bucket, n_tt, eff)
        fn = body_builder(bucket, program.use_pallas)
        out.extend(lint_traced(fn, specs, location=loc))
        keyed.append(((bucket.static, bucket.cap),
                      program.cache_key(bucket, specs), loc))
    out.extend(check_cache_keys(keyed))
    return out
