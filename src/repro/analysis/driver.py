"""Analysis driver: compose the analyzer families into one report.

Three entry granularities, all execution-free:

  * `analyze_workload` — lowest level: a DAG (+ optionally its
    `BucketedProgram`) with statistics and view infos in hand.  This is
    what `WorkloadExecutor.analyze()` calls.
  * `analyze_state` — a search `State` (tuned but not applied): builds
    the device DAG from the rewritings, estimates extent infos from the
    view CQs (`cost.cq_rel_info`), constructs the shape-bucketed
    program WITHOUT compiling it, and analyzes.  This is how the CLI
    and CI verify a workload nothing has executed yet.
  * `verify_session` — a `TuningSession`: prefers the live executor
    (real extent statistics, real learned capacities, real view buffer
    shapes) when one is applied; falls back to `analyze_state` on the
    tuned-but-unapplied best state.

`analyze_repo` runs the AST repo rules over the installed `repro`
package tree (or any root).
"""
from __future__ import annotations

import os

from repro.analysis import capacity as capacity_mod
from repro.analysis import (ir_verifier, jaxpr_lint, maintenance_check,
                            repo_rules)
from repro.analysis.findings import AnalysisReport
from repro.query import cost as cost_mod
from repro.query.dag import WorkloadDAG, build_dag
from repro.query.plan import has_cartesian


def analyze_workload(dag: WorkloadDAG, stats, view_infos, *,
                     program=None, n_tt: int | None = None,
                     view_caps: dict[int, int] | None = None,
                     expected_members: set[str] | None = None
                     ) -> AnalysisReport:
    """Run the IR verifier, the capacity analyzer and — when a bucketed
    `program` is supplied — the jaxpr lint over one workload."""
    report = AnalysisReport()
    report.extend(ir_verifier.verify_dag(dag, expected_members),
                  count_key="nodes", count=len(dag.nodes))
    report.extend(capacity_mod.analyze_capacity(dag, stats, view_infos,
                                                program=program),
                  count_key="sized_nodes",
                  count=sum(1 for n in dag.nodes
                            if n.kind in ("scan", "join")))
    if program is not None:
        if n_tt is None:
            n_tt = max(int(stats.n_triples), 1)
        report.extend(jaxpr_lint.lint_program(program, n_tt, view_caps),
                      count_key="buckets", count=len(program.buckets))
    return report


def analyze_state(state, stats, *, use_pallas: bool = False,
                  with_program: bool = True,
                  n_tt: int | None = None) -> AnalysisReport:
    """Statically analyze a tuned `State` before anything materializes.

    The device DAG is built exactly as `QueryExecutor` would build it
    (cartesian rewritings stay on the oracle and are excluded); extent
    infos are ESTIMATED from the view CQs, so the capacity findings are
    predictions, not measurements.  Constructing the `BucketedProgram`
    plans shapes only — nothing compiles, nothing runs.
    """
    from repro.query.buckets import BucketedProgram

    device_plans = {}
    oracle = 0
    for name, plan in state.rewritings.items():
        if has_cartesian(plan):
            oracle += 1
        else:
            device_plans[name] = plan
    dag = build_dag(device_plans)
    view_infos = {vid: cost_mod.cq_rel_info(v.cq, stats)
                  for vid, v in state.views.items()}
    program = None
    if with_program and dag.nodes:
        program = BucketedProgram(dag, stats, view_infos,
                                  use_pallas=use_pallas)
    report = analyze_workload(dag, stats, view_infos, program=program,
                              n_tt=n_tt,
                              expected_members=set(device_plans))
    report.extend(maintenance_check.analyze_maintenance(state, stats),
                  count_key="maint_views", count=len(state.views))
    if oracle:
        report.checked["oracle_fallbacks"] = oracle
    return report


def verify_session(session, *, n_tt: int | None = None) -> AnalysisReport:
    """Verify a `TuningSession`'s current configuration.

    With an applied executor: analyzes the live DAG against the real
    materialized extent statistics and the real compiled-shape program
    (including adaptively learned capacities), passing the actual view
    buffer shapes to the jaxpr lint.  Tuned but not applied: falls back
    to the estimate-based `analyze_state`.
    """
    ex = session.executor
    if ex is not None and not session.pending:
        expected = set(ex.state.rewritings) - ex._oracle_names
        stats = ex.store.stats
        program = None
        view_caps = None
        if ex.workload.mode == "bucketed":
            program = ex.workload._program()
            view_caps = {vid: int(rel.data.shape[0])
                         for vid, rel in ex.device_views.items()}
        report = analyze_workload(
            ex.dag, stats, ex.infos, program=program,
            n_tt=n_tt if n_tt is not None else int(ex.tt["spo"].shape[0]),
            view_caps=view_caps, expected_members=expected)
        maintainer = getattr(session, "_maintainer", None)
        if maintainer is not None and maintainer.executor is ex:
            # live maintenance envelope: real buffer classes, host
            # mirrors and measured per-triple costs
            maint = maintenance_check.analyze_maintenance(
                maintainer=maintainer)
        else:
            maint = maintenance_check.analyze_maintenance(ex.state, stats)
        report.extend(maint, count_key="maint_views",
                      count=len(ex.state.views))
        if ex._oracle_names:
            report.checked["oracle_fallbacks"] = len(ex._oracle_names)
        return report
    if session.best is None:
        raise RuntimeError("nothing to verify: retune() first")
    return analyze_state(session.best, session.store.stats,
                         use_pallas=session.cfg.use_pallas, n_tt=n_tt)


def analyze_repo(root: str | None = None) -> AnalysisReport:
    """Run the AST repo rules; `root` defaults to the installed `repro`
    package directory."""
    if root is None:
        root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    report = AnalysisReport()
    findings, n_files = repo_rules.run_repo_rules(root)
    report.extend(findings, count_key="files", count=n_files)
    return report
