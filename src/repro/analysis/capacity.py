"""Capacity & recompile-hazard analysis.

The engine's buffers are statically sized from cardinality estimates
(`cost.capacity_for`); an undersized buffer overflows at serve time and
the adaptive driver pays an overflow→promote→recompile cycle for it —
correct, but a latency cliff on the hot path.  This analyzer predicts
those cliffs from the same estimates BEFORE anything executes:

  cap/undersized       planned capacity below the estimated row demand —
                       the first run is already predicted to overflow
                       and recompile (per bucket: the whole bucket pays)
  cap/ceiling          demand exceeds the engine's capacity ceiling; the
                       promote chain cannot absorb it and the driver
                       will raise at serve time
  cap/headroom         capacity covers the estimate but with less than
                       2x slack — one modest mis-estimate triggers the
                       recompile cycle (warning)
  cap/chain-unbounded  the promote chain from a planned class fails to
                       reach the ceiling monotonically in bounded steps
                       (the driver would recompile forever)
  cap/invalid          a sized node carries a non-positive or
                       non-power-of-two capacity class
"""
from __future__ import annotations

from repro.analysis.findings import Finding
from repro.errors import InvariantViolation
from repro.query import cost as cost_mod
from repro.query.buckets import CAP_CEIL, BucketedProgram, plan_capacities
from repro.query.dag import WorkloadDAG

HEADROOM_WARN = 2.0  # flag sized buffers with < 2x slack over the estimate


def _f(rule: str, severity: str, message: str, location: str = "") -> Finding:
    return Finding("capacity", rule, severity, message, location)


def analyze_capacity(dag: WorkloadDAG, stats, view_infos, *,
                     caps: list[int] | None = None,
                     demands: list[float] | None = None,
                     safety: float = 4.0, ceil: int = CAP_CEIL,
                     program: BucketedProgram | None = None) -> list[Finding]:
    """Predict overflow/recompile hazards for a workload DAG.

    With `program` given, its planned capacities and demands are checked
    (including carried/promoted ones); otherwise capacities are planned
    fresh from the estimates like `BucketedProgram` would.
    """
    if program is not None:
        caps, demands = program.caps, program.demands
    if caps is None or demands is None:
        ests = cost_mod.estimate_dag(dag, stats, view_infos)
        planned, _s, _j, planned_demands = plan_capacities(
            dag, stats, view_infos, safety=safety, ests=ests)
        caps = caps if caps is not None else planned
        demands = demands if demands is not None else planned_demands

    out: list[Finding] = []
    checked_chains: set[int] = set()
    for node in dag.nodes:
        cap = caps[node.id]
        if node.kind not in ("scan", "join"):
            continue
        loc = f"node {node.id} ({node.kind})"
        if program is not None and node.id in program.node_bucket:
            loc += f", bucket {program.node_bucket[node.id].label}"
        demand = float(demands[node.id])
        if cap <= 0 or (cap & (cap - 1)) != 0:
            out.append(_f("cap/invalid", "error",
                          f"capacity {cap} is not a positive power of two "
                          "— bucketing by capacity class is broken", loc))
            continue
        if cap > ceil:
            out.append(_f("cap/invalid", "error",
                          f"capacity {cap} exceeds the ceiling {ceil}", loc))
            continue
        if demand > ceil:
            out.append(_f(
                "cap/ceiling", "error",
                f"estimated demand {demand:.0f} rows exceeds the capacity "
                f"ceiling {ceil}; the promote chain cannot absorb it and "
                "the adaptive driver will raise at serve time", loc))
            continue
        if demand > cap:
            promotions = 0
            c = cap
            while c < demand and c < ceil:
                c = cost_mod.promote_capacity(c, ceil)
                promotions += 1
            out.append(_f(
                "cap/undersized", "warning",
                f"planned capacity {cap} < estimated demand {demand:.0f} "
                f"rows: predicted to overflow and pay {promotions} "
                "promote+recompile cycle(s) at serve time — size it now",
                loc))
        elif demand > 0 and cap < ceil and cap / max(demand, 1.0) \
                < HEADROOM_WARN:
            out.append(_f(
                "cap/headroom", "warning",
                f"capacity {cap} holds only {cap / max(demand, 1.0):.2f}x "
                f"the estimated {demand:.0f} rows; a modest mis-estimate "
                "triggers the recompile cycle", loc))
        # promote chain must be bounded from every planned class
        if cap not in checked_chains:
            checked_chains.add(cap)
            try:
                chain = cost_mod.promotion_chain(cap, ceil)
            except InvariantViolation as e:
                out.append(_f("cap/chain-unbounded", "error", str(e), loc))
            else:
                if chain and chain[-1] != ceil:
                    out.append(_f(
                        "cap/chain-unbounded", "error",
                        f"promotion chain from {cap} stops at {chain[-1]} "
                        f"short of the ceiling {ceil}", loc))
    return out
