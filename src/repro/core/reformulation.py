"""RDFS query reformulation: compile schema knowledge into the workload.

Each query becomes a union of conjunctive queries (UCQ) whose plain
evaluation over the raw triples equals the original query's evaluation
over the RDFS-saturated triples (completeness under entailment).  The
rules follow the paper's technical report [3]:

  (s rdf:type C)  ->  (s rdf:type C') for every C' <= C
                  |   (s P ?new)      for every P with domain(P) <= C
                  |   (?new P s)      for every P with range(P)  <= C
  (s P o)         ->  (s P' o)        for every P' <= P

The cartesian product over atoms is deduplicated by canonical key and
capped (reformulation is exponential in the worst case; the cap is a
stop-condition the demo exposes).
"""
from __future__ import annotations

import itertools

from repro.core.queries import CQ, Atom, Const, Term, Var, dedupe_cqs
from repro.rdf.schema import RDFSchema

DEFAULT_MAX_REFORMULATIONS = 2048


def _atom_alternatives(atom: Atom, schema: RDFSchema, type_id: int,
                       fresh_counter: list[int]) -> list[Atom]:
    alts: list[Atom] = []
    if isinstance(atom.p, Const) and atom.p.id == type_id and isinstance(atom.o, Const):
        c = atom.o.id
        for sub in sorted(schema.subclasses(c)):
            alts.append(Atom(atom.s, atom.p, Const(sub)))
        # (x P y) entails (x type C) when domain(P) <= C — and so does any
        # SUBPROPERTY of such a P (P' <= P implies P'-triples are P-triples)
        dom_props: set[int] = set()
        for p in schema.props_with_domain_under(c):
            dom_props |= schema.subproperties(p)
        for p in sorted(dom_props):
            fresh_counter[0] += 1
            alts.append(Atom(atom.s, Const(p), Var(f"_r{fresh_counter[0]}")))
        rng_props: set[int] = set()
        for p in schema.props_with_range_under(c):
            rng_props |= schema.subproperties(p)
        for p in sorted(rng_props):
            fresh_counter[0] += 1
            alts.append(Atom(Var(f"_r{fresh_counter[0]}"), Const(p), atom.s))
        return alts
    if isinstance(atom.p, Const) and atom.p.id != type_id:
        for sub in sorted(schema.subproperties(atom.p.id)):
            alts.append(Atom(atom.s, Const(sub), atom.o))
        return alts
    return [atom]


def reformulate(cq: CQ, schema: RDFSchema, type_id: int,
                max_reformulations: int = DEFAULT_MAX_REFORMULATIONS) -> list[CQ]:
    """CQ -> UCQ, deduplicated; member i is named `{cq.name}#i`."""
    fresh_counter = [0]
    per_atom = [
        _atom_alternatives(a, schema, type_id, fresh_counter) for a in cq.atoms
    ]
    total = 1
    for alts in per_atom:
        total *= len(alts)
    if total > max_reformulations:
        raise ValueError(
            f"reformulation of {cq.name!r} would produce {total} CQs "
            f"(cap {max_reformulations}); raise the cap or simplify the schema"
        )
    out: list[CQ] = []
    for combo in itertools.product(*per_atom):
        out.append(CQ(cq.head, tuple(combo), name=cq.name, weight=cq.weight))
    out = dedupe_cqs(out)
    return [
        CQ(q.head, q.atoms, name=f"{cq.name}#{i}", weight=cq.weight)
        for i, q in enumerate(out)
    ]


def infer_type_id(queries: list[CQ], schema: RDFSchema) -> int | None:
    """Infer the rdf:type predicate id from workload + schema shape.

    A type atom is (?s, type, Class): its predicate is a constant the
    schema does NOT know as a property, and its object is a constant the
    schema DOES know as a class.  Returns the id when exactly one
    predicate qualifies across the workload, else None (ambiguous or no
    evidence — the caller must be told explicitly)."""
    classes: set[int] = set(schema.domain.values()) | set(schema.range_.values())
    for c, parents in schema.subclass.items():
        classes.add(c)
        classes |= parents
    props: set[int] = set(schema.domain) | set(schema.range_)
    for p, parents in schema.subprop.items():
        props.add(p)
        props |= parents
    candidates: set[int] = set()
    for q in queries:
        for atom in q.atoms:
            if (isinstance(atom.p, Const) and isinstance(atom.o, Const)
                    and atom.o.id in classes and atom.p.id not in props):
                candidates.add(atom.p.id)
    if len(candidates) == 1:
        return candidates.pop()
    return None


def reformulate_workload(queries: list[CQ], schema: RDFSchema | None, type_id: int,
                         max_reformulations: int = DEFAULT_MAX_REFORMULATIONS
                         ) -> tuple[list[CQ], dict[str, list[str]]]:
    """Reformulate every workload query; returns (all members, groups)
    where groups maps original name -> member names (union semantics)."""
    if schema is None:
        return list(queries), {q.name: [q.name] for q in queries}
    members: list[CQ] = []
    groups: dict[str, list[str]] = {}
    for q in queries:
        ref = reformulate(q, schema, type_id, max_reformulations)
        members.extend(ref)
        groups[q.name] = [m.name for m in ref]
    return members, groups
