"""Conjunctive-query (CQ) model for RDFViewS.

A conjunctive SPARQL query is a set of triple-pattern atoms over the
single triple table, plus a head (projected variables) and a workload
weight.  Views are full-projection CQs (they materialize every variable
of their body) so that rewritings can re-apply selections and joins on
top of them.

Canonicalization (`canonical_key`) gives a hashable form invariant under
variable renaming and atom reordering; it powers view fusion and search
memoization.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Iterator, Mapping, Sequence

from repro.errors import InvariantViolation


@dataclass(frozen=True, order=True)
class Var:
    name: str

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return f"?{self.name}"


@dataclass(frozen=True, order=True)
class Const:
    id: int

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return f"#{self.id}"


Term = Var | Const


@dataclass(frozen=True)
class Atom:
    """One triple pattern (s, p, o)."""

    s: Term
    p: Term
    o: Term

    def terms(self) -> tuple[Term, Term, Term]:
        return (self.s, self.p, self.o)

    def vars(self) -> tuple[Var, ...]:
        return tuple(t for t in self.terms() if isinstance(t, Var))

    def consts(self) -> tuple[tuple[int, int], ...]:
        """(position, id) for each constant in the atom."""
        return tuple(
            (i, t.id) for i, t in enumerate(self.terms()) if isinstance(t, Const)
        )

    def signature(self) -> tuple:
        """Shape of the atom ignoring variable identities (canonical aid).
        Uniform ("kind", id) entries so signatures sort across mixed
        constant/variable positions."""
        return tuple(
            ("c", t.id) if isinstance(t, Const) else ("v", -1)
            for t in self.terms()
        )

    def substitute(self, mapping: Mapping[Var, Term]) -> "Atom":
        def sub(t: Term) -> Term:
            return mapping.get(t, t) if isinstance(t, Var) else t

        return Atom(sub(self.s), sub(self.p), sub(self.o))


# Cap on the canonical-labelling search; beyond it we fall back to a greedy
# (deterministic but not perfectly canonical) labelling.  Workload queries
# have a handful of atoms, so this never triggers in practice.
_CANON_BUDGET = 20_000


@dataclass(frozen=True)
class CQ:
    """A conjunctive query: head <- atoms, with a workload weight."""

    head: tuple[Var, ...]
    atoms: tuple[Atom, ...]
    name: str = field(default="", compare=False)
    weight: float = field(default=1.0, compare=False)

    # ------------------------------------------------------------------
    # basic structure
    # ------------------------------------------------------------------
    def all_vars(self) -> tuple[Var, ...]:
        seen: dict[Var, None] = {}
        for a in self.atoms:
            for v in a.vars():
                seen.setdefault(v)
        return tuple(seen)

    def var_positions(self) -> dict[Var, list[tuple[int, int]]]:
        """var -> [(atom_idx, position)] occurrences."""
        occ: dict[Var, list[tuple[int, int]]] = {}
        for i, a in enumerate(self.atoms):
            for pos, t in enumerate(a.terms()):
                if isinstance(t, Var):
                    occ.setdefault(t, []).append((i, pos))
        return occ

    def join_vars(self) -> tuple[Var, ...]:
        """Variables shared by >= 2 atoms (join edges)."""
        occ = self.var_positions()
        return tuple(
            v for v, ps in occ.items() if len({i for i, _ in ps}) >= 2
        )

    def is_connected(self) -> bool:
        if len(self.atoms) <= 1:
            return True
        adj: dict[int, set[int]] = {i: set() for i in range(len(self.atoms))}
        occ = self.var_positions()
        for ps in occ.values():
            idxs = sorted({i for i, _ in ps})
            for a, b in itertools.combinations(idxs, 2):
                adj[a].add(b)
                adj[b].add(a)
        seen = {0}
        stack = [0]
        while stack:
            cur = stack.pop()
            for nxt in adj[cur]:
                if nxt not in seen:
                    seen.add(nxt)
                    stack.append(nxt)
        return len(seen) == len(self.atoms)

    def connected_components(self, drop_var: Var | None = None) -> list[tuple[int, ...]]:
        """Connected components of the atom join graph; edges induced by
        shared variables, optionally ignoring `drop_var` (join-cut probe)."""
        n = len(self.atoms)
        adj: dict[int, set[int]] = {i: set() for i in range(n)}
        for v, ps in self.var_positions().items():
            if drop_var is not None and v == drop_var:
                continue
            idxs = sorted({i for i, _ in ps})
            for a, b in itertools.combinations(idxs, 2):
                adj[a].add(b)
                adj[b].add(a)
        comps: list[tuple[int, ...]] = []
        unseen = set(range(n))
        while unseen:
            root = min(unseen)
            comp = {root}
            stack = [root]
            unseen.discard(root)
            while stack:
                cur = stack.pop()
                for nxt in adj[cur]:
                    if nxt in unseen:
                        unseen.discard(nxt)
                        comp.add(nxt)
                        stack.append(nxt)
            comps.append(tuple(sorted(comp)))
        return comps

    # ------------------------------------------------------------------
    # canonicalization
    # ------------------------------------------------------------------
    def canonical_key(self) -> tuple:
        """Hashable form invariant under variable renaming / atom order.

        Atoms are grouped by signature (constants pin groups); we search
        over within-group permutations, rename variables by first
        occurrence, and keep the lexicographically smallest encoding.
        The head is encoded through the same renaming.
        """
        atoms = list(self.atoms)
        order0 = sorted(range(len(atoms)), key=lambda i: atoms[i].signature())
        groups: list[list[int]] = []
        for i in order0:
            if groups and atoms[groups[-1][-1]].signature() == atoms[i].signature():
                groups[-1].append(i)
            else:
                groups.append([i])

        total = 1
        for g in groups:
            for k in range(2, len(g) + 1):
                total *= k
            if total > _CANON_BUDGET:
                break

        def encode(order: Sequence[int]) -> tuple:
            rename: dict[Var, int] = {}
            enc_atoms = []
            for i in order:
                enc_terms = []
                for t in atoms[i].terms():
                    if isinstance(t, Const):
                        enc_terms.append(("c", t.id))
                    else:
                        if t not in rename:
                            rename[t] = len(rename)
                        enc_terms.append(("v", rename[t]))
                enc_atoms.append(tuple(enc_terms))
            head_enc = tuple(
                ("v", rename[h]) if h in rename else ("free", h.name) for h in self.head
            )
            return (tuple(enc_atoms), tuple(sorted(head_enc)))

        if total > _CANON_BUDGET:  # pragma: no cover - pathological queries only
            return encode(order0)

        best: tuple | None = None
        for perms in itertools.product(
            *[itertools.permutations(g) for g in groups]
        ):
            order = [i for g in perms for i in g]
            cand = encode(order)
            if best is None or cand < best:
                best = cand
        if best is None:
            raise InvariantViolation("canonical search visited no ordering")
        return best

    def canonical_var_order(self) -> tuple[Var, ...]:
        """Variable order consistent with the winning canonical labelling."""
        atoms = list(self.atoms)
        order0 = sorted(range(len(atoms)), key=lambda i: atoms[i].signature())
        groups: list[list[int]] = []
        for i in order0:
            if groups and atoms[groups[-1][-1]].signature() == atoms[i].signature():
                groups[-1].append(i)
            else:
                groups.append([i])

        def encode(order: Sequence[int]) -> tuple[tuple, tuple[Var, ...]]:
            rename: dict[Var, int] = {}
            enc_atoms = []
            for i in order:
                enc_terms = []
                for t in atoms[i].terms():
                    if isinstance(t, Const):
                        enc_terms.append(("c", t.id))
                    else:
                        if t not in rename:
                            rename[t] = len(rename)
                        enc_terms.append(("v", rename[t]))
                enc_atoms.append(tuple(enc_terms))
            head_enc = tuple(
                ("v", rename[h]) if h in rename else ("free", h.name) for h in self.head
            )
            return (tuple(enc_atoms), tuple(sorted(head_enc))), tuple(rename)

        total = 1
        for g in groups:
            for k in range(2, len(g) + 1):
                total *= k

        if total > _CANON_BUDGET:  # pragma: no cover
            return encode([i for g in groups for i in g])[1]

        best: tuple | None = None
        best_vars: tuple[Var, ...] = ()
        for perms in itertools.product(*[itertools.permutations(g) for g in groups]):
            order = [i for g in perms for i in g]
            cand, vars_ = encode(order)
            if best is None or cand < best:
                best, best_vars = cand, vars_
        return best_vars

    def rename_apart(self, suffix: str) -> "CQ":
        mapping = {v: Var(f"{v.name}{suffix}") for v in self.all_vars()}
        return CQ(
            head=tuple(mapping[h] for h in self.head),
            atoms=tuple(a.substitute(mapping) for a in self.atoms),
            name=self.name,
            weight=self.weight,
        )

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        body = " . ".join(
            f"({a.s!r} {a.p!r} {a.o!r})" for a in self.atoms
        )
        return f"CQ[{self.name}]({', '.join(map(repr, self.head))} <- {body})"


def full_projection(atoms: Sequence[Atom], name: str = "", weight: float = 1.0) -> CQ:
    """A view-style CQ projecting every variable of its body."""
    tmp = CQ(head=(), atoms=tuple(atoms))
    return CQ(head=tmp.all_vars(), atoms=tuple(atoms), name=name, weight=weight)


def isomorphism(a: CQ, b: CQ) -> dict[Var, Var] | None:
    """Variable bijection mapping `a` onto `b` (atoms as sets), or None.

    Used by view fusion to redirect rewritings onto the surviving view.
    """
    if len(a.atoms) != len(b.atoms):
        return None
    if a.canonical_key() != b.canonical_key():
        return None
    b_atoms = set(b.atoms)

    a_vars = list(a.all_vars())

    def backtrack(i: int, mapping: dict[Var, Var], used: set[Var]) -> dict[Var, Var] | None:
        if i == len(a_vars):
            mapped = {at.substitute(mapping) for at in a.atoms}
            return dict(mapping) if mapped == b_atoms else None
        for cand in b.all_vars():
            if cand in used:
                continue
            mapping[a_vars[i]] = cand
            # quick pruning: every atom fully mapped so far must exist in b
            ok = True
            for at in a.atoms:
                sub = at.substitute(mapping)
                if not sub.vars() or all(v in mapping.values() for v in sub.vars()):
                    pass
            if ok:
                res = backtrack(i + 1, mapping, used | {cand})
                if res is not None:
                    return res
            del mapping[a_vars[i]]
        return None

    return backtrack(0, {}, set())


def dedupe_cqs(cqs: Sequence[CQ]) -> list[CQ]:
    seen: set = set()
    out: list[CQ] = []
    for q in cqs:
        k = q.canonical_key()
        if k not in seen:
            seen.add(k)
            out.append(q)
    return out
