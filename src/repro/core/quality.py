"""The quality function: weighted execution cost + maintenance + space.

epsilon(S) = w_exec * Σ_q weight(q)·cost(R(q))
           + w_maint * Σ_v maint(v)
           + w_space * Σ_v space(v)

All terms come from the statistics-driven cost model (query/cost.py), so
the same numbers drive the search, the JAX engine's buffer capacities and
the EXPERIMENTS.md claims.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.core.queries import CQ
from repro.core.state import State
from repro.query import cost as cost_mod
from repro.rdf.triples import Statistics

BYTES_PER_ID = 4


@dataclass(frozen=True)
class QualityWeights:
    w_exec: float = 1.0
    w_maint: float = 0.1
    w_space: float = 0.01
    update_rate: float = 1.0  # expected triple inserts per query answered


@dataclass
class QualityBreakdown:
    exec_cost: float
    maint_cost: float
    space_bytes: float
    total: float
    per_query: dict[str, float] = field(default_factory=dict)
    per_view_rows: dict[int, float] = field(default_factory=dict)


def view_maintenance_cost(cq: CQ, stats: Statistics) -> float:
    """Expected incremental-maintenance work for one random triple insert.

    For each atom i, the insert matches it with probability
    card(atom_i)/N; the delta query then joins the remaining atoms —
    approximated by the view cardinality over the atom's own cardinality
    (delta-join estimate).
    """
    n = max(stats.n_triples, 1)
    total_card = cost_mod.cq_cardinality(cq, stats)
    cost = 0.0
    for atom in cq.atoms:
        a_card = max(cost_mod.atom_cardinality(atom, stats), 1e-3)
        p_match = min(a_card / n, 1.0)
        delta_cost = max(total_card / a_card, 1.0) + len(cq.atoms)
        cost += p_match * delta_cost
    return cost


@dataclass
class MaintenanceCostModel:
    """Measured per-view maintenance cost, keyed by the view CQ's
    canonical key so measurements survive retunes (view ids change,
    isomorphic views keep their key).

    `measured` holds EWMA'd work units (extent rows touched per update
    triple) reported by the streaming maintainer; views never maintained
    yet fall back to the static `view_maintenance_cost` estimate — the
    paper's a-priori model, progressively replaced by reality."""

    measured: dict = field(default_factory=dict)  # canonical_key -> units
    alpha: float = 0.3  # EWMA smoothing for observe()

    def observe(self, cq: CQ, units_per_triple: float) -> None:
        key = cq.canonical_key()
        prev = self.measured.get(key)
        self.measured[key] = (units_per_triple if prev is None else
                              (1 - self.alpha) * prev
                              + self.alpha * units_per_triple)

    def cost_for(self, cq: CQ, stats: Statistics) -> float:
        got = self.measured.get(cq.canonical_key())
        return view_maintenance_cost(cq, stats) if got is None else got

    def __len__(self) -> int:
        return len(self.measured)


def view_infos_for(state: State, stats: Statistics) -> dict[int, cost_mod.RelInfo]:
    return {vid: cost_mod.cq_rel_info(v.cq, stats) for vid, v in state.views.items()}


def quality(state: State, stats: Statistics,
            weights: QualityWeights = QualityWeights(),
            maint_model: MaintenanceCostModel | None = None
            ) -> QualityBreakdown:
    infos = view_infos_for(state, stats)
    per_query: dict[str, float] = {}
    exec_cost = 0.0
    for q in state.queries:
        est = cost_mod.estimate_plan(state.rewritings[q.name], stats, infos)
        per_query[q.name] = est.cost
        exec_cost += q.weight * est.cost

    maint = 0.0
    space = 0.0
    per_view_rows: dict[int, float] = {}
    for vid, v in state.views.items():
        rows = infos[vid].rows
        per_view_rows[vid] = rows
        space += rows * len(v.cq.head) * BYTES_PER_ID
        unit = (maint_model.cost_for(v.cq, stats) if maint_model is not None
                else view_maintenance_cost(v.cq, stats))
        maint += weights.update_rate * unit

    total = (weights.w_exec * exec_cost + weights.w_maint * maint
             + weights.w_space * space)
    return QualityBreakdown(exec_cost=exec_cost, maint_cost=maint,
                            space_bytes=space, total=total,
                            per_query=per_query, per_view_rows=per_view_rows)
