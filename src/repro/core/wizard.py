"""The storage tuning wizard: end-to-end pipeline of Figure 1.

Workload Processor (RDFS reformulation) -> initial state -> States
Navigator (search) -> View Materializer -> Query Executor.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.core.executor import QueryExecutor
from repro.core.quality import QualityBreakdown, QualityWeights, quality
from repro.core.reformulation import reformulate_workload
from repro.core.search import SearchConfig, SearchResult, search
from repro.core.state import State, initial_state
from repro.rdf.schema import RDFSchema
from repro.rdf.triples import TripleStore


@dataclass
class WizardConfig:
    search: SearchConfig = field(default_factory=SearchConfig)
    use_schema: bool = True
    max_reformulations: int = 2048
    use_pallas: bool = False


@dataclass
class WizardReport:
    initial: State
    initial_quality: QualityBreakdown
    result: SearchResult
    executor: QueryExecutor
    groups: dict[str, list[str]]

    def summary(self) -> str:
        lines = [
            f"initial: total={self.initial_quality.total:.1f} "
            f"({len(self.initial.views)} views)",
            f"search:  {self.result.summary()}",
            "chosen views:",
        ]
        for vid, v in sorted(self.result.best.views.items()):
            lines.append(
                f"  v{vid}: {len(v.cq.atoms)} atoms / {len(v.cq.head)} cols "
                f"(~{self.result.best_quality.per_view_rows.get(vid, 0):.0f} rows est)"
            )
        return "\n".join(lines)


def tune(store: TripleStore, workload, schema: RDFSchema | None = None,
         type_id: int | None = None, cfg: WizardConfig | None = None) -> WizardReport:
    cfg = cfg or WizardConfig()
    if cfg.use_schema and schema is not None:
        assert type_id is not None, "type_id required for schema reformulation"
        members, groups = reformulate_workload(
            list(workload), schema, type_id, cfg.max_reformulations
        )
    else:
        members, groups = list(workload), {q.name: [q.name] for q in workload}

    init = initial_state(members)
    init_q = quality(init, store.stats, cfg.search.weights)
    result = search(init, store.stats, cfg.search)
    executor = QueryExecutor(store, result.best, groups, use_pallas=cfg.use_pallas)
    return WizardReport(initial=init, initial_quality=init_q, result=result,
                        executor=executor, groups=groups)
