"""The storage tuning wizard: end-to-end pipeline of Figure 1.

Workload Processor (RDFS reformulation) -> initial state -> States
Navigator (search) -> View Materializer -> Query Executor.

`tune()` is the original one-shot entry point, kept as a compatibility
shim: it runs a throwaway `repro.api.TuningSession` (retune + apply)
and repackages the result as a `WizardReport`.  New code should hold a
session instead — it supports incremental re-tuning and online view
swaps that a one-shot call cannot.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass, field

from repro.core.executor import QueryExecutor
from repro.core.quality import QualityBreakdown
from repro.core.search import SearchConfig, SearchResult
from repro.core.state import State
from repro.rdf.schema import RDFSchema
from repro.rdf.triples import TripleStore


@dataclass
class WizardConfig:
    search: SearchConfig = field(default_factory=SearchConfig)
    use_schema: bool = True
    max_reformulations: int = 2048
    use_pallas: bool = False


@dataclass
class WizardReport:
    initial: State
    initial_quality: QualityBreakdown
    result: SearchResult
    executor: QueryExecutor
    groups: dict[str, list[str]]

    def summary(self) -> str:
        lines = [
            f"initial: total={self.initial_quality.total:.1f} "
            f"({len(self.initial.views)} views)",
            f"search:  {self.result.summary()}",
            "chosen views:",
        ]
        for vid, v in sorted(self.result.best.views.items()):
            lines.append(
                f"  v{vid}: {len(v.cq.atoms)} atoms / {len(v.cq.head)} cols "
                f"(~{self.result.best_quality.per_view_rows.get(vid, 0):.0f} rows est)"
            )
        return "\n".join(lines)


def tune(store: TripleStore, workload, schema: RDFSchema | None = None,
         type_id: int | None = None, cfg: WizardConfig | None = None) -> WizardReport:
    """One-shot wizard run (deprecated): prefer `repro.api.TuningSession`.

    `type_id=None` with a schema infers the rdf:type predicate from the
    workload when unambiguous; a `ValueError` is raised otherwise.
    """
    from repro.api.session import TuningSession  # lazy: avoids import cycle

    warnings.warn(
        "repro.core.wizard.tune() is a one-shot shim; use "
        "repro.api.TuningSession for incremental re-tuning",
        DeprecationWarning, stacklevel=2)
    session = TuningSession(store, workload=list(workload), schema=schema,
                            type_id=type_id, cfg=cfg)
    rep = session.retune()
    session.apply()
    return WizardReport(initial=rep.seed, initial_quality=rep.seed_quality,
                        result=rep.result, executor=session.executor,
                        groups=session.groups)
