"""The three transitions of the paper: selection cut, join cut, view fusion.

Each transition maps a state to a successor state, updating both the view
set V and every affected rewriting in R so the state invariant holds
(rewritings answer the workload exactly).

  * selection cut — relax a constant in a view to a fresh variable; the
    rewritings compensate with sigma (Filter) + a no-dedupe Project that
    restores the original arity/order.
  * join cut — split a view across a join variable whose removal
    disconnects its atom set; rewritings compensate with an EquiJoin.
  * view fusion — merge two views that are identical up to variable
    renaming; rewritings are redirected through a column permutation.

Relaxations (cuts) make views more generic, which is what enables fusion
to discover shared sub-queries across the workload — the paper's route to
storage savings.
"""
from __future__ import annotations

import itertools
from dataclasses import replace
from typing import Iterator

from repro.core.queries import CQ, Atom, Const, Var, full_projection, isomorphism
from repro.core.state import State, View
from repro.errors import InvariantViolation, require
from repro.query.plan import (EquiJoin, Filter, Plan, Project, ViewRef,
                              referenced_views, remap_view, replace_view)


def _update_rewritings(state: State, vid: int, replacement: Plan) -> dict[str, Plan]:
    out = {}
    for name, plan in state.rewritings.items():
        out[name] = replace_view(plan, vid, replacement) if vid in referenced_views(plan) else plan
    return out


# ----------------------------------------------------------------------
# selection cut
# ----------------------------------------------------------------------
def selection_cut_candidates(state: State, allow_predicate_cut: bool = False
                             ) -> Iterator[tuple[int, int, int]]:
    """(view_id, atom_idx, position) for every constant occurrence."""
    for vid, v in state.views.items():
        for ai, atom in enumerate(v.cq.atoms):
            for pos, t in enumerate(atom.terms()):
                if isinstance(t, Const):
                    if pos == 1 and not allow_predicate_cut:
                        continue
                    yield (vid, ai, pos)


def apply_selection_cut(state: State, vid: int, atom_idx: int, pos: int) -> State:
    view = state.views[vid]
    atom = view.cq.atoms[atom_idx]
    const = atom.terms()[pos]
    if not isinstance(const, Const):
        raise InvariantViolation("selection cut needs a constant")
    fresh, state = state.fresh_var()
    new_terms = list(atom.terms())
    new_terms[pos] = fresh
    new_atoms = list(view.cq.atoms)
    new_atoms[atom_idx] = Atom(*new_terms)
    new_cq = full_projection(new_atoms, name=f"{view.cq.name}+sc")
    new_vid = state.next_view_id
    new_view = View(new_vid, new_cq)

    old_head = tuple(h.name for h in view.cq.head)
    new_head = tuple(h.name for h in new_cq.head)
    # compensation: sigma_{fresh = const} then restore the old column order
    comp: Plan = Filter(ViewRef(new_vid, new_head), fresh.name, const.id)
    comp = Project(comp, old_head, dedupe=False)

    views = dict(state.views)
    del views[vid]
    views[new_vid] = new_view
    rewritings = _update_rewritings(state, vid, comp)
    return replace(
        state, views=views, rewritings=rewritings, next_view_id=new_vid + 1,
    ).gc().with_path(f"sc(v{vid},a{atom_idx},p{pos})")


# ----------------------------------------------------------------------
# join cut
# ----------------------------------------------------------------------
def join_cut_candidates(state: State) -> Iterator[tuple[int, Var, tuple[int, ...]]]:
    """(view_id, var, atom-component) such that dropping `var`'s edges
    splits the view into `component` + rest, sharing only `var`."""
    for vid, v in state.views.items():
        if len(v.cq.atoms) < 2:
            continue
        occ = v.cq.var_positions()
        for x in v.cq.join_vars():
            comps = v.cq.connected_components(drop_var=x)
            if len(comps) < 2:
                continue
            x_atoms = {i for i, _ in occ[x]}
            for comp in comps:
                comp_set = set(comp)
                # both sides of the split must contain the cut variable
                if not (x_atoms & comp_set) or not (x_atoms - comp_set):
                    continue
                yield (vid, x, comp)


def apply_join_cut(state: State, vid: int, x: Var, comp: tuple[int, ...]) -> State:
    view = state.views[vid]
    part1 = [view.cq.atoms[i] for i in comp]
    part2 = [a for i, a in enumerate(view.cq.atoms) if i not in comp]
    require(bool(part1 and part2), "join cut must split the view")
    cq1 = full_projection(part1, name=f"{view.cq.name}+jc1")
    cq2 = full_projection(part2, name=f"{view.cq.name}+jc2")
    # both sides must still contain the cut variable
    require(x in cq1.all_vars() and x in cq2.all_vars(),
            f"cut variable {x!r} must appear on both sides of the split")
    # the two parts share only x (guaranteed by component construction)
    shared = set(cq1.all_vars()) & set(cq2.all_vars())
    require(shared == {x}, f"parts share {shared}, expected only {x}")

    vid1 = state.next_view_id
    vid2 = vid1 + 1
    head1 = tuple(h.name for h in cq1.head)
    head2 = tuple(h.name for h in cq2.head)
    joined = EquiJoin(ViewRef(vid1, head1), ViewRef(vid2, head2),
                      pairs=((x.name, x.name),))
    old_head = tuple(h.name for h in view.cq.head)
    comp_plan: Plan = Project(joined, old_head, dedupe=False)

    views = dict(state.views)
    del views[vid]
    views[vid1] = View(vid1, cq1)
    views[vid2] = View(vid2, cq2)
    rewritings = _update_rewritings(state, vid, comp_plan)
    return replace(
        state, views=views, rewritings=rewritings, next_view_id=vid2 + 1,
    ).gc().with_path(f"jc(v{vid},{x.name})")


# ----------------------------------------------------------------------
# view fusion
# ----------------------------------------------------------------------
def fusion_candidates(state: State) -> Iterator[tuple[int, int]]:
    """(keep_vid, drop_vid) pairs of views equal up to variable renaming."""
    by_key: dict = {}
    for vid in sorted(state.views):
        k = state.views[vid].cq.canonical_key()
        by_key.setdefault(k, []).append(vid)
    for vids in by_key.values():
        for a, b in itertools.combinations(vids, 2):
            yield (a, b)


def apply_fusion(state: State, keep_vid: int, drop_vid: int) -> State:
    keep, drop = state.views[keep_vid], state.views[drop_vid]
    iso = isomorphism(drop.cq, keep.cq)
    if iso is None:
        raise InvariantViolation("fusion requires isomorphic views")
    # perm[j]: position in drop.head of the variable mapped to keep.head[j]
    drop_pos = {h: i for i, h in enumerate(drop.cq.head)}
    keep_pos = {h: j for j, h in enumerate(keep.cq.head)}
    perm = [0] * len(keep.cq.head)
    for dvar, kvar in iso.items():
        perm[keep_pos[kvar]] = drop_pos[dvar]
    views = dict(state.views)
    del views[drop_vid]
    rewritings = {
        name: remap_view(plan, drop_vid, keep_vid, tuple(perm))
        for name, plan in state.rewritings.items()
    }
    return replace(state, views=views, rewritings=rewritings).gc().with_path(
        f"fuse(v{keep_vid}<-v{drop_vid})"
    )


# ----------------------------------------------------------------------
# successor enumeration
# ----------------------------------------------------------------------
def successors(state: State, allow_predicate_cut: bool = False) -> Iterator[State]:
    for a, b in fusion_candidates(state):
        yield apply_fusion(state, a, b)
    for vid, ai, pos in selection_cut_candidates(state, allow_predicate_cut):
        yield apply_selection_cut(state, vid, ai, pos)
    for vid, x, comp in join_cut_candidates(state):
        yield apply_join_cut(state, vid, x, comp)


def is_fully_relaxed(state: State) -> bool:
    """Stop condition: every view is a single const-free atom (the TT
    itself) — no further transition can be useful."""
    for v in state.views.values():
        if len(v.cq.atoms) > 1:
            return False
        if any(isinstance(t, Const) for t in v.cq.atoms[0].terms()):
            return False
    return True
