"""Query Executor: answers workload queries through the stored rewritings.

Two paths with identical answers:
  * `answer(name)`        — JAX engine over materialized padded views
                            (the production path; jitted once per query),
  * `answer_direct(name)` — oracle evaluation over the raw triple table
                            (the paper's "before tuning" baseline).

Union groups from RDFS reformulation are answered by unioning member
rewritings (`answer_group`).
"""
from __future__ import annotations

import jax
import numpy as np

from repro.core.state import State
from repro.query import engine as E
from repro.query import ref_engine as R
from repro.query.plan import plan_for_cq
from repro.rdf.triples import TripleStore
from repro.views.materializer import materialize_state


class QueryExecutor:
    def __init__(self, store: TripleStore, state: State,
                 groups: dict[str, list[str]] | None = None,
                 use_pallas: bool = False):
        self.store = store
        self.state = state
        self.groups = groups or {q.name: [q.name] for q in state.queries}
        self.extents, self.device_views, self.infos = materialize_state(state, store)
        self.tt = E.tt_device_indexes(store)
        self._queries = {q.name: q for q in state.queries}
        self._fns = {}
        for q in state.queries:
            fn = E.build_executor(
                state.rewritings[q.name], store.stats, self.infos,
                use_pallas=use_pallas,
            )
            self._fns[q.name] = (jax.jit(fn), fn.out_columns)

    # ------------------------------------------------------------------
    def answer(self, name: str) -> np.ndarray:
        """Answer one (possibly reformulated-member) query via its rewriting."""
        fn, _cols = self._fns[name]
        out = fn(self.tt, self.device_views)
        if bool(out.overflow):
            raise RuntimeError(
                f"capacity overflow answering {name!r}; re-plan with a larger "
                f"safety factor"
            )
        return E.to_numpy(out)

    def answer_group(self, original_name: str) -> set[tuple[int, ...]]:
        """Union semantics over the reformulation members of a query."""
        out: set[tuple[int, ...]] = set()
        for member in self.groups[original_name]:
            out |= {tuple(r) for r in self.answer(member).tolist()}
        return out

    # ------------------------------------------------------------------
    def answer_direct(self, name: str) -> set[tuple[int, ...]]:
        """Baseline: evaluate the original CQ straight over the TT."""
        q = self._queries[name]
        return R.evaluate_cq(q, self.store).as_set()

    def answer_group_direct(self, original_name: str) -> set[tuple[int, ...]]:
        out: set[tuple[int, ...]] = set()
        for member in self.groups[original_name]:
            out |= self.answer_direct(member)
        return out
