"""Query Executor: answers workload queries through the stored rewritings.

The production path is *workload-level*: every member rewriting
(including reformulation-group members) is canonicalized into one
shared-subplan DAG (`query/dag.py`) and compiled into a single jitted
program (`query/workload.py`) that answers the entire workload in one
device call — each shared subtree computed once.  Capacity overflows no
longer raise: the adaptive driver doubles the offending node's buffer
and recompiles under a bounded retry budget (telemetry on
`executor.workload`).

Paths with identical answers:
  * `answer(name)` / `answer_workload()` — fused JAX engine over
    materialized padded views (adaptive, jitted once per workload),
  * `answer_per_query(name)` — legacy per-query jitted tree compilation
    (kept for A/B benchmarks; raises on overflow like the old engine),
  * `answer_direct(name)` — oracle evaluation over the raw triple table
    (the paper's "before tuning" baseline).

Union groups from RDFS reformulation are answered by unioning member
rewritings (`answer_group`).  Disconnected rewritings (cartesian
products) are not device-compilable and fall back to the oracle over
the materialized extents.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import numpy as np

from repro.core.state import State
from repro.query import engine as E
from repro.query import ref_engine as R
from repro.query.dag import build_dag
from repro.query.plan import has_cartesian
from repro.query.workload import WorkloadExecutor
from repro.rdf.triples import TripleStore
from repro.views.materializer import materialize_state, materialize_state_device


@dataclass
class ExecutorSnapshot:
    """Everything `swap_state`/`refresh` mutate, captured by reference
    (dicts shallow-copied) so a failed hot swap restores the executor
    object in place — a server holding it keeps serving the previous
    program."""

    store: object
    state: State
    groups: dict
    queries: dict
    dag: object
    oracle_names: set
    extents: dict
    device_views: dict
    infos: dict
    tt: object
    workload: object
    results: dict | None


class QueryExecutor:
    def __init__(self, store: TripleStore, state: State,
                 groups: dict[str, list[str]] | None = None,
                 use_pallas: bool = False, safety: float = 4.0,
                 max_retries: int = 12, cap_planner=None,
                 device_materialize: bool = False,
                 workload_mode: str = "bucketed",
                 fault_hook=None):
        self.fault_hook = fault_hook
        self.store = store
        self.state = state
        self.groups = groups or {q.name: [q.name] for q in state.queries}
        self._use_pallas = use_pallas
        self._safety = safety
        self._max_retries = max_retries
        self._cap_planner = cap_planner
        self._device_materialize = device_materialize
        self._workload_mode = workload_mode
        self._queries = {q.name: q for q in state.queries}

        # ---- fused workload path: one DAG + one jitted program --------
        self._build_dag()
        self._load_device_state(store)

        # legacy per-query path: built lazily on first access (benchmarks
        # and A/B tests only; the production path never compiles it)
        self.__fns = None

    def _build_dag(self) -> None:
        device_plans = {}
        self._oracle_names: set[str] = set()
        for name, plan in self.state.rewritings.items():
            if has_cartesian(plan):
                self._oracle_names.add(name)
            else:
                device_plans[name] = plan
        self.dag = build_dag(device_plans)

    def _load_device_state(self, store: TripleStore,
                           carry_caps: dict | None = None) -> None:
        """(Re)materialize views and upload TT indexes + rebuild the
        fused executor against them.  `carry_caps` seeds the new program
        with capacities a previous one learned adaptively."""
        self.store = store
        if self._device_materialize:
            self.extents, self.device_views, self.infos = \
                materialize_state_device(self.state, store,
                                         use_pallas=self._use_pallas)
        else:
            self.extents, self.device_views, self.infos = \
                materialize_state(self.state, store)
        self.tt = E.tt_device_indexes(store)
        self.workload = WorkloadExecutor(
            self.dag, store.stats, self.infos, safety=self._safety,
            use_pallas=self._use_pallas, max_retries=self._max_retries,
            cap_planner=self._cap_planner, mode=self._workload_mode,
            carry_caps=carry_caps, fault_hook=self.fault_hook,
        )
        self._results: dict[str, np.ndarray] | None = None

    # ------------------------------------------------------------------
    # transactional binding snapshots
    # ------------------------------------------------------------------
    def snapshot(self) -> ExecutorSnapshot:
        """Capture every binding `swap_state`/`refresh` mutate."""
        return ExecutorSnapshot(
            store=self.store, state=self.state, groups=dict(self.groups),
            queries=dict(self._queries), dag=self.dag,
            oracle_names=set(self._oracle_names),
            extents=dict(self.extents), device_views=dict(self.device_views),
            infos=dict(self.infos), tt=self.tt, workload=self.workload,
            results=self._results)

    def restore(self, snap: ExecutorSnapshot) -> None:
        """Roll the executor back to a snapshot, in place."""
        self.store = snap.store
        self.state = snap.state
        self.groups = snap.groups
        self._queries = snap.queries
        self.dag = snap.dag
        self._oracle_names = snap.oracle_names
        self.extents = snap.extents
        self.device_views = snap.device_views
        self.infos = snap.infos
        self.tt = snap.tt
        self.workload = snap.workload
        self._results = snap.results
        self.__fns = None

    def set_fault_hook(self, hook) -> None:
        """Attach a chaos injector to this executor and its current
        fused program (future programs inherit it automatically)."""
        self.fault_hook = hook
        self.workload.fault_hook = hook

    def refresh(self, store: TripleStore | None = None) -> None:
        """Point the executor at a maintained/replaced triple store:
        re-materializes every view extent, re-uploads the TT indexes,
        and recompiles the fused program against the fresh statistics.
        With no argument, refreshes device state from the current store
        (e.g. after in-place mutation).  Capacities the old program
        learned adaptively are carried into the new one.  Transactional:
        a failure mid-refresh restores the previous bindings."""
        snap = self.snapshot()
        carry = self.workload.learned_caps()
        try:
            self._load_device_state(
                store if store is not None else self.store,
                carry_caps=carry)
        except Exception:
            self.restore(snap)
            raise
        self.__fns = None

    def swap_state(self, state: State,
                   groups: dict[str, list[str]] | None = None,
                   warm: bool = True) -> dict:
        """Online view swap onto a retuned configuration: diff old vs new
        views by canonical key, materialize ONLY the genuinely new
        extents (reusing surviving ones through a column permutation),
        drop dead extents, and hot-swap the compiled workload program.
        The executor object stays valid throughout — a server holding it
        keeps serving.

        Capacities the outgoing program learned adaptively are carried
        into the incoming one (keyed by DAG content key), so the fresh
        program does not re-learn overflows the old one already healed.
        With `warm=True` (default) the new program is pre-warmed before
        the swap returns: every bucket body is compiled (mostly
        persistent-cache hits) and the workload results are cached, so
        the serving path never pays a cold compile.  Returns the swap
        summary: {"materialized": [vid], "reused": [vid],
        "dropped": [prev_vid]}.

        The swap is TRANSACTIONAL: any failure — materialization,
        program construction, the pre-warm compile/run — rolls every
        binding back to the snapshot taken on entry and re-raises, so
        the executor object keeps serving the previous program.
        """
        from repro.views.materializer import materialize_state_delta

        snap = self.snapshot()
        carry = self.workload.learned_caps()
        try:
            extents, device, infos, reused, fresh, dropped = \
                materialize_state_delta(state, self.store, self.state,
                                        self.extents, self.infos,
                                        self.device_views)
            self.state = state
            self.groups = groups or {q.name: [q.name] for q in state.queries}
            self._queries = {q.name: q for q in state.queries}
            self.extents, self.device_views, self.infos = \
                extents, device, infos
            self._build_dag()
            self.workload = WorkloadExecutor(
                self.dag, self.store.stats, self.infos, safety=self._safety,
                use_pallas=self._use_pallas, max_retries=self._max_retries,
                cap_planner=self._cap_planner, mode=self._workload_mode,
                carry_caps=carry, fault_hook=self.fault_hook,
            )
            self._results = None
            self.__fns = None
            if warm:
                self.warmup()
        except Exception:
            self.restore(snap)
            raise
        return {"materialized": sorted(fresh), "reused": sorted(reused),
                "dropped": dropped}

    def note_maintenance(self, store: TripleStore) -> None:
        """In-place delta applied by `repro.maintenance.ViewMaintainer`:
        extents/device buffers/TT were updated under the executor, so
        point at the new store and drop cached answers.  The compiled
        workload program survives — maintenance keeps operand shapes in
        their capacity classes precisely so this is NOT a refresh()."""
        self.store = store
        self._results = None
        self.__fns = None

    def warmup(self) -> None:
        """Compile every bucket body of the current program and cache
        the workload results, so the next `answer*` call is pure reads —
        the pre-warming half of the hot-swap contract."""
        roots = self.workload.warmup(self.tt, self.device_views)
        self._results = {name: E.to_numpy(rel) for name, rel in roots.items()}

    @property
    def _fns(self):
        if self.__fns is None:
            self.__fns = {}
            for q in self.state.queries:
                if q.name in self._oracle_names:
                    continue
                fn = E.build_executor(
                    self.state.rewritings[q.name], self.store.stats,
                    self.infos, safety=self._safety,
                    use_pallas=self._use_pallas,
                )
                self.__fns[q.name] = (jax.jit(fn), fn.out_columns)
        return self.__fns

    # ------------------------------------------------------------------
    def answer_workload(self) -> dict[str, np.ndarray]:
        """Answer every member rewriting in one fused device call
        (cached; overflow recovered adaptively)."""
        if self._results is None:
            roots = self.workload.run(self.tt, self.device_views)
            self._results = {name: E.to_numpy(rel)
                             for name, rel in roots.items()}
        return self._results

    def answer(self, name: str) -> np.ndarray:
        """Answer one (possibly reformulated-member) query via its rewriting."""
        if name in self._oracle_names:
            return R.execute(self.state.rewritings[name], self.store,
                             self.extents).rows
        return self.answer_workload()[name]

    def answer_group(self, original_name: str) -> set[tuple[int, ...]]:
        """Union semantics over the reformulation members of a query."""
        out: set[tuple[int, ...]] = set()
        for member in self.groups[original_name]:
            out |= {tuple(r) for r in self.answer(member).tolist()}
        return out

    # ------------------------------------------------------------------
    def answer_per_query(self, name: str) -> np.ndarray:
        """Legacy path: this member's rewriting compiled and run alone."""
        fn, _cols = self._fns[name]
        out = fn(self.tt, self.device_views)
        if bool(out.overflow):
            raise RuntimeError(
                f"capacity overflow answering {name!r}; re-plan with a larger "
                f"safety factor"
            )
        return E.to_numpy(out)

    def answer_group_per_query(self, original_name: str
                               ) -> set[tuple[int, ...]]:
        """Union-group answer through the per-query unrolled path — the
        serving ladder's first fallback when the fused program fails.
        Each member compiles and runs alone (no shared subplans, raises
        on overflow like the old engine); cartesian members fall back
        to the oracle over the materialized extents as usual."""
        out: set[tuple[int, ...]] = set()
        for member in self.groups[original_name]:
            if member in self._oracle_names:
                out |= {tuple(r) for r in self.answer(member).tolist()}
            else:
                out |= {tuple(r)
                        for r in self.answer_per_query(member).tolist()}
        return out

    # ------------------------------------------------------------------
    def answer_direct(self, name: str) -> set[tuple[int, ...]]:
        """Baseline: evaluate the original CQ straight over the TT."""
        q = self._queries[name]
        return R.evaluate_cq(q, self.store).as_set()

    def answer_group_direct(self, original_name: str) -> set[tuple[int, ...]]:
        out: set[tuple[int, ...]] = set()
        for member in self.groups[original_name]:
            out |= self.answer_direct(member)
        return out

    # ------------------------------------------------------------------
    def telemetry(self) -> dict:
        t = self.workload.telemetry()
        t["oracle_fallbacks"] = len(self._oracle_names)
        return t
