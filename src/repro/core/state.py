"""Search states: S = ⟨V, R⟩ — candidate views + workload rewritings.

Invariant maintained by every transition: for each workload query q,
`rewritings[q.name]` evaluates (over the extents of `views`) to exactly
the answer of q over the triple table.  The property-based test suite
checks this invariant on randomly generated transition paths.

Positional contract: a `ViewRef(vid).schema` is positionally aligned with
`views[vid].cq.head` (names may be plan-local renamings).
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field, replace

from repro.core.queries import CQ, Atom, Const, Var, full_projection
from repro.query.plan import Plan, Project, ViewRef, referenced_views


@dataclass(frozen=True)
class View:
    id: int
    cq: CQ  # full projection: head == all body variables


@dataclass(frozen=True)
class State:
    views: dict[int, View] = field(default_factory=dict)
    rewritings: dict[str, Plan] = field(default_factory=dict)
    queries: tuple[CQ, ...] = ()
    next_view_id: int = 0
    next_fresh: int = 0
    # the transition path that produced this state (for the demo UI / logs)
    path: tuple[str, ...] = ()

    # ------------------------------------------------------------------
    def key(self) -> frozenset:
        """Memoization key: the canonical multiset of views."""
        keys: list = []
        for v in self.views.values():
            keys.append(v.cq.canonical_key())
        # multiset: count duplicates
        out: dict = {}
        for k in keys:
            out[k] = out.get(k, 0) + 1
        return frozenset(out.items())

    def live_view_ids(self) -> set[int]:
        used: set[int] = set()
        for p in self.rewritings.values():
            used |= referenced_views(p)
        return used

    def gc(self) -> "State":
        """Drop views no rewriting references."""
        live = self.live_view_ids()
        if live == set(self.views):
            return self
        return replace(self, views={k: v for k, v in self.views.items() if k in live})

    def with_path(self, step: str) -> "State":
        return replace(self, path=self.path + (step,))

    def fresh_var(self) -> tuple[Var, "State"]:
        v = Var(f"_f{self.next_fresh}")
        return v, replace(self, next_fresh=self.next_fresh + 1)

    def summary(self) -> str:  # pragma: no cover - debug aid
        lines = [f"State({len(self.views)} views)"]
        for v in self.views.values():
            lines.append(f"  v{v.id}: {len(v.cq.atoms)} atoms, head={len(v.cq.head)}")
        return "\n".join(lines)


def _materialize_exactly(state_views: dict[int, View],
                         rewritings: dict[str, Plan],
                         q: CQ, nid: int) -> int:
    """Add q's own full-projection view + trivial rewriting (the paper's
    initial-state shape for one query); returns the next free view id."""
    view_cq = full_projection(q.atoms, name=f"v_{q.name}")
    state_views[nid] = View(id=nid, cq=view_cq)
    head_names = tuple(h.name for h in view_cq.head)
    ref = ViewRef(nid, head_names)
    plan: Plan = ref
    q_head = tuple(h.name for h in q.head)
    if q_head != head_names:
        plan = Project(ref, q_head)
    rewritings[q.name] = plan
    return nid + 1


def initial_state(queries: list[CQ]) -> State:
    """The paper's initial state: materialize exactly the workload.

    Best execution cost (each query is a view scan), worst storage /
    maintenance.
    """
    views: dict[int, View] = {}
    rewritings: dict[str, Plan] = {}
    nid = 0
    for q in queries:
        if not q.name:
            raise ValueError("workload queries must be named")
        if q.name in rewritings:
            raise ValueError(f"duplicate query name {q.name!r}")
        nid = _materialize_exactly(views, rewritings, q, nid)
    return State(views=views, rewritings=rewritings, queries=tuple(queries),
                 next_view_id=nid)


def graft_queries(state: State, queries: list[CQ]) -> State:
    """Evolve a tuned state's workload: each new query enters in its
    initial-state shape (own view, trivial rewriting) next to the
    already-relaxed views — the warm-start seed for an incremental
    retune."""
    views = dict(state.views)
    rewritings = dict(state.rewritings)
    nid = state.next_view_id
    for q in queries:
        if not q.name:
            raise ValueError("workload queries must be named")
        if q.name in rewritings:
            raise ValueError(f"duplicate query name {q.name!r}")
        nid = _materialize_exactly(views, rewritings, q, nid)
    return replace(state, views=views, rewritings=rewritings,
                   queries=state.queries + tuple(queries), next_view_id=nid)


def drop_queries(state: State, names: set[str]) -> State:
    """Remove queries from a tuned state; views only they referenced are
    garbage-collected (their extents become droppable dead weight)."""
    missing = names - {q.name for q in state.queries}
    if missing:
        raise KeyError(f"unknown queries: {sorted(missing)}")
    rewritings = {n: p for n, p in state.rewritings.items() if n not in names}
    queries = tuple(q for q in state.queries if q.name not in names)
    return replace(state, rewritings=rewritings, queries=queries).gc()
