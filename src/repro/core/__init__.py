"""RDFViewS core: the paper's contribution.

Modules:
  queries        — conjunctive-query model (CQ/Atom/Var/Const)
  state          — search states S = (V, R) + initial_state
  transitions    — selection cut / join cut / view fusion
  quality        — the quality function epsilon(S)
  search         — exhaustive + heuristic strategies
  reformulation  — RDFS-aware query reformulation (CQ -> UCQ)
  executor       — the Query Executor over materialized views
  wizard         — end-to-end tune() pipeline

Public names are re-exported lazily to avoid import cycles with
repro.query (which uses the CQ model).
"""
_EXPORTS = {
    "CQ": "repro.core.queries", "Atom": "repro.core.queries",
    "Const": "repro.core.queries", "Var": "repro.core.queries",
    "full_projection": "repro.core.queries",
    "State": "repro.core.state", "View": "repro.core.state",
    "initial_state": "repro.core.state",
    "QualityWeights": "repro.core.quality", "quality": "repro.core.quality",
    "SearchConfig": "repro.core.search", "SearchResult": "repro.core.search",
    "search": "repro.core.search",
    "WizardConfig": "repro.core.wizard", "WizardReport": "repro.core.wizard",
    "tune": "repro.core.wizard",
}

__all__ = list(_EXPORTS)


def __getattr__(name: str):
    if name in _EXPORTS:
        import importlib

        mod = importlib.import_module(_EXPORTS[name])
        return getattr(mod, name)
    raise AttributeError(f"module 'repro.core' has no attribute {name!r}")
