"""States Navigator: strategies over the view-configuration search space.

Two exhaustive strategies (DFS, best-first) navigate the whole space with
memoization; heuristic strategies (greedy, beam, simulated annealing)
prune it, as the paper's demo offers ("quick search" vs "optimal
solution").  Stop conditions: state budget, wall-clock budget, and the
fully-relaxed detector.
"""
from __future__ import annotations

import heapq
import math
import random
import time
from dataclasses import dataclass, field

from repro.core.quality import (MaintenanceCostModel, QualityBreakdown,
                                QualityWeights, quality)
from repro.core.state import State
from repro.core.transitions import is_fully_relaxed, successors
from repro.rdf.triples import Statistics


@dataclass
class SearchConfig:
    strategy: str = "greedy"  # exhaustive_dfs|best_first|greedy|beam|anneal
    max_states: int = 5000
    max_seconds: float = 60.0
    beam_width: int = 8
    anneal_steps: int = 400
    anneal_t0: float = 1.0
    anneal_decay: float = 0.99
    seed: int = 0
    allow_predicate_cut: bool = False
    stop_fully_relaxed: bool = True
    weights: QualityWeights = field(default_factory=QualityWeights)
    # warm-start seed: when set, the navigator resumes from this state
    # instead of the initial_state it is handed (TuningSession.retune)
    initial: State | None = None
    # measured per-view maintenance costs (repro.maintenance); None keeps
    # the static a-priori estimate for every view
    maint_model: MaintenanceCostModel | None = None


@dataclass
class SearchResult:
    best: State
    best_quality: QualityBreakdown
    explored: int
    elapsed_s: float
    log: list[dict] = field(default_factory=list)

    def summary(self) -> str:
        q = self.best_quality
        return (f"explored={self.explored} states in {self.elapsed_s:.2f}s; "
                f"best total={q.total:.1f} (exec={q.exec_cost:.1f}, "
                f"maint={q.maint_cost:.1f}, space={q.space_bytes:.0f}B, "
                f"{len(self.best.views)} views)")


def _expand(state: State, cfg: SearchConfig) -> list[State]:
    if cfg.stop_fully_relaxed and is_fully_relaxed(state):
        return []
    return list(successors(state, allow_predicate_cut=cfg.allow_predicate_cut))


def search(initial: State, stats: Statistics, cfg: SearchConfig) -> SearchResult:
    fn = {
        "exhaustive_dfs": _exhaustive_dfs,
        "best_first": _best_first,
        "greedy": _greedy,
        "beam": _beam,
        "anneal": _anneal,
    }[cfg.strategy]
    if cfg.initial is not None:
        initial = cfg.initial
    t0 = time.monotonic()
    result = fn(initial, stats, cfg, t0)
    result.elapsed_s = time.monotonic() - t0
    return result


def _exhaustive_dfs(initial: State, stats, cfg: SearchConfig, t0: float) -> SearchResult:
    best, best_q = initial, quality(initial, stats, cfg.weights, cfg.maint_model)
    seen = {initial.key()}
    stack = [initial]
    explored = 1
    log = [{"step": 0, "total": best_q.total, "views": len(initial.views)}]
    while stack:
        if explored >= cfg.max_states or time.monotonic() - t0 > cfg.max_seconds:
            break
        cur = stack.pop()
        for nxt in _expand(cur, cfg):
            k = nxt.key()
            if k in seen:
                continue
            seen.add(k)
            explored += 1
            q = quality(nxt, stats, cfg.weights, cfg.maint_model)
            if q.total < best_q.total:
                best, best_q = nxt, q
                log.append({"step": explored, "total": q.total, "views": len(nxt.views)})
            stack.append(nxt)
            if explored >= cfg.max_states:
                break
    return SearchResult(best, best_q, explored, 0.0, log)


def _best_first(initial: State, stats, cfg: SearchConfig, t0: float) -> SearchResult:
    best, best_q = initial, quality(initial, stats, cfg.weights, cfg.maint_model)
    seen = {initial.key()}
    counter = 0
    heap = [(best_q.total, counter, initial)]
    explored = 1
    log = [{"step": 0, "total": best_q.total, "views": len(initial.views)}]
    while heap:
        if explored >= cfg.max_states or time.monotonic() - t0 > cfg.max_seconds:
            break
        _, _, cur = heapq.heappop(heap)
        for nxt in _expand(cur, cfg):
            k = nxt.key()
            if k in seen:
                continue
            seen.add(k)
            explored += 1
            q = quality(nxt, stats, cfg.weights, cfg.maint_model)
            if q.total < best_q.total:
                best, best_q = nxt, q
                log.append({"step": explored, "total": q.total, "views": len(nxt.views)})
            counter += 1
            heapq.heappush(heap, (q.total, counter, nxt))
            if explored >= cfg.max_states:
                break
    return SearchResult(best, best_q, explored, 0.0, log)


def _greedy(initial: State, stats, cfg: SearchConfig, t0: float) -> SearchResult:
    cur, cur_q = initial, quality(initial, stats, cfg.weights, cfg.maint_model)
    explored = 1
    log = [{"step": 0, "total": cur_q.total, "views": len(initial.views)}]
    while time.monotonic() - t0 <= cfg.max_seconds and explored < cfg.max_states:
        best_next, best_next_q = None, None
        for nxt in _expand(cur, cfg):
            explored += 1
            q = quality(nxt, stats, cfg.weights, cfg.maint_model)
            if best_next_q is None or q.total < best_next_q.total:
                best_next, best_next_q = nxt, q
            if explored >= cfg.max_states:
                break
        if best_next is None or best_next_q.total >= cur_q.total:
            break  # local optimum
        cur, cur_q = best_next, best_next_q
        log.append({"step": explored, "total": cur_q.total, "views": len(cur.views)})
    return SearchResult(cur, cur_q, explored, 0.0, log)


def _beam(initial: State, stats, cfg: SearchConfig, t0: float) -> SearchResult:
    best, best_q = initial, quality(initial, stats, cfg.weights, cfg.maint_model)
    frontier = [(best_q, initial)]
    seen = {initial.key()}
    explored = 1
    log = [{"step": 0, "total": best_q.total, "views": len(initial.views)}]
    while frontier:
        if explored >= cfg.max_states or time.monotonic() - t0 > cfg.max_seconds:
            break
        candidates: list[tuple[QualityBreakdown, State]] = []
        for _, cur in frontier:
            for nxt in _expand(cur, cfg):
                k = nxt.key()
                if k in seen:
                    continue
                seen.add(k)
                explored += 1
                q = quality(nxt, stats, cfg.weights, cfg.maint_model)
                candidates.append((q, nxt))
                if q.total < best_q.total:
                    best, best_q = nxt, q
                    log.append({"step": explored, "total": q.total,
                                "views": len(nxt.views)})
                if explored >= cfg.max_states:
                    break
            if explored >= cfg.max_states:
                break
        candidates.sort(key=lambda t: t[0].total)
        frontier = candidates[: cfg.beam_width]
    return SearchResult(best, best_q, explored, 0.0, log)


def _anneal(initial: State, stats, cfg: SearchConfig, t0: float) -> SearchResult:
    rng = random.Random(cfg.seed)
    cur, cur_q = initial, quality(initial, stats, cfg.weights, cfg.maint_model)
    best, best_q = cur, cur_q
    temp = cfg.anneal_t0 * max(cur_q.total, 1.0)
    explored = 1
    log = [{"step": 0, "total": cur_q.total, "views": len(initial.views)}]
    for step in range(cfg.anneal_steps):
        if explored >= cfg.max_states or time.monotonic() - t0 > cfg.max_seconds:
            break
        succ = _expand(cur, cfg)
        if not succ:
            break
        nxt = rng.choice(succ)
        explored += 1
        q = quality(nxt, stats, cfg.weights, cfg.maint_model)
        delta = q.total - cur_q.total
        if delta <= 0 or rng.random() < math.exp(-delta / max(temp, 1e-9)):
            cur, cur_q = nxt, q
            if cur_q.total < best_q.total:
                best, best_q = cur, cur_q
                log.append({"step": explored, "total": cur_q.total,
                            "views": len(cur.views)})
        temp *= cfg.anneal_decay
    return SearchResult(best, best_q, explored, 0.0, log)
