"""AdamW with configurable moment dtypes + global-norm clipping.

Moment dtypes are a distributed-memory knob (bf16 m / fp32 v roughly
halves optimizer HBM — recorded in §Perf for the >=20B configs).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    m_dtype: Any = jnp.float32
    v_dtype: Any = jnp.float32
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def lr_at(cfg: OptConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * cos


def init_opt_state(params, cfg: OptConfig):
    return {
        "m": jax.tree.map(lambda p: jnp.zeros(p.shape, cfg.m_dtype), params),
        "v": jax.tree.map(lambda p: jnp.zeros(p.shape, cfg.v_dtype), params),
        "step": jnp.zeros((), jnp.int32),
    }


def opt_state_shapes(param_shapes, cfg: OptConfig):
    """ShapeDtypeStruct pytree (dry-run path, no allocation)."""
    return {
        "m": jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape, cfg.m_dtype), param_shapes),
        "v": jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape, cfg.v_dtype), param_shapes),
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), gnorm


def adamw_update(params, grads, opt_state, cfg: OptConfig):
    step = opt_state["step"] + 1
    lr = lr_at(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m32 = m.astype(jnp.float32) * b1 + g32 * (1 - b1)
        v32 = v.astype(jnp.float32) * b2 + jnp.square(g32) * (1 - b2)
        update = (m32 / bc1) / (jnp.sqrt(v32 / bc2) + cfg.eps)
        update = update + cfg.weight_decay * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - lr * update
        return new_p.astype(p.dtype), m32.astype(cfg.m_dtype), v32.astype(cfg.v_dtype)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt_state["m"])
    flat_v = jax.tree.leaves(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    return new_params, {"m": new_m, "v": new_v, "step": step}, lr
