"""Training step: CE loss, remat, microbatch gradient accumulation,
mixed precision, logical-axis sharding.

`make_train_step` builds the jitted SPMD program used by launch/train.py
and by the dry-run (lowered against ShapeDtypeStructs).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp

from repro.distributed.sharding import (DEFAULT_RULES, axis_ctx,
                                        param_shardings, shard_act, spec_for)
from repro.models.model import Model
from repro.train.optimizer import (OptConfig, adamw_update,
                                   clip_by_global_norm, init_opt_state)


@dataclass(frozen=True)
class TrainConfig:
    opt: OptConfig = field(default_factory=OptConfig)
    remat: str = "full"          # none | full
    accum_steps: int = 1         # microbatch gradient accumulation
    grad_dtype: Any = jnp.float32  # bf16 = compressed gradient reduction
    z_loss: float = 0.0


def cross_entropy(logits, labels, z_loss: float = 0.0):
    lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(lp, labels[..., None], axis=-1)[..., 0]
    loss = nll.mean()
    if z_loss > 0.0:
        zl = jnp.square(jax.scipy.special.logsumexp(
            logits.astype(jnp.float32), axis=-1)).mean()
        loss = loss + z_loss * zl
    return loss


def loss_fn(model: Model, params, batch, tc: TrainConfig):
    kw = {}
    if "positions" in batch:
        kw["positions"] = batch["positions"]
    if "enc_frames" in batch:
        kw["enc_frames"] = batch["enc_frames"]
    logits = model.forward(params, tokens=batch["tokens"], remat=tc.remat, **kw)
    return cross_entropy(logits, batch["labels"], tc.z_loss)


def make_train_step(model: Model, tc: TrainConfig, mesh=None,
                    rules: dict | None = None):
    """Returns train_step(state, batch) -> (state, metrics).

    state = {"params": ..., "opt": {...}}.  When `mesh` is given, the
    function applies logical-axis constraints inside the model and the
    caller is expected to jit with matching in/out shardings.
    """
    rules = rules or DEFAULT_RULES

    def step(state, batch):
        ctx = axis_ctx(mesh, rules) if mesh is not None else _null_ctx()
        with ctx:
            params = state["params"]

            if tc.accum_steps > 1:
                def micro(carry, mb):
                    loss_i, grads_i = jax.value_and_grad(
                        lambda p: loss_fn(model, p, mb, tc))(params)
                    grads_i = jax.tree.map(
                        lambda g: g.astype(tc.grad_dtype), grads_i)
                    acc_loss, acc_g = carry
                    return (acc_loss + loss_i,
                            jax.tree.map(jnp.add, acc_g, grads_i)), None

                zero = (jnp.zeros((), jnp.float32),
                        jax.tree.map(
                            lambda p: jnp.zeros(p.shape, tc.grad_dtype), params))
                mbs = jax.tree.map(
                    lambda x: x.reshape((tc.accum_steps,
                                         x.shape[0] // tc.accum_steps) + x.shape[1:]),
                    batch)
                (loss, grads), _ = jax.lax.scan(micro, zero, mbs)
                loss = loss / tc.accum_steps
                grads = jax.tree.map(lambda g: g / tc.accum_steps, grads)
            else:
                loss, grads = jax.value_and_grad(
                    lambda p: loss_fn(model, p, batch, tc))(params)
                grads = jax.tree.map(lambda g: g.astype(tc.grad_dtype), grads)

            grads, gnorm = clip_by_global_norm(grads, tc.opt.clip_norm)
            new_params, new_opt, lr = adamw_update(
                params, grads, state["opt"], tc.opt)
            metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr}
            return {"params": new_params, "opt": new_opt}, metrics

    return step


def init_train_state(model: Model, tc: TrainConfig, key, dtype=jnp.float32):
    params = model.init(key, dtype)
    return {"params": params, "opt": init_opt_state(params, tc.opt)}


def train_state_shapes(model: Model, tc: TrainConfig, dtype=jnp.bfloat16):
    """Dry-run path: the full train state as ShapeDtypeStructs."""
    from repro.train.optimizer import opt_state_shapes

    pshapes = model.param_shapes(dtype)
    return {"params": pshapes, "opt": opt_state_shapes(pshapes, tc.opt)}


def train_state_shardings(model: Model, tc: TrainConfig, mesh, rules=None):
    rules = rules or DEFAULT_RULES
    ps = param_shardings(model.template, rules, mesh)
    return {"params": ps, "opt": {"m": ps, "v": ps,
                                  "step": jax.sharding.NamedSharding(
                                      mesh, jax.sharding.PartitionSpec())}}


def batch_shardings(mesh, batch_tree, rules=None):
    from jax.sharding import NamedSharding

    rules = rules or DEFAULT_RULES

    def for_leaf(x):
        ndim = len(x.shape)
        axes = ("batch",) + (None,) * (ndim - 1)
        return NamedSharding(mesh, spec_for(axes, rules, mesh))

    return jax.tree.map(for_leaf, batch_tree)


class _null_ctx:
    def __enter__(self):
        return self

    def __exit__(self, *a):
        return False
