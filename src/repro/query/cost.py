"""Cardinality estimation + cost model.

Feeds (i) the quality function of the view-selection search and (ii) the
static capacity planner of the JAX engine.  System-R-style independence
assumptions over the triple-store statistics.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.core.queries import CQ, Atom, Const, Var
from repro.query.plan import EquiJoin, Filter, Plan, Project, TTScan, ViewRef
from repro.rdf.triples import Statistics

# relative per-row costs (calibrated to the JAX engine's ops)
C_SCAN = 1.0
C_FILTER = 0.5
C_JOIN_BUILD = 2.0   # sort side
C_JOIN_PROBE = 1.0
C_OUT = 1.0
C_DEDUPE = 2.0


def atom_cardinality(atom: Atom, stats: Statistics) -> float:
    p = atom.p.id if isinstance(atom.p, Const) else None
    o_val = atom.o.id if isinstance(atom.o, Const) else None
    return stats.atom_card(
        s_bound=isinstance(atom.s, Const), p=p,
        o_bound=isinstance(atom.o, Const), o_val=o_val,
    )


def _var_domain(var: Var, cq: CQ, stats: Statistics) -> float:
    """Estimated #distinct values a variable ranges over (min across its
    occurrences — the most selective role wins)."""
    best = float(max(stats.n_ids, 1))
    for atom in cq.atoms:
        for pos, t in enumerate(atom.terms()):
            if t != var:
                continue
            p = atom.p.id if isinstance(atom.p, Const) else None
            if pos == 0:
                d = stats.pred_distinct_s.get(p, stats.distinct_s) if p is not None else stats.distinct_s
            elif pos == 2:
                d = stats.pred_distinct_o.get(p, stats.distinct_o) if p is not None else stats.distinct_o
            else:
                d = stats.distinct_p
            best = min(best, float(max(d, 1)))
    return best


def cq_cardinality(cq: CQ, stats: Statistics) -> float:
    """Join cardinality estimate: product of atom cards, divided by the
    domain of each join variable once per extra occurrence."""
    card = 1.0
    for a in cq.atoms:
        card *= atom_cardinality(a, stats)
    occ = cq.var_positions()
    for v, ps in occ.items():
        n_atoms = len({i for i, _ in ps})
        if n_atoms >= 2:
            card /= _var_domain(v, cq, stats) ** (n_atoms - 1)
    return max(card, 1e-3)


@dataclass
class RelInfo:
    """Cardinality + per-column distinct-value estimates for a relation."""

    rows: float
    distinct: dict[str, float]

    def dcol(self, col: str) -> float:
        return max(self.distinct.get(col, self.rows), 1.0)


@dataclass
class PlanEstimate:
    rows: float
    cost: float
    info: RelInfo
    lead_rows: float = 0.0  # pre-residual expansion of the topmost join


def _atom_col_distinct(atom: Atom, stats: Statistics, rows: float) -> dict[str, float]:
    p = atom.p.id if isinstance(atom.p, Const) else None
    out: dict[str, float] = {}
    for pos, t in enumerate(atom.terms()):
        if not isinstance(t, Var):
            continue
        if pos == 0:
            d = stats.pred_distinct_s.get(p, stats.distinct_s) if p is not None else stats.distinct_s
        elif pos == 2:
            d = stats.pred_distinct_o.get(p, stats.distinct_o) if p is not None else stats.distinct_o
        else:
            d = stats.distinct_p
        out[t.name] = min(max(float(d), 1.0), max(rows, 1.0))
    return out


def cq_rel_info(cq: CQ, stats: Statistics) -> RelInfo:
    """Extent estimate for a view CQ: rows + per-head-variable distincts."""
    rows = cq_cardinality(cq, stats)
    distinct = {
        v.name: min(_var_domain(v, cq, stats), max(rows, 1.0)) for v in cq.all_vars()
    }
    return RelInfo(rows=max(rows, 1e-3), distinct=distinct)


def estimate_plan(plan: Plan, stats: Statistics,
                  view_infos: dict[int, RelInfo]) -> PlanEstimate:
    """Bottom-up (rows, cost, distincts) estimate of a rewriting plan.

    `view_infos` maps view id -> RelInfo of the (estimated or actual)
    extent; computed once per state from the view CQs, or measured after
    materialization.
    """
    if isinstance(plan, TTScan):
        rows = atom_cardinality(plan.atom, stats)
        info = RelInfo(max(rows, 1e-3), _atom_col_distinct(plan.atom, stats, rows))
        return PlanEstimate(info.rows, C_SCAN * info.rows, info)
    if isinstance(plan, ViewRef):
        vi = view_infos[plan.view_id]
        # align distinct names to the reference schema (positional)
        names = list(vi.distinct)
        if set(names) != set(plan.schema) and len(names) == len(plan.schema):
            distinct = {c: vi.distinct[n] for c, n in zip(plan.schema, names)}
        else:
            distinct = dict(vi.distinct)
        info = RelInfo(vi.rows, distinct)
        return PlanEstimate(info.rows, C_SCAN * info.rows, info)
    if isinstance(plan, Filter):
        child = estimate_plan(plan.child, stats, view_infos)
        sel = 1.0 / child.info.dcol(plan.col)
        rows = max(child.rows * sel, 1e-3)
        distinct = {c: min(d, max(rows, 1.0)) for c, d in child.info.distinct.items()}
        distinct[plan.col] = 1.0
        return PlanEstimate(rows, child.cost + C_FILTER * child.rows,
                            RelInfo(rows, distinct))
    if isinstance(plan, EquiJoin):
        left = estimate_plan(plan.left, stats, view_infos)
        right = estimate_plan(plan.right, stats, view_infos)
        cross = left.rows * right.rows
        rows = cross
        lead_rows = cross
        if plan.pairs:
            doms = [
                max(left.info.dcol(l), right.info.dcol(r)) for l, r in plan.pairs
            ]
            lead_dom = max(doms)
            lead_rows = cross / lead_dom
            for d in doms:
                rows /= d
        rows = max(rows, 1e-3)
        lead_rows = max(lead_rows, 1e-3)
        drop = {r for _, r in plan.pairs}
        distinct: dict[str, float] = {}
        for c, d in left.info.distinct.items():
            distinct[c] = min(d, max(rows, 1.0))
        for c, d in right.info.distinct.items():
            if c not in drop:
                distinct[c] = min(d, max(rows, 1.0))
        cost = (
            left.cost + right.cost
            + C_JOIN_BUILD * right.rows + C_JOIN_PROBE * left.rows
            + C_OUT * lead_rows  # expansion happens before residual filtering
        )
        return PlanEstimate(rows, cost, RelInfo(rows, distinct), lead_rows)
    if isinstance(plan, Project):
        child = estimate_plan(plan.child, stats, view_infos)
        rows = child.rows
        if plan.dedupe:
            limit = 1.0
            for c in plan.cols:
                limit *= child.info.dcol(c)
            rows = min(rows, limit)
        distinct = {c: min(child.info.dcol(c), max(rows, 1.0)) for c in plan.cols}
        extra = C_DEDUPE * child.rows if plan.dedupe else 0.0
        return PlanEstimate(rows, child.cost + extra, RelInfo(rows, distinct))
    raise TypeError(type(plan))


# ----------------------------------------------------------------------
# DAG-wide estimation (workload compiler)
# ----------------------------------------------------------------------
def estimate_dag(dag, stats: Statistics,
                 view_infos: dict[int, RelInfo]) -> list[PlanEstimate]:
    """Bottom-up estimates over a `WorkloadDAG`, one per node, memoized
    by node id — each shared subtree is estimated exactly once, matching
    how the fused executor evaluates it.

    DAG nodes are positional (no column names), so the returned
    `RelInfo.distinct` dicts are keyed by output column *index*; the
    formulas mirror `estimate_plan` exactly.
    """
    ests: list[PlanEstimate] = []
    for node in dag.nodes:
        if node.kind == "scan":
            atom = node.spec
            rows = atom_cardinality(atom, stats)
            named = _atom_col_distinct(atom, stats, rows)
            cols = TTScan(atom).columns()
            info = RelInfo(max(rows, 1e-3),
                           {i: named[c] for i, c in enumerate(cols)})
            ests.append(PlanEstimate(info.rows, C_SCAN * info.rows, info))
        elif node.kind == "view":
            vi = view_infos[node.spec]
            vals = list(vi.distinct.values())
            if len(vals) != node.width:  # stale/missing stats: assume keys
                vals = [vi.rows] * node.width
            info = RelInfo(vi.rows, dict(enumerate(vals)))
            ests.append(PlanEstimate(info.rows, C_SCAN * info.rows, info))
        elif node.kind == "filter":
            child = ests[node.child_ids[0]]
            ci, _value = node.spec
            rows = max(child.rows / child.info.dcol(ci), 1e-3)
            distinct = {c: min(d, max(rows, 1.0))
                        for c, d in child.info.distinct.items()}
            distinct[ci] = 1.0
            ests.append(PlanEstimate(rows, child.cost + C_FILTER * child.rows,
                                     RelInfo(rows, distinct)))
        elif node.kind == "join":
            left = ests[node.child_ids[0]]
            right = ests[node.child_ids[1]]
            pairs = node.spec
            doms = [max(left.info.dcol(l), right.info.dcol(r))
                    for l, r in pairs]
            cross = left.rows * right.rows
            rows = cross
            for d in doms:
                rows /= d
            rows = max(rows, 1e-3)
            lead_rows = max(cross / max(doms), 1e-3)
            lw = dag.nodes[node.child_ids[0]].width
            rw = dag.nodes[node.child_ids[1]].width
            drop = {r for _, r in pairs}
            distinct: dict = {
                i: min(left.info.dcol(i), max(rows, 1.0)) for i in range(lw)
            }
            out = lw
            for j in range(rw):
                if j not in drop:
                    distinct[out] = min(right.info.dcol(j), max(rows, 1.0))
                    out += 1
            cost = (left.cost + right.cost
                    + C_JOIN_BUILD * right.rows + C_JOIN_PROBE * left.rows
                    + C_OUT * lead_rows)
            ests.append(PlanEstimate(rows, cost, RelInfo(rows, distinct),
                                     lead_rows))
        elif node.kind == "project":
            child = ests[node.child_ids[0]]
            idxs, dedupe = node.spec
            rows = child.rows
            if dedupe:
                limit = 1.0
                for c in idxs:
                    limit *= child.info.dcol(c)
                rows = min(rows, limit)
            distinct = {
                i: min(child.info.dcol(src), max(rows, 1.0))
                for i, src in enumerate(idxs)
            }
            extra = C_DEDUPE * child.rows if dedupe else 0.0
            ests.append(PlanEstimate(rows, child.cost + extra,
                                     RelInfo(rows, distinct)))
        else:
            raise TypeError(node.kind)
    return ests


def capacity_for(rows_estimate: float, safety: float = 4.0, floor: int = 128,
                 ceil: int = 1 << 22) -> int:
    """Static buffer capacity for the JAX engine: next power of two above
    safety * estimate (the paper's statistics reused for shape planning)."""
    import math

    target = max(float(rows_estimate) * safety, float(floor))
    cap = 1 << max(int(math.ceil(math.log2(target))), 0)
    return int(min(max(cap, floor), ceil))


def promotion_chain(cap: int, ceil: int = 1 << 22,
                    max_steps: int = 64) -> list[int]:
    """The full capacity-class ladder from `cap` (exclusive) to the
    ceiling, as the adaptive driver would climb it one overflow at a
    time.  Statically bounds the overflow→promote→recompile cycle: the
    chain must be strictly increasing and terminate at the ceiling
    within `max_steps`, else the promotion logic itself is broken and
    the driver would recompile forever.  Raises InvariantViolation on an
    unbounded or non-monotonic chain (the capacity analyzer also reports
    this as a finding)."""
    from repro.errors import InvariantViolation

    chain: list[int] = []
    cur = cap
    for _ in range(max_steps):
        nxt = promote_capacity(cur, ceil)
        if nxt <= cur:
            if cur < ceil:
                raise InvariantViolation(
                    f"promotion stalled at {cur} below the ceiling {ceil}")
            return chain
        chain.append(nxt)
        cur = nxt
    raise InvariantViolation(
        f"promotion chain from {cap} did not reach the ceiling {ceil} "
        f"within {max_steps} steps")


def promote_capacity(cap: int, ceil: int = 1 << 22) -> int:
    """Next capacity class above `cap` (classes are powers of two, so
    promotion doubles).  Returns `cap` unchanged once the ceiling is
    reached — callers treat a no-op promotion as 'cannot grow further'.
    The bucketed executor promotes a whole shape bucket at a time, so
    every member node of the bucket moves to the new class together and
    the bucket's compiled body stays shared."""
    return int(min(max(cap * 2, 2), ceil))
