"""Shape-bucketed lowering of a `WorkloadDAG`: one scanned body per bucket.

The unrolled fused executor (`compile_workload`) traces one closure per
DAG node, so XLA graph size — and compile time — grows linearly with the
workload.  At 1000+ members that is the wall.  This module applies the
scan-over-layers idiom (levanter's `Stacked`, SNIPPETS.md Snippet 1) to
query plans: nodes are grouped into *shape buckets* by

    (topological wave, operator kind, structural signature, capacity class)

and each bucket executes as ONE `lax.scan` over its members' stacked
operands.  The per-element constants (scan prefix/residual bindings,
filter values) become data; the structure (column positions, join pairs,
buffer capacities) stays static, so XLA traces and compiles each bucket
body exactly once regardless of how many workload members share it.
Compile time therefore scales with the number of *distinct shapes* in
the workload, not with the number of queries.

Bucket bodies are compiled ahead-of-time (`jax.jit(...).lower().compile()`)
through a process-global `CompileCache` keyed by (kind, static spec,
operand shapes).  The cache persists across program rebuilds — a
`TuningSession.retune()+apply()` hot swap whose new DAG reuses old shapes
pays zero cold compiles on the serving path — and it gives the adaptive
overflow driver bucket-scoped recompiles: promoting one bucket to the
next capacity class invalidates only that bucket's body (plus any
consumer whose operand shape actually changed); every other body is a
cache hit.

Capacity classes are powers of two (`cost.capacity_for` /
`cost.promote_capacity`).  Consumers pad child buffers up to their
bucket's per-slot maximum capacity (padded rows are `-1`-scrubbed and
sit beyond the valid count, so operators never see them), which keeps a
bucket batchable even after one producer bucket has been promoted past
its siblings.
"""
from __future__ import annotations

import time
from collections import OrderedDict
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.query import cost as cost_mod
from repro.query import engine as E
from repro.query.dag import WorkloadDAG

CAP_CEIL = 1 << 22

# Default LRU bound of the process-global compile cache.  A long-lived
# TuningSession.retune() loop churns through bucket shapes; without a
# bound every shape ever compiled stays resident (XLA executables hold
# device memory) for the life of the process.
DEFAULT_CACHE_ENTRIES = 512


# ----------------------------------------------------------------------
# persistent compile cache
# ----------------------------------------------------------------------
class CompileCache:
    """Process-global LRU cache of AOT-compiled bucket bodies.

    Keyed by (kind, static signature, operand shape/dtype tuple): the
    key pins everything that affects the traced program, so an entry is
    valid for any executor in the process — rebuilt programs after a
    view hot swap reuse every body whose shape survived.

    Bounded to `max_entries` (LRU eviction): long-lived retune() loops
    keep only their working set of shapes resident instead of every
    shape ever compiled.  Evictions surface in `stats()` and through
    executor telemetry; an evicted body is simply a future cache miss.
    """

    def __init__(self, max_entries: int = DEFAULT_CACHE_ENTRIES) -> None:
        if max_entries < 1:
            raise ValueError(f"max_entries must be >= 1, got {max_entries}")
        self.max_entries = max_entries
        self.entries: OrderedDict = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.compile_seconds = 0.0

    def get(self, key, build_fn, arg_specs):
        """Return (compiled, cached, seconds): `compiled` is an AOT
        executable accepting concrete arrays of `arg_specs` shapes."""
        ent = self.entries.get(key)
        if ent is not None:
            self.hits += 1
            self.entries.move_to_end(key)  # most-recently used
            return ent, True, 0.0
        t0 = time.perf_counter()
        compiled = jax.jit(build_fn()).lower(*arg_specs).compile()
        dt = time.perf_counter() - t0
        self.entries[key] = compiled
        self.misses += 1
        self.compile_seconds += dt
        self._evict()
        return compiled, False, dt

    def _evict(self) -> None:
        while len(self.entries) > self.max_entries:
            self.entries.popitem(last=False)  # least-recently used
            self.evictions += 1

    def resize(self, max_entries: int) -> None:
        """Change the LRU bound in place (evicting immediately if the
        cache already exceeds the new bound)."""
        if max_entries < 1:
            raise ValueError(f"max_entries must be >= 1, got {max_entries}")
        self.max_entries = max_entries
        self._evict()

    def clear(self) -> None:
        self.entries.clear()
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.compile_seconds = 0.0

    def stats(self) -> dict:
        return {"entries": len(self.entries),
                "max_entries": self.max_entries,
                "hits": self.hits,
                "misses": self.misses,
                "evictions": self.evictions,
                "compile_seconds": self.compile_seconds}


_CACHE = CompileCache()


def compile_cache() -> CompileCache:
    return _CACHE


def clear_compile_cache() -> None:
    """Drop every cached bucket body (benchmarks measuring cold-compile
    scaling call this between sweep points)."""
    _CACHE.clear()


# ----------------------------------------------------------------------
# bucket planning
# ----------------------------------------------------------------------
@dataclass
class Bucket:
    """One shape bucket: members share kind, structural signature and
    capacity class, and sit on the same topological wave (so no member
    depends on another — the batch is embarrassingly parallel and safe
    to drive with one `lax.scan`)."""

    kind: str
    wave: int
    static: tuple                 # structural signature (positions only)
    cap: int                      # output capacity class (scan/join; 0 else)
    node_ids: list[int] = field(default_factory=list)
    promotions: int = 0
    # scan buckets: per-member constants, stacked + uploaded at build time
    pvals: jax.Array | None = None
    rvals: jax.Array | None = None

    @property
    def label(self) -> str:
        return f"w{self.wave}:{self.kind}:cap{self.cap}:n{len(self.node_ids)}"


def node_waves(dag: WorkloadDAG) -> list[int]:
    """Topological wave per node: leaves at 0, inner nodes one past
    their deepest child.  Children always sit on strictly lower waves,
    so same-wave nodes can never depend on each other."""
    waves: list[int] = []
    for node in dag.nodes:
        if node.child_ids:
            waves.append(1 + max(waves[c] for c in node.child_ids))
        else:
            waves.append(0)
    return waves


def plan_buckets(dag: WorkloadDAG, caps: list[int], scan_specs: dict,
                 join_specs: dict) -> tuple[list[Bucket], dict[int, Bucket]]:
    """Group every non-view node into shape buckets.

    `caps` holds the planned output capacity class per node (scan/join;
    unused entries 0).  `scan_specs[nid]` / `join_specs[nid]` hold the
    static lowering parameters produced by `BucketedProgram`.  Returns
    (buckets in execution order, node id -> bucket).
    """
    waves = node_waves(dag)
    by_key: dict[tuple, Bucket] = {}
    node_bucket: dict[int, Bucket] = {}
    for node in dag.nodes:
        if node.kind == "view":
            continue
        if node.kind == "scan":
            idx_name, prefix, residual, takes, self_eq = scan_specs[node.id]
            static = ("scan", idx_name, tuple(c for c, _ in prefix),
                      tuple(c for c, _ in residual), takes, self_eq)
            cap = caps[node.id]
        elif node.kind == "filter":
            ci, _value = node.spec
            static = ("filter", ci, node.width)
            cap = 0
        elif node.kind == "join":
            lcol, rcol, residual, keep_right = join_specs[node.id]
            lw = dag.nodes[node.child_ids[0]].width
            rw = dag.nodes[node.child_ids[1]].width
            static = ("join", lcol, rcol, residual, keep_right, lw, rw)
            cap = caps[node.id]
        elif node.kind == "project":
            idxs, dedupe = node.spec
            cw = dag.nodes[node.child_ids[0]].width
            static = ("project", idxs, dedupe, cw)
            cap = 0
        else:
            raise TypeError(node.kind)
        key = (waves[node.id], static, cap)
        bucket = by_key.get(key)
        if bucket is None:
            bucket = Bucket(kind=node.kind, wave=waves[node.id],
                            static=static, cap=cap)
            by_key[key] = bucket
        bucket.node_ids.append(node.id)
        node_bucket[node.id] = bucket
    order = sorted(by_key.values(),
                   key=lambda b: (b.wave, min(b.node_ids)))
    return order, node_bucket


# ----------------------------------------------------------------------
# bucket bodies (built from the cache key alone — pure shape functions)
# ----------------------------------------------------------------------
def _scan_body(static, cap):
    _, _idx_name, prefix_cols, residual_cols, takes, self_eq = static

    def fn(index_data, pvals, rvals):
        def step(carry, xs):
            pv, rv = xs
            prefix = tuple((c, pv[i]) for i, c in enumerate(prefix_cols))
            residual = tuple((c, rv[i]) for i, c in enumerate(residual_cols))
            return carry, E.scan_pattern(index_data, prefix, residual,
                                         takes, self_eq, cap)

        _, out = lax.scan(step, None, (pvals, rvals))
        return out

    return fn


def _filter_body(static):
    _, ci, _width = static

    def fn(cdata, cn, covf, vals):
        def step(carry, xs):
            d, n, o, v = xs
            return carry, E.filter_eq(E.PRel(d, n, o), ci, v)

        _, out = lax.scan(step, None, (cdata, cn, covf, vals))
        return out

    return fn


def _join_body(static, cap, use_pallas):
    _, lcol, rcol, residual, keep_right, _lw, _rw = static

    def fn(ldata, ln, lovf, rdata, rn, rovf):
        def step(carry, xs):
            ld, ln_, lo, rd, rn_, ro = xs
            return carry, E.join(E.PRel(ld, ln_, lo), E.PRel(rd, rn_, ro),
                                 lcol, rcol, residual, keep_right, cap,
                                 use_pallas=use_pallas)

        _, out = lax.scan(step, None, (ldata, ln, lovf, rdata, rn, rovf))
        return out

    return fn


def _project_body(static):
    _, idxs, dedupe, _cw = static

    def fn(cdata, cn, covf):
        def step(carry, xs):
            d, n, o = xs
            return carry, E.project(E.PRel(d, n, o), idxs, dedupe)

        _, out = lax.scan(step, None, (cdata, cn, covf))
        return out

    return fn


def body_builder(bucket: Bucket, use_pallas: bool = False):
    """The traced body function for one bucket, built from its static
    signature alone — the same builder `_run_bucket` compiles through
    the cache, exposed so the jaxpr lint (`repro.analysis.jaxpr_lint`)
    can trace every body abstractly without executing anything."""
    if bucket.kind == "scan":
        return _scan_body(bucket.static, bucket.cap)
    if bucket.kind == "filter":
        return _filter_body(bucket.static)
    if bucket.kind == "join":
        return _join_body(bucket.static, bucket.cap, use_pallas)
    if bucket.kind == "project":
        return _project_body(bucket.static)
    raise TypeError(bucket.kind)


def _specs_of(args) -> tuple:
    return tuple(jax.ShapeDtypeStruct(a.shape, a.dtype) for a in args)


def _shape_key(specs) -> tuple:
    return tuple((s.shape, str(s.dtype)) for s in specs)


# ----------------------------------------------------------------------
# capacity planning (shared with the static capacity analyzer)
# ----------------------------------------------------------------------
def plan_capacities(dag: WorkloadDAG, stats, view_infos, *,
                    safety: float = 4.0, cap_planner=None, ests=None,
                    carry_caps: dict | None = None, content_keys=None):
    """Plan per-node buffer capacities and static lowering specs.

    Returns (caps, scan_specs, join_specs, demands):
      caps:    planned output capacity class per node (0 where unsized),
      scan_specs[nid] = (idx_name, prefix, residual, takes, self_eq),
      join_specs[nid] = (lcol, rcol, residual, keep_right),
      demands: estimated row demand each sized buffer must absorb — the
               quantity `capacity_for` was fed, kept so the static
               capacity analyzer can re-check headroom without
               re-deriving the sizing inputs.
    """
    if ests is None:
        ests = cost_mod.estimate_dag(dag, stats, view_infos)
    if content_keys is None and carry_caps:
        content_keys = dag.content_keys()

    def _cap(node, rows: float) -> int:
        if cap_planner is not None:
            planned = int(cap_planner(node.plan, rows))
        else:
            planned = cost_mod.capacity_for(rows, safety=safety)
        if carry_caps:
            planned = max(planned,
                          carry_caps.get(content_keys[node.id], 0))
        return planned

    caps = [0] * len(dag.nodes)
    demands = [0.0] * len(dag.nodes)
    scan_specs: dict[int, tuple] = {}
    join_specs: dict[int, tuple] = {}
    for node in dag.nodes:
        if node.kind == "scan":
            idx_name, prefix, residual, takes, self_eq, _sorted = \
                E.atom_scan_spec(node.spec)
            scan_specs[node.id] = (idx_name, prefix, residual, takes,
                                   self_eq)
            demands[node.id] = E.range_cardinality(node.spec, prefix, stats)
            caps[node.id] = _cap(node, demands[node.id])
        elif node.kind == "join":
            lid, rid = node.child_ids
            pairs = node.spec
            doms = [max(ests[lid].info.dcol(l), ests[rid].info.dcol(r))
                    for l, r in pairs]
            lead_k = max(range(len(doms)), key=lambda i: doms[i])
            lcol, rcol = pairs[lead_k]
            residual = tuple(p for k, p in enumerate(pairs)
                             if k != lead_k)
            drop = {r for _, r in pairs}
            keep_right = tuple(i for i in range(dag.nodes[rid].width)
                               if i not in drop)
            join_specs[node.id] = (lcol, rcol, residual, keep_right)
            demands[node.id] = max(
                ests[lid].rows * ests[rid].rows / doms[lead_k], 1e-3)
            caps[node.id] = _cap(node, demands[node.id])
    return caps, scan_specs, join_specs, demands


# ----------------------------------------------------------------------
# the bucketed program
# ----------------------------------------------------------------------
class BucketedProgram:
    """Executable lowering of a `WorkloadDAG` as shape buckets.

    `execute(tt, views)` runs every bucket in wave order — one AOT
    compiled `lax.scan` dispatch per bucket — and returns
    ({root name: PRel}, own_overflow np (n_nodes,)), the same contract
    as the unrolled program plus host-side overflow attribution.

    `promote(node_ids)` moves the offending nodes' buckets to the next
    capacity class; only those buckets' bodies (and consumers whose
    operand shapes changed) recompile on the next execute — everything
    else hits the persistent cache.
    """

    def __init__(self, dag: WorkloadDAG, stats, view_infos, *,
                 safety: float = 4.0, use_pallas: bool = False,
                 cap_planner=None, ests=None,
                 carry_caps: dict | None = None):
        self.dag = dag
        self.stats = stats
        self.use_pallas = use_pallas
        if ests is None:
            ests = cost_mod.estimate_dag(dag, stats, view_infos)
        self.ests = ests
        self.content_keys = dag.content_keys()
        caps, scan_specs, join_specs, demands = plan_capacities(
            dag, stats, view_infos, safety=safety, cap_planner=cap_planner,
            ests=ests, carry_caps=carry_caps,
            content_keys=self.content_keys)
        self.caps = caps
        self.demands = demands
        self.buckets, self.node_bucket = plan_buckets(dag, caps, scan_specs,
                                                      join_specs)
        # stack per-member scan constants once (they never change)
        for b in self.buckets:
            if b.kind == "scan":
                pv, rv = [], []
                for nid in b.node_ids:
                    _, prefix, residual, _, _ = scan_specs[nid]
                    pv.append([v for _, v in prefix])
                    rv.append([v for _, v in residual])
                # device-resident once: re-uploading per run would put a
                # host transfer on every dispatch of the hot path
                b.pvals = jnp.asarray(np.asarray(pv, np.int32).reshape(
                    len(b.node_ids), -1))
                b.rvals = jnp.asarray(np.asarray(rv, np.int32).reshape(
                    len(b.node_ids), -1))
        # telemetry (per program; the cache itself is process-global)
        self.cache_hits = 0
        self.cache_misses = 0
        self.compile_seconds = 0.0
        self.compile_log: list[dict] = []  # one entry per body compile

    # ------------------------------------------------------------------
    @property
    def n_buckets(self) -> int:
        return len(self.buckets)

    def signatures(self) -> set[tuple]:
        return {(b.static, b.cap) for b in self.buckets}

    # ------------------------------------------------------------------
    def promote(self, node_ids) -> list[tuple[int, int, int]]:
        """Promote the buckets containing `node_ids` to the next
        capacity class.  Returns [(nid, old_cap, new_cap)] for every
        member of every promoted bucket (the whole bucket moves, so the
        batch stays shape-uniform); empty when every offending bucket is
        already at the capacity ceiling."""
        grown: list[tuple[int, int, int]] = []
        seen: set[int] = set()
        for nid in node_ids:
            bucket = self.node_bucket.get(nid)
            if bucket is None or bucket.cap == 0 or id(bucket) in seen:
                continue
            seen.add(id(bucket))
            new = cost_mod.promote_capacity(bucket.cap, CAP_CEIL)
            if new <= bucket.cap:
                continue
            old = bucket.cap
            bucket.cap = new
            bucket.promotions += 1
            for m in bucket.node_ids:
                self.caps[m] = new
                grown.append((m, old, new))
        return grown

    # ------------------------------------------------------------------
    # operand assembly
    # ------------------------------------------------------------------
    @staticmethod
    def _pad_rows(data, cap: int):
        """Pad the row axis (second-to-last) up to `cap` with -1 rows;
        padded rows sit beyond the valid count, matching the scrubbed
        tail every operator already ignores."""
        have = data.shape[-2]
        if have == cap:
            return data
        widths = [(0, 0)] * data.ndim
        widths[-2] = (0, cap - have)
        return jnp.pad(data, widths, constant_values=-1)

    def _gather_slot(self, res, child_ids, cap: int):
        """Stack one operand slot for a bucket: the children's PRels,
        padded to `cap` rows each.  Consecutive children living in the
        same producer bucket collapse into one gather, so the dispatch
        count scales with producer-bucket runs, not members."""
        parts_d, parts_n, parts_o = [], [], []
        i = 0
        while i < len(child_ids):
            entry = res[child_ids[i]]
            if entry[0] is None:  # single PRel (view node)
                rel = entry[1]
                parts_d.append(self._pad_rows(rel.data[None], cap))
                parts_n.append(rel.n[None])
                parts_o.append(rel.overflow[None])
                i += 1
                continue
            producer = entry[0]
            idxs = [entry[1]]
            j = i + 1
            while j < len(child_ids) and res[child_ids[j]][0] is producer:
                idxs.append(res[child_ids[j]][1])
                j += 1
            take = jnp.asarray(np.asarray(idxs, np.int32))
            parts_d.append(self._pad_rows(producer.data[take], cap))
            parts_n.append(producer.n[take])
            parts_o.append(producer.overflow[take])
            i = j
        if len(parts_d) == 1:
            return parts_d[0], parts_n[0], parts_o[0]
        return (jnp.concatenate(parts_d), jnp.concatenate(parts_n),
                jnp.concatenate(parts_o))

    # ------------------------------------------------------------------
    def _run_bucket(self, bucket: Bucket, tt, res, eff_cap):
        dag = self.dag
        build = lambda: body_builder(bucket, self.use_pallas)
        if bucket.kind == "scan":
            _, idx_name = bucket.static[0], bucket.static[1]
            args = (tt[idx_name], bucket.pvals, bucket.rvals)
            out_cap = bucket.cap
        elif bucket.kind == "filter":
            kids = [dag.nodes[nid].child_ids[0] for nid in bucket.node_ids]
            cap = max(eff_cap[c] for c in kids)
            cd, cn, co = self._gather_slot(res, kids, cap)
            vals = jnp.asarray(np.asarray(
                [dag.nodes[nid].spec[1] for nid in bucket.node_ids],
                np.int32))
            args = (cd, cn, co, vals)
            out_cap = cap
        elif bucket.kind == "join":
            lkids = [dag.nodes[nid].child_ids[0] for nid in bucket.node_ids]
            rkids = [dag.nodes[nid].child_ids[1] for nid in bucket.node_ids]
            lcap = max(eff_cap[c] for c in lkids)
            rcap = max(eff_cap[c] for c in rkids)
            ld, ln, lo = self._gather_slot(res, lkids, lcap)
            rd, rn, ro = self._gather_slot(res, rkids, rcap)
            args = (ld, ln, lo, rd, rn, ro)
            out_cap = bucket.cap
        elif bucket.kind == "project":
            kids = [dag.nodes[nid].child_ids[0] for nid in bucket.node_ids]
            cap = max(eff_cap[c] for c in kids)
            cd, cn, co = self._gather_slot(res, kids, cap)
            args = (cd, cn, co)
            out_cap = cap
        else:
            raise TypeError(bucket.kind)

        specs = _specs_of(args)
        key = self.cache_key(bucket, specs)
        compiled, cached, dt = _CACHE.get(key, build, specs)
        if cached:
            self.cache_hits += 1
        else:
            self.cache_misses += 1
            self.compile_seconds += dt
            self.compile_log.append({
                "bucket": bucket.label, "kind": bucket.kind,
                "wave": bucket.wave, "cap": bucket.cap,
                "batch": len(bucket.node_ids), "seconds": dt,
            })
        out = compiled(*args)
        for i, nid in enumerate(bucket.node_ids):
            res[nid] = (out, i)
            eff_cap[nid] = out_cap
        return out

    # ------------------------------------------------------------------
    def execute(self, tt, views):
        """Run every bucket; returns ({root: PRel}, own_overflow np)."""
        dag = self.dag
        n = len(dag.nodes)
        res: list = [None] * n
        eff_cap: list[int] = [0] * n
        view_nids: list[int] = []
        for node in dag.nodes:
            if node.kind == "view":
                rel = views[node.spec]
                res[node.id] = (None, rel)
                eff_cap[node.id] = rel.cap
                view_nids.append(node.id)
        outs = [self._run_bucket(b, tt, res, eff_cap) for b in self.buckets]

        # host-side overflow attribution: one transfer for all flags
        flat = jax.device_get(
            [o.overflow for o in outs]
            + [res[nid][1].overflow for nid in view_nids])
        raw = np.zeros(n, dtype=bool)
        for b, ovf in zip(self.buckets, flat[: len(outs)]):
            raw[np.asarray(b.node_ids)] = np.asarray(ovf)
        for nid, ovf in zip(view_nids, flat[len(outs):]):
            raw[nid] = bool(ovf)
        own = raw.copy()
        for node in dag.nodes:
            if node.kind == "view":
                own[node.id] = False
            elif node.child_ids and raw[list(node.child_ids)].any():
                own[node.id] = False  # inherited, not this node's buffer

        roots: dict[str, E.PRel] = {}
        for name, nid in dag.roots.items():
            entry = res[nid]
            if entry[0] is None:
                roots[name] = entry[1]
            else:
                out, i = entry
                roots[name] = E.PRel(out.data[i], out.n[i], out.overflow[i])
        return roots, own

    # ------------------------------------------------------------------
    # static views of the program (no execution) — jaxpr lint hooks
    # ------------------------------------------------------------------
    def static_eff_caps(self, view_caps: dict[int, int] | None = None
                        ) -> list[int]:
        """Effective buffer capacity per node, computed exactly like
        `execute` propagates it but without touching the device: views
        take `view_caps[vid]` (falling back to a capacity class planned
        from the estimated extent rows), scans/joins their bucket's
        capacity class, filters/projects the max of their child caps."""
        view_caps = view_caps or {}
        eff: list[int] = [0] * len(self.dag.nodes)
        for node in self.dag.nodes:
            if node.kind == "view":
                eff[node.id] = view_caps.get(
                    node.spec,
                    cost_mod.capacity_for(self.ests[node.id].rows,
                                          safety=1.0))
        for bucket in self.buckets:
            for nid in bucket.node_ids:
                node = self.dag.nodes[nid]
                if bucket.kind in ("scan", "join"):
                    eff[nid] = bucket.cap
                else:  # filter/project pass through their child's cap
                    eff[nid] = max(eff[c] for c in node.child_ids)
        return eff

    def abstract_args(self, bucket: Bucket, n_tt: int,
                      eff_cap: list[int]) -> tuple:
        """ShapeDtypeStructs of the operands `_run_bucket` would stack
        for this bucket — enough to trace the body with `make_jaxpr` /
        `eval_shape` without any device data.  `n_tt` is the triple
        count (scan buckets read one sorted (n_tt, 3) index)."""
        dag = self.dag
        B = len(bucket.node_ids)
        i32, b1 = np.dtype(np.int32), np.dtype(bool)

        def slot(kids, cap: int, width: int) -> tuple:
            return (jax.ShapeDtypeStruct((B, cap, width), i32),
                    jax.ShapeDtypeStruct((B,), i32),
                    jax.ShapeDtypeStruct((B,), b1))

        if bucket.kind == "scan":
            pw = 0 if bucket.pvals is None else bucket.pvals.shape[1]
            rw = 0 if bucket.rvals is None else bucket.rvals.shape[1]
            return (jax.ShapeDtypeStruct((n_tt, 3), i32),
                    jax.ShapeDtypeStruct((B, pw), i32),
                    jax.ShapeDtypeStruct((B, rw), i32))
        if bucket.kind == "filter":
            kids = [dag.nodes[nid].child_ids[0] for nid in bucket.node_ids]
            cap = max(eff_cap[c] for c in kids)
            _, _ci, width = bucket.static
            return slot(kids, cap, width) + (
                jax.ShapeDtypeStruct((B,), i32),)
        if bucket.kind == "join":
            lkids = [dag.nodes[nid].child_ids[0] for nid in bucket.node_ids]
            rkids = [dag.nodes[nid].child_ids[1] for nid in bucket.node_ids]
            lcap = max(eff_cap[c] for c in lkids)
            rcap = max(eff_cap[c] for c in rkids)
            lw, rw = bucket.static[5], bucket.static[6]
            return slot(lkids, lcap, lw) + slot(rkids, rcap, rw)
        if bucket.kind == "project":
            kids = [dag.nodes[nid].child_ids[0] for nid in bucket.node_ids]
            cap = max(eff_cap[c] for c in kids)
            cw = bucket.static[3]
            return slot(kids, cap, cw)
        raise TypeError(bucket.kind)

    def cache_key(self, bucket: Bucket, specs) -> tuple:
        """The persistent-cache key `_run_bucket` would use for this
        bucket with operands of `specs` shapes (lint checks hashability
        and cross-bucket collision-freedom of exactly these keys)."""
        return (bucket.static, bucket.cap, self.use_pallas,
                _shape_key(specs))

    # ------------------------------------------------------------------
    def telemetry(self) -> dict:
        return {
            "buckets": self.n_buckets,
            "bucket_signatures": len(self.signatures()),
            "bucket_compiles": self.cache_misses,
            "bucket_cache_hits": self.cache_hits,
            "bucket_cache_misses": self.cache_misses,
            "bucket_compile_seconds": self.compile_seconds,
            "bucket_compile_log": list(self.compile_log),
            "bucket_promotions": sum(b.promotions for b in self.buckets),
        }
