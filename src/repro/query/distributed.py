"""Distributed query evaluation: sharded TT + views, repartition joins.

The paper evaluated rewritings inside a single PostgreSQL node; at pod
scale the triple table and every materialized view are row-sharded by
hash over the `data` mesh axis.  A rewriting becomes one SPMD program
(`query_step`) built from:

  * local scans/filters (selections are row-local),
  * hash-repartition equi-joins: both sides are bucketed by
    `key % ndev` into fixed-capacity per-destination buckets and
    exchanged with `lax.all_to_all`, then joined locally — the classic
    distributed hash join on jax.lax collectives,
  * co-partition elision: when both inputs are already partitioned by
    the join column (tracked statically through the plan), the
    all_to_all is skipped — this is the main collective optimization
    knob measured in EXPERIMENTS.md §Perf.

Buckets make the exchange static-shaped; overflow latches like the local
engine.  The final relation stays sharded; `gather_result` collects it.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.query import cost as cost_mod
from repro.query import engine as E
from repro.query.engine import INVALID, PRel, compact
from repro.query.plan import EquiJoin, Filter, Plan, Project, TTScan, ViewRef


# ----------------------------------------------------------------------
# repartition
# ----------------------------------------------------------------------
def bucket_by_dest(rel: PRel, key_col: int, ndev: int, bucket_cap: int) -> tuple[jax.Array, jax.Array]:
    """Pack rows into an (ndev, bucket_cap, w) send buffer by key % ndev.

    Returns (buffer, overflow).  Empty slots are -1."""
    w = rel.width
    valid = jnp.arange(rel.cap, dtype=jnp.int32) < rel.n
    dest = jnp.where(valid, rel.data[:, key_col] % ndev, ndev)
    order = jnp.argsort(dest)  # stable; invalid rows sort last
    sorted_dest = dest[order]
    sorted_rows = rel.data[order]
    # rank of each row within its destination group
    group_start = jnp.searchsorted(sorted_dest, sorted_dest, side="left")
    rank = jnp.arange(rel.cap, dtype=jnp.int32) - group_start.astype(jnp.int32)
    ok = (sorted_dest < ndev) & (rank < bucket_cap)
    slot = jnp.where(ok, sorted_dest * bucket_cap + rank, ndev * bucket_cap)
    buf = jnp.full((ndev * bucket_cap + 1, w), -1, dtype=jnp.int32)
    buf = buf.at[slot].set(sorted_rows)
    overflow = rel.overflow | jnp.any((sorted_dest < ndev) & (rank >= bucket_cap))
    return buf[:-1].reshape(ndev, bucket_cap, w), overflow


def repartition(rel: PRel, key_col: int, axis, ndev: int,
                bucket_cap: int) -> PRel:
    """Exchange rows so that equal keys land on the same device.

    `axis` may be one mesh axis name or a tuple (the partition space is
    the flattened product, e.g. ("data","model") = the whole pod)."""
    buf, overflow = bucket_by_dest(rel, key_col, ndev, bucket_cap)
    recv = jax.lax.all_to_all(buf, axis, split_axis=0, concat_axis=0)
    data = recv.reshape(ndev * bucket_cap, rel.width)
    mask = data[:, 0] != INVALID
    out = compact(data, mask, overflow)
    # overflow is device-local; make the flag global so every shard agrees
    return PRel(out.data, out.n, jax.lax.pmax(out.overflow.astype(jnp.int32), axis) > 0)


# ----------------------------------------------------------------------
# distributed plan compiler
# ----------------------------------------------------------------------
def build_distributed_executor(plan: Plan, stats, view_infos, mesh,
                               axis="data", safety: float = 4.0,
                               partition_cols: dict[int, str] | None = None,
                               final_gather: bool = False):
    """Compile `plan` into an SPMD function over `mesh` axis `axis`.

    `partition_cols` maps view_id -> column name the extent is hash-
    partitioned by (enables co-partition elision; the TT is partitioned
    by subject).  Per-device capacities are the global estimates divided
    by ndev times a skew factor.

    Returns `fn(tt_shards, view_shards) -> PRel` wrapped in shard_map;
    inputs are globally-sharded arrays, output is the sharded result.
    """
    import os

    ndev = int(np.prod([mesh.shape[a] for a in (axis if isinstance(axis, tuple) else (axis,))]))
    partition_cols = partition_cols or {}
    SKEW = float(os.environ.get("REPRO_QUERY_SKEW", "4.0"))

    def cap_of(rows_global: float) -> int:
        per_dev = rows_global / ndev * SKEW
        return cost_mod.capacity_for(per_dev, safety=safety)

    def build(node: Plan, prefer_sorted: str | None = None
              ) -> tuple[Callable, tuple[str, ...], object, str | None, str | None]:
        """returns (fn, cols, info, partitioned_by|None, sorted_by|None)"""
        est = cost_mod.estimate_plan(node, stats, view_infos)
        if isinstance(node, TTScan):
            idx_name, prefix, residual, takes, self_eq, sorted_by = \
                E.atom_scan_spec(node.atom, prefer_sorted)
            cap = cap_of(E.range_cardinality(node.atom, prefix, stats))
            cols = node.columns()
            # the TT is hash(s)-partitioned: a scan output inherits the
            # subject partitioning iff it keeps the subject column
            from repro.core.queries import Var
            part = node.atom.s.name if isinstance(node.atom.s, Var) else None

            def run(tt, views, _f=functools.partial(
                    E.scan_pattern, prefix=prefix, residual=residual,
                    takes=takes, self_eq=self_eq, cap=cap), _idx=idx_name):
                return _f(tt[_idx])

            return run, cols, est.info, part, sorted_by
        if isinstance(node, ViewRef):
            part_src = partition_cols.get(node.view_id)
            # positional alignment: view head name -> plan-local name
            part = None
            if part_src is not None and part_src in node.schema:
                part = part_src

            def run(tt, views, _vid=node.view_id):
                return views[_vid]

            return run, node.schema, est.info, part, None
        if isinstance(node, Filter):
            child_fn, cols, _, part, sorted_by = build(node.child, prefer_sorted)
            ci = cols.index(node.col)

            def run(tt, views, _fn=child_fn, _ci=ci, _v=node.value):
                return E.filter_eq(_fn(tt, views), _ci, _v)

            return run, cols, est.info, part, sorted_by
        if isinstance(node, EquiJoin):
            if not node.pairs:
                raise NotImplementedError("cartesian products not supported distributed")
            l_est = cost_mod.estimate_plan(node.left, stats, view_infos)
            r_est = cost_mod.estimate_plan(node.right, stats, view_infos)
            doms = [max(l_est.info.dcol(l), r_est.info.dcol(r))
                    for l, r in node.pairs]
            lead_k = max(range(len(doms)), key=lambda i: doms[i])
            lead_pair = node.pairs[lead_k]
            lf, lcols, linfo, lpart, _ = build(node.left)
            rf, rcols, rinfo, rpart, r_sorted_by = build(node.right,
                                                         lead_pair[1])
            li, ri = lcols.index(lead_pair[0]), rcols.index(lead_pair[1])
            residual = tuple(
                (lcols.index(l), rcols.index(r))
                for k, (l, r) in enumerate(node.pairs) if k != lead_k
            )
            lead_rows = max(linfo.rows * rinfo.rows / doms[lead_k], 1e-3)
            drop = {r for _, r in node.pairs}
            keep_right = tuple(i for i, c in enumerate(rcols) if c not in drop)
            out_cols = lcols + tuple(c for c in rcols if c not in drop)
            out_cap = cap_of(lead_rows)
            # per-destination bucket: rows/(ndev^2) with skew headroom
            lbucket = cost_mod.capacity_for(
                linfo.rows / (ndev * ndev) * SKEW * 2, safety=safety, floor=16)
            rbucket = cost_mod.capacity_for(
                rinfo.rows / (ndev * ndev) * SKEW * 2, safety=safety, floor=16)
            l_colocated = lpart == lead_pair[0] and lpart is not None
            r_colocated = rpart == lead_pair[1] and rpart is not None
            # sort elision survives only when the right side is NOT
            # repartitioned (the exchange destroys row order)
            r_presorted = r_colocated and r_sorted_by == lead_pair[1]

            def run(tt, views, _lf=lf, _rf=rf, _li=li, _ri=ri, _res=residual,
                    _keep=keep_right, _cap=out_cap, _lb=lbucket, _rb=rbucket,
                    _lcol=l_colocated, _rcol=r_colocated, _rs=r_presorted):
                left = _lf(tt, views)
                right = _rf(tt, views)
                # co-partition elision: only repartition sides not already
                # hashed on the lead join column
                if not (_lcol and _rcol):
                    if not _lcol:
                        left = repartition(left, _li, axis, ndev, _lb)
                    if not _rcol:
                        right = repartition(right, _ri, axis, ndev, _rb)
                return E.join(left, right, _li, _ri, _res, _keep, _cap,
                              right_sorted=_rs)

            return run, out_cols, est.info, lead_pair[0], None
        if isinstance(node, Project):
            child_fn, cols, _, part, sorted_by = build(node.child, prefer_sorted)
            idx = tuple(cols.index(c) for c in node.cols)
            out_part = part if part in node.cols else None
            out_sorted = sorted_by if (not node.dedupe and sorted_by in node.cols) \
                else (node.cols[0] if node.dedupe else None)

            def run(tt, views, _fn=child_fn, _idx=idx, _d=node.dedupe):
                rel = _fn(tt, views)
                # local dedupe is enough: rows are co-partitioned by the
                # kept partition column or will be deduped at gather
                return E.project(rel, _idx, _d)

            return run, node.cols, est.info, out_part, out_sorted
        raise TypeError(type(node))

    fn, cols, info, part, _sorted = build(plan)

    in_specs = ({k: P(axis) for k in E.INDEX_NAMES},
                {vid: PRel(P(axis), P(axis), P()) for vid in view_infos})
    out_specs = PRel(P(axis), P(axis), P(axis))

    def local_program(tt, views):
        # unwrap per-shard views: n arrives as a (1,) slice of the global
        # per-device count vector
        views = {vid: PRel(v.data, v.n.reshape(()), v.overflow)
                 for vid, v in views.items()}
        out = fn(tt, views)
        return PRel(out.data, out.n.reshape(1), out.overflow.reshape(1))

    from repro.distributed.sharding import shard_map_compat

    smapped = shard_map_compat(local_program, mesh=mesh,
                               in_specs=in_specs, out_specs=out_specs)
    smapped.out_columns = cols  # type: ignore[attr-defined]
    smapped.est_rows = info.rows  # type: ignore[attr-defined]
    return smapped


# ----------------------------------------------------------------------
# host helpers
# ----------------------------------------------------------------------
def shard_store_by_subject(store, mesh, axis: str = "data",
                           with_shards: bool = False):
    """Partition the TT by hash(subject); per-shard local sorted indexes,
    stacked into global arrays sharded over `axis`.

    Empty shards are legal (hash skew, or ndev > distinct subjects —
    common on tiny stores over wide meshes): they stack as all-sentinel
    slabs, which every index order sorts last and `scan_pattern` masks,
    so downstream searchsorted sees a valid zero-row sorted index.  The
    per-shard capacity always covers the longest shard even past the
    planner's power-of-two ceiling, so a heavily skewed shard can never
    truncate rows.  `with_shards=True` additionally returns the host-
    side per-shard `TripleStore`s (the mirrors a sharded serving backend
    probes against and falls back to when a device shard degrades).
    """
    ndev = mesh.shape[axis]
    t = store.triples
    dest = t[:, 0] % ndev
    from repro.rdf.triples import TripleStore

    shards = [TripleStore(t[dest == d]) for d in range(ndev)]
    longest = max((len(s) for s in shards), default=0)
    cap = max(cost_mod.capacity_for(max(longest, 1), safety=1.0),
              max(longest, 1))

    out: dict[str, np.ndarray] = {}
    for name in E.INDEX_NAMES:
        stacked = np.full((ndev, cap, 3), 2**31 - 1, dtype=np.int32)
        for d, s in enumerate(shards):
            idx = s.index(name)
            stacked[d, : len(idx)] = idx
        out[name] = stacked.reshape(ndev * cap, 3)
    sharding = NamedSharding(mesh, P(axis))
    tt = {k: jax.device_put(v, sharding) for k, v in out.items()}
    return (tt, shards) if with_shards else tt


def shard_prel_rows(rows: np.ndarray, key_col: int, mesh, axis: str = "data",
                    cap_per_dev: int | None = None,
                    width: int | None = None) -> PRel:
    """Hash-partition extent rows by `key_col` into a sharded PRel.

    A zero-row extent is valid input, including the degenerate 1-D empty
    array numpy produces for `[]` — it is normalized to a (0, width)
    table (`width` defaults to `key_col + 1`) so every shard gets an
    empty-but-well-shaped slab instead of crashing on the column index.
    """
    ndev = mesh.shape[axis]
    rows = np.asarray(rows, np.int32)
    if rows.ndim != 2:
        rows = rows.reshape(0, width if width else key_col + 1)
    dest = rows[:, key_col] % ndev
    groups = [rows[dest == d] for d in range(ndev)]
    cap = cap_per_dev or cost_mod.capacity_for(
        max(max((len(g) for g in groups), default=1), 1), safety=2.0)
    data = np.full((ndev, cap, rows.shape[1]), -1, dtype=np.int32)
    ns = np.zeros((ndev,), np.int32)
    for d, g in enumerate(groups):
        k = min(len(g), cap)
        data[d, :k] = g[:k]
        ns[d] = k
    sh_rows = NamedSharding(mesh, P(axis))
    return PRel(
        jax.device_put(data.reshape(ndev * cap, rows.shape[1]), sh_rows),
        jax.device_put(ns, sh_rows),
        jax.device_put(np.asarray(False), NamedSharding(mesh, P())),
    )


def gather_result(rel: PRel) -> np.ndarray:
    """Collect a sharded result to the host (set semantics: dedupe rows
    that a head projection may have duplicated across shards)."""
    data = np.asarray(rel.data)
    mask = data[:, 0] != -1 if data.shape[1] else np.zeros(len(data), bool)
    rows = data[mask]
    return np.unique(rows, axis=0) if len(rows) else rows
