"""Fused workload execution: shape-bucketed (default) or unrolled.

`compile_workload` lowers a `WorkloadDAG` (query/dag.py) into a single
function evaluated in one device call: nodes run in topological order,
each shared node computed once and its `PRel` buffer read by every
consumer.  Static buffer capacities are planned DAG-wide from the cost
model (`cost.estimate_dag` + `cost.capacity_for`).  This unrolled path
traces one closure per node, so its compile time grows linearly with
workload size — it remains as the A/B reference (`mode="unrolled"`).

The default lowering is *shape-bucketed* (`query/buckets.py`,
`mode="bucketed"`): DAG nodes are grouped by (wave, operator kind,
structural signature, capacity class) and each bucket executes as one
`lax.scan` over stacked operands, compiled ahead-of-time through a
process-global persistent cache.  Compile time scales with the number
of distinct shapes, not the number of queries — near-flat from 22 to
1000+ workload members (benchmarks/bench_compile_scale.py).

`WorkloadExecutor` wraps either program in an adaptive driver: alongside
the root results it observes each node's *own* overflow flag (latched
overflow minus anything inherited from children), so when a capacity
proves too small the driver knows exactly which buffer to grow.  In
bucketed mode an overflow promotes only the offending node's *bucket*
to the next capacity class — the next execute recompiles that bucket's
body (and any consumer whose operand shape changed); every untouched
body is a cache hit.  Capacities learned this way can be carried into a
successor executor (`learned_caps()` / `carry_caps=`), so a hot-swapped
program does not re-learn overflows the previous one already healed.

The fused path compiles scans without consumer-specific sort
preferences (a shared scan can't commit to one consumer's join order),
so joins never assume a pre-sorted build side here; correctness is
unaffected and the redundancy removed by sharing dominates the elided
sort it gives up.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.query import cost as cost_mod
from repro.query import engine as E
from repro.query.buckets import BucketedProgram, compile_cache
from repro.query.dag import WorkloadDAG

CAP_CEIL = 1 << 22


def compile_workload(dag: WorkloadDAG, stats, view_infos,
                     safety: float = 4.0, use_pallas: bool = False,
                     caps: list[int] | None = None,
                     cap_planner: Callable[[object, float], int] | None = None,
                     ests=None):
    """Lower the DAG into `fn(tt, views) -> (roots, own_overflow)`.

    roots: {member name: PRel}; own_overflow: (n_nodes,) bool vector of
    node-local overflows.  `caps` pins every node's buffer capacity
    (adaptive recompiles); when None, capacities are planned from the
    DAG-wide estimates (`cap_planner(node, est_rows)` overrides the
    default `capacity_for`, mirroring `build_executor`'s cap_override).
    The planned capacities are returned on `fn.caps`.  `ests` accepts
    precomputed `cost.estimate_dag` output (estimates don't depend on
    capacities, so adaptive recompiles can reuse them).
    """
    if ests is None:
        ests = cost_mod.estimate_dag(dag, stats, view_infos)
    plan_caps = caps is None
    if plan_caps:
        caps = [0] * len(dag.nodes)

    def _cap(node, rows: float) -> int:
        if cap_planner is not None:
            return int(cap_planner(node.plan, rows))
        return cost_mod.capacity_for(rows, safety=safety)

    steps: list[tuple[Callable, tuple[int, ...], str]] = []
    for node in dag.nodes:
        if node.kind == "scan":
            idx_name, prefix, residual, takes, self_eq, _sorted = \
                E.atom_scan_spec(node.spec)
            if plan_caps:
                caps[node.id] = _cap(
                    node, E.range_cardinality(node.spec, prefix, stats))

            def step(tt, views, res, _f=functools.partial(
                    E.scan_pattern, prefix=prefix, residual=residual,
                    takes=takes, self_eq=self_eq, cap=caps[node.id]),
                    _idx=idx_name):
                return _f(tt[_idx])

        elif node.kind == "view":
            def step(tt, views, res, _vid=node.spec):
                return views[_vid]

        elif node.kind == "filter":
            ci, value = node.spec

            def step(tt, views, res, _c=node.child_ids[0], _ci=ci, _v=value):
                return E.filter_eq(res[_c], _ci, _v)

        elif node.kind == "join":
            lid, rid = node.child_ids
            pairs = node.spec
            doms = [max(ests[lid].info.dcol(l), ests[rid].info.dcol(r))
                    for l, r in pairs]
            lead_k = max(range(len(doms)), key=lambda i: doms[i])
            lcol, rcol = pairs[lead_k]
            residual = tuple(p for k, p in enumerate(pairs) if k != lead_k)
            drop = {r for _, r in pairs}
            keep_right = tuple(i for i in range(dag.nodes[rid].width)
                               if i not in drop)
            if plan_caps:
                lead_rows = max(
                    ests[lid].rows * ests[rid].rows / doms[lead_k], 1e-3)
                caps[node.id] = _cap(node, lead_rows)

            def step(tt, views, res, _l=lid, _r=rid, _lc=lcol, _rc=rcol,
                     _res=residual, _keep=keep_right, _cap=caps[node.id]):
                return E.join(res[_l], res[_r], _lc, _rc, _res, _keep, _cap,
                              use_pallas=use_pallas)

        elif node.kind == "project":
            idxs, dedupe = node.spec

            def step(tt, views, res, _c=node.child_ids[0], _idx=idxs,
                     _d=dedupe):
                return E.project(res[_c], _idx, _d)

        else:
            raise TypeError(node.kind)
        steps.append((step, node.child_ids, node.kind))

    roots = dict(dag.roots)

    def fn(tt, views):
        res: list[E.PRel] = []
        own: list[jax.Array] = []
        for run, child_ids, kind in steps:
            rel = run(tt, views, res)
            if kind == "view":
                # view buffers are packed at exact capacity by the
                # materializer; nothing here for the driver to grow
                own.append(jnp.asarray(False))
            else:
                inherited = jnp.asarray(False)
                for c in child_ids:
                    inherited = inherited | res[c].overflow
                own.append(rel.overflow & ~inherited)
            res.append(rel)
        ovf = jnp.stack(own) if own else jnp.zeros((0,), dtype=bool)
        return {name: res[nid] for name, nid in roots.items()}, ovf

    fn.caps = caps  # type: ignore[attr-defined]
    return fn


class WorkloadExecutor:
    """Adaptive driver around the fused workload program.

    `run` executes the whole workload; on capacity overflow it grows the
    offending buffers (bucketed mode: promotes the offending *buckets*
    to the next capacity class; unrolled mode: doubles the node's
    buffer), recompiles what changed, and retries — up to `max_retries`
    recompiles, after which (or once a buffer hits the capacity ceiling)
    it raises.

    `carry_caps` seeds planning with capacities a previous executor
    learned (`learned_caps()`), keyed by DAG content key, so a rebuilt
    program — e.g. after a `swap_state` hot swap — starts from the
    healed capacities instead of re-learning every overflow.
    """

    def __init__(self, dag: WorkloadDAG, stats, view_infos, *,
                 safety: float = 4.0, use_pallas: bool = False,
                 max_retries: int = 12,
                 cap_planner: Callable[[object, float], int] | None = None,
                 mode: str = "bucketed",
                 carry_caps: dict | None = None,
                 fault_hook=None):
        if mode not in ("bucketed", "unrolled"):
            raise ValueError(f"unknown workload mode {mode!r}")
        # fault_hook: duck-typed chaos injector (`.fire(site)` raising an
        # injected fault when armed); None in production.  Sites fired
        # here: "compile" on program (re)construction, "device_call" and
        # "capacity_overflow" on each run.
        self.fault_hook = fault_hook
        self.dag = dag
        self.stats = stats
        self.view_infos = view_infos
        self.safety = safety
        self.use_pallas = use_pallas
        self.max_retries = max_retries
        self.cap_planner = cap_planner
        self.mode = mode
        self.carry_caps = dict(carry_caps or {})
        self.caps: list[int] | None = None
        # telemetry
        self.compiles = 0
        self.runs = 0
        self.recompiles = 0
        self.cap_history: dict[int, list[int]] = {}
        self._jit = None
        self._prog: BucketedProgram | None = None
        self._ests = None

    # ------------------------------------------------------------------
    # program construction
    # ------------------------------------------------------------------
    def _ensure_ests(self):
        if self._ests is None:
            self._ests = cost_mod.estimate_dag(self.dag, self.stats,
                                               self.view_infos)
        return self._ests

    def _fire(self, site: str) -> None:
        if self.fault_hook is not None:
            self.fault_hook.fire(site)

    def _compile(self) -> None:
        """Unrolled mode: (re)trace the whole program."""
        self._fire("compile")
        fn = compile_workload(self.dag, self.stats, self.view_infos,
                              safety=self.safety, use_pallas=self.use_pallas,
                              caps=self.caps, cap_planner=self.cap_planner,
                              ests=self._ensure_ests())
        self.caps = fn.caps
        self._jit = jax.jit(fn)
        self.compiles += 1

    def _program(self) -> BucketedProgram:
        if self._prog is None:
            self._fire("compile")
            self._prog = BucketedProgram(
                self.dag, self.stats, self.view_infos, safety=self.safety,
                use_pallas=self.use_pallas, cap_planner=self.cap_planner,
                ests=self._ensure_ests(), carry_caps=self.carry_caps)
            self.caps = self._prog.caps
            self.compiles += 1
        return self._prog

    # ------------------------------------------------------------------
    def run(self, tt, views) -> dict[str, E.PRel]:
        """Answer every workload member; returns {name: PRel}."""
        self._fire("device_call")
        self._fire("capacity_overflow")
        if self.mode == "bucketed":
            return self._run_bucketed(tt, views)
        return self._run_unrolled(tt, views)

    def _run_bucketed(self, tt, views) -> dict[str, E.PRel]:
        prog = self._program()
        attempt = 0
        while True:
            roots, own = prog.execute(tt, views)
            self.runs += 1
            if not own.any():
                return roots
            offending = np.nonzero(own)[0].tolist()
            if attempt >= self.max_retries:
                raise RuntimeError(
                    f"capacity overflow persists after {attempt} adaptive "
                    f"recompiles (nodes {offending}); estimates are "
                    f"pathologically low — raise max_retries or safety"
                )
            grown = prog.promote(offending)
            if not grown:
                raise RuntimeError(
                    f"capacity ceiling ({CAP_CEIL}) reached on nodes "
                    f"{offending}; result exceeds the engine's maximum "
                    f"buffer size"
                )
            for nid, old, new in grown:
                self.cap_history.setdefault(nid, [old]).append(new)
            self.compiles += 1
            self.recompiles += 1
            attempt += 1

    def _run_unrolled(self, tt, views) -> dict[str, E.PRel]:
        if self._jit is None:
            self._compile()
        attempt = 0
        while True:
            roots, own = self._jit(tt, views)
            self.runs += 1
            own_np = np.asarray(own)
            if not own_np.any():
                return roots
            offending = np.nonzero(own_np)[0].tolist()
            if attempt >= self.max_retries:
                raise RuntimeError(
                    f"capacity overflow persists after {attempt} adaptive "
                    f"recompiles (nodes {offending}); estimates are "
                    f"pathologically low — raise max_retries or safety"
                )
            grew = False
            for nid in offending:
                cur = self.caps[nid]
                new = min(max(cur * 2, 2), CAP_CEIL)
                if new > cur:
                    self.caps[nid] = new
                    self.cap_history.setdefault(nid, [cur]).append(new)
                    grew = True
            if not grew:
                raise RuntimeError(
                    f"capacity ceiling ({CAP_CEIL}) reached on nodes "
                    f"{offending}; result exceeds the engine's maximum "
                    f"buffer size"
                )
            self._compile()
            self.recompiles += 1
            attempt += 1

    # ------------------------------------------------------------------
    # static verification
    # ------------------------------------------------------------------
    def analyze(self, n_tt: int | None = None, view_caps=None):
        """Run the static analyzers (IR verifier, capacity analysis,
        jaxpr lint) over this executor's DAG and — in bucketed mode —
        its compiled-shape program, without executing anything.  Returns
        an `repro.analysis.AnalysisReport`."""
        from repro import analysis

        program = self._program() if self.mode == "bucketed" else None
        return analysis.analyze_workload(
            self.dag, self.stats, self.view_infos, program=program,
            n_tt=n_tt, view_caps=view_caps)

    # ------------------------------------------------------------------
    # capacity carry across program rebuilds
    # ------------------------------------------------------------------
    def learned_caps(self) -> dict:
        """Capacities grown by the adaptive driver, keyed by DAG content
        key (stable across DAG instances), merged over whatever this
        executor itself was seeded with — pass to a successor's
        `carry_caps=` so a hot-swapped program keeps the healed sizes."""
        out = dict(self.carry_caps)
        if self.cap_history and self.caps is not None:
            keys = self.dag.content_keys()
            for nid in self.cap_history:
                out[keys[nid]] = max(out.get(keys[nid], 0), self.caps[nid])
        return out

    # ------------------------------------------------------------------
    def warmup(self, tt, views) -> dict[str, E.PRel]:
        """Pre-warm the serving path: compile every bucket body (mostly
        persistent-cache hits after a hot swap) and heal any planning
        overflows by running the workload once.  Returns the roots so
        callers can seed their result caches."""
        return self.run(tt, views)

    # ------------------------------------------------------------------
    def telemetry(self) -> dict:
        t = dict(self.dag.stats())
        t.update(compiles=self.compiles, runs=self.runs,
                 recompiles=self.recompiles,
                 grown_nodes=sorted(self.cap_history),
                 mode=self.mode)
        # bucket/compile-cache telemetry (zeros on the unrolled path so
        # consumers can rely on the keys being present)
        t.update(buckets=0, bucket_signatures=0, bucket_compiles=0,
                 bucket_cache_hits=0, bucket_cache_misses=0,
                 bucket_compile_seconds=0.0,
                 bucket_compile_log=[], bucket_promotions=0)
        if self._prog is not None:
            t.update(self._prog.telemetry())
        t["compile_cache"] = compile_cache().stats()
        return t
