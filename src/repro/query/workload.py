"""Fused workload execution: one jitted program answers every rewriting.

`compile_workload` lowers a `WorkloadDAG` (query/dag.py) into a single
function evaluated in one device call: nodes run in topological order,
each shared node computed once and its `PRel` buffer read by every
consumer.  Static buffer capacities are planned DAG-wide from the cost
model (`cost.estimate_dag` + `cost.capacity_for`).

`WorkloadExecutor` wraps the compiled program in an adaptive driver:
alongside the root results the program returns each node's *own*
overflow flag (its latched overflow minus anything inherited from
children), so when a capacity proves too small the driver knows exactly
which buffer to grow — it doubles the offending node's capacity,
recompiles, and retries under a bounded budget instead of raising to
the caller.  Recompile counts and capacity history are kept as
telemetry.

The fused path compiles scans without consumer-specific sort
preferences (a shared scan can't commit to one consumer's join order),
so joins never assume a pre-sorted build side here; correctness is
unaffected and the redundancy removed by sharing dominates the elided
sort it gives up.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.query import cost as cost_mod
from repro.query import engine as E
from repro.query.dag import WorkloadDAG

CAP_CEIL = 1 << 22


def compile_workload(dag: WorkloadDAG, stats, view_infos,
                     safety: float = 4.0, use_pallas: bool = False,
                     caps: list[int] | None = None,
                     cap_planner: Callable[[object, float], int] | None = None,
                     ests=None):
    """Lower the DAG into `fn(tt, views) -> (roots, own_overflow)`.

    roots: {member name: PRel}; own_overflow: (n_nodes,) bool vector of
    node-local overflows.  `caps` pins every node's buffer capacity
    (adaptive recompiles); when None, capacities are planned from the
    DAG-wide estimates (`cap_planner(node, est_rows)` overrides the
    default `capacity_for`, mirroring `build_executor`'s cap_override).
    The planned capacities are returned on `fn.caps`.  `ests` accepts
    precomputed `cost.estimate_dag` output (estimates don't depend on
    capacities, so adaptive recompiles can reuse them).
    """
    if ests is None:
        ests = cost_mod.estimate_dag(dag, stats, view_infos)
    plan_caps = caps is None
    if plan_caps:
        caps = [0] * len(dag.nodes)

    def _cap(node, rows: float) -> int:
        if cap_planner is not None:
            return int(cap_planner(node.plan, rows))
        return cost_mod.capacity_for(rows, safety=safety)

    steps: list[tuple[Callable, tuple[int, ...], str]] = []
    for node in dag.nodes:
        if node.kind == "scan":
            idx_name, prefix, residual, takes, self_eq, _sorted = \
                E.atom_scan_spec(node.spec)
            if plan_caps:
                caps[node.id] = _cap(
                    node, E.range_cardinality(node.spec, prefix, stats))

            def step(tt, views, res, _f=functools.partial(
                    E.scan_pattern, prefix=prefix, residual=residual,
                    takes=takes, self_eq=self_eq, cap=caps[node.id]),
                    _idx=idx_name):
                return _f(tt[_idx])

        elif node.kind == "view":
            def step(tt, views, res, _vid=node.spec):
                return views[_vid]

        elif node.kind == "filter":
            ci, value = node.spec

            def step(tt, views, res, _c=node.child_ids[0], _ci=ci, _v=value):
                return E.filter_eq(res[_c], _ci, _v)

        elif node.kind == "join":
            lid, rid = node.child_ids
            pairs = node.spec
            doms = [max(ests[lid].info.dcol(l), ests[rid].info.dcol(r))
                    for l, r in pairs]
            lead_k = max(range(len(doms)), key=lambda i: doms[i])
            lcol, rcol = pairs[lead_k]
            residual = tuple(p for k, p in enumerate(pairs) if k != lead_k)
            drop = {r for _, r in pairs}
            keep_right = tuple(i for i in range(dag.nodes[rid].width)
                               if i not in drop)
            if plan_caps:
                lead_rows = max(
                    ests[lid].rows * ests[rid].rows / doms[lead_k], 1e-3)
                caps[node.id] = _cap(node, lead_rows)

            def step(tt, views, res, _l=lid, _r=rid, _lc=lcol, _rc=rcol,
                     _res=residual, _keep=keep_right, _cap=caps[node.id]):
                return E.join(res[_l], res[_r], _lc, _rc, _res, _keep, _cap,
                              use_pallas=use_pallas)

        elif node.kind == "project":
            idxs, dedupe = node.spec

            def step(tt, views, res, _c=node.child_ids[0], _idx=idxs,
                     _d=dedupe):
                return E.project(res[_c], _idx, _d)

        else:
            raise TypeError(node.kind)
        steps.append((step, node.child_ids, node.kind))

    roots = dict(dag.roots)

    def fn(tt, views):
        res: list[E.PRel] = []
        own: list[jax.Array] = []
        for run, child_ids, kind in steps:
            rel = run(tt, views, res)
            if kind == "view":
                # view buffers are packed at exact capacity by the
                # materializer; nothing here for the driver to grow
                own.append(jnp.asarray(False))
            else:
                inherited = jnp.asarray(False)
                for c in child_ids:
                    inherited = inherited | res[c].overflow
                own.append(rel.overflow & ~inherited)
            res.append(rel)
        ovf = jnp.stack(own) if own else jnp.zeros((0,), dtype=bool)
        return {name: res[nid] for name, nid in roots.items()}, ovf

    fn.caps = caps  # type: ignore[attr-defined]
    return fn


class WorkloadExecutor:
    """Adaptive driver around the fused workload program.

    `run` executes the whole workload in a single device call; on
    capacity overflow it doubles the offending nodes' capacities,
    recompiles, and retries — up to `max_retries` recompiles, after
    which (or once a buffer hits the capacity ceiling) it raises.
    """

    def __init__(self, dag: WorkloadDAG, stats, view_infos, *,
                 safety: float = 4.0, use_pallas: bool = False,
                 max_retries: int = 12,
                 cap_planner: Callable[[object, float], int] | None = None):
        self.dag = dag
        self.stats = stats
        self.view_infos = view_infos
        self.safety = safety
        self.use_pallas = use_pallas
        self.max_retries = max_retries
        self.cap_planner = cap_planner
        self.caps: list[int] | None = None
        # telemetry
        self.compiles = 0
        self.runs = 0
        self.recompiles = 0
        self.cap_history: dict[int, list[int]] = {}
        self._jit = None
        self._ests = None

    def _compile(self) -> None:
        if self._ests is None:
            self._ests = cost_mod.estimate_dag(self.dag, self.stats,
                                               self.view_infos)
        fn = compile_workload(self.dag, self.stats, self.view_infos,
                              safety=self.safety, use_pallas=self.use_pallas,
                              caps=self.caps, cap_planner=self.cap_planner,
                              ests=self._ests)
        self.caps = fn.caps
        self._jit = jax.jit(fn)
        self.compiles += 1

    def run(self, tt, views) -> dict[str, E.PRel]:
        """Answer every workload member; returns {name: PRel}."""
        if self._jit is None:
            self._compile()
        attempt = 0
        while True:
            roots, own = self._jit(tt, views)
            self.runs += 1
            own_np = np.asarray(own)
            if not own_np.any():
                return roots
            offending = np.nonzero(own_np)[0].tolist()
            if attempt >= self.max_retries:
                raise RuntimeError(
                    f"capacity overflow persists after {attempt} adaptive "
                    f"recompiles (nodes {offending}); estimates are "
                    f"pathologically low — raise max_retries or safety"
                )
            grew = False
            for nid in offending:
                cur = self.caps[nid]
                new = min(max(cur * 2, 2), CAP_CEIL)
                if new > cur:
                    self.caps[nid] = new
                    self.cap_history.setdefault(nid, [cur]).append(new)
                    grew = True
            if not grew:
                raise RuntimeError(
                    f"capacity ceiling ({CAP_CEIL}) reached on nodes "
                    f"{offending}; result exceeds the engine's maximum "
                    f"buffer size"
                )
            self._compile()
            self.recompiles += 1
            attempt += 1

    def telemetry(self) -> dict:
        t = dict(self.dag.stats())
        t.update(compiles=self.compiles, runs=self.runs,
                 recompiles=self.recompiles,
                 grown_nodes=sorted(self.cap_history))
        return t
