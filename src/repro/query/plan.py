"""Physical plan algebra for rewritings.

A rewriting (state component R of the paper) is a plan tree whose leaves
are materialized views (`ViewRef`) or the triple table (`TTScan`, used by
the no-views baseline).  Inner nodes re-apply the selections and joins
that transitions removed from views.

Plans are executed by two engines with identical semantics:
  * query/ref_engine.py — numpy, dynamic shapes (oracle),
  * query/engine.py    — JAX, static padded shapes (jittable, shardable).
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.core.queries import Atom, Const, Var


@dataclass(frozen=True)
class Plan:
    def columns(self) -> tuple[str, ...]:
        raise NotImplementedError

    def children(self) -> tuple["Plan", ...]:
        return ()


@dataclass(frozen=True)
class ViewRef(Plan):
    """Scan of a materialized view extent; columns follow the view head."""

    view_id: int
    schema: tuple[str, ...]

    def columns(self) -> tuple[str, ...]:
        return self.schema


@dataclass(frozen=True)
class TTScan(Plan):
    """Scan of the triple table with one triple pattern."""

    atom: Atom

    def columns(self) -> tuple[str, ...]:
        seen: dict[str, None] = {}
        for t in self.atom.terms():
            if isinstance(t, Var):
                seen.setdefault(t.name)
        return tuple(seen)


@dataclass(frozen=True)
class Filter(Plan):
    """sigma_{col = value} — compensation for a selection cut."""

    child: Plan
    col: str
    value: int

    def columns(self) -> tuple[str, ...]:
        return self.child.columns()

    def children(self) -> tuple[Plan, ...]:
        return (self.child,)


@dataclass(frozen=True)
class EquiJoin(Plan):
    """left ⋈ right on pairs of named columns — compensation for a join cut."""

    left: Plan
    right: Plan
    pairs: tuple[tuple[str, str], ...]  # (left_col, right_col)

    def columns(self) -> tuple[str, ...]:
        rights = {r for _, r in self.pairs}
        return self.left.columns() + tuple(
            c for c in self.right.columns() if c not in rights
        )

    def children(self) -> tuple[Plan, ...]:
        return (self.left, self.right)


@dataclass(frozen=True)
class Project(Plan):
    child: Plan
    cols: tuple[str, ...]
    dedupe: bool = True

    def columns(self) -> tuple[str, ...]:
        return self.cols

    def children(self) -> tuple[Plan, ...]:
        return (self.child,)


def rename_columns(plan: Plan, mapping: dict[str, str]) -> Plan:
    """Rename output columns throughout a plan (used by view fusion to
    redirect rewritings onto the surviving isomorphic view)."""
    if isinstance(plan, ViewRef):
        return ViewRef(plan.view_id, tuple(mapping.get(c, c) for c in plan.schema))
    if isinstance(plan, TTScan):
        def sub(t):
            if isinstance(t, Var) and t.name in mapping:
                return Var(mapping[t.name])
            return t
        a = plan.atom
        return TTScan(Atom(sub(a.s), sub(a.p), sub(a.o)))
    if isinstance(plan, Filter):
        return Filter(rename_columns(plan.child, mapping), mapping.get(plan.col, plan.col), plan.value)
    if isinstance(plan, EquiJoin):
        return EquiJoin(
            rename_columns(plan.left, mapping),
            rename_columns(plan.right, mapping),
            tuple((mapping.get(l, l), mapping.get(r, r)) for l, r in plan.pairs),
        )
    if isinstance(plan, Project):
        return Project(
            rename_columns(plan.child, mapping),
            tuple(mapping.get(c, c) for c in plan.cols),
            plan.dedupe,
        )
    raise TypeError(type(plan))


def replace_view(plan: Plan, view_id: int, replacement: Plan) -> Plan:
    """Substitute every `ViewRef(view_id)` by `replacement` (column-aligned)."""
    if isinstance(plan, ViewRef):
        if plan.view_id == view_id:
            rep_cols = replacement.columns()
            if tuple(rep_cols) != tuple(plan.schema):
                # align replacement columns to the old reference's schema
                mapping = dict(zip(rep_cols, plan.schema))
                return rename_columns(replacement, mapping)
            return replacement
        return plan
    if isinstance(plan, TTScan):
        return plan
    if isinstance(plan, Filter):
        return Filter(replace_view(plan.child, view_id, replacement), plan.col, plan.value)
    if isinstance(plan, EquiJoin):
        return EquiJoin(
            replace_view(plan.left, view_id, replacement),
            replace_view(plan.right, view_id, replacement),
            plan.pairs,
        )
    if isinstance(plan, Project):
        return Project(replace_view(plan.child, view_id, replacement), plan.cols, plan.dedupe)
    raise TypeError(type(plan))


def remap_view(plan: Plan, old_vid: int, new_vid: int,
               perm: tuple[int, ...]) -> Plan:
    """Redirect `ViewRef(old_vid)` to `new_vid` with a column permutation:
    new schema[j] = old schema[perm[j]] (view-fusion plumbing)."""
    if isinstance(plan, ViewRef):
        if plan.view_id == old_vid:
            return ViewRef(new_vid, tuple(plan.schema[i] for i in perm))
        return plan
    if isinstance(plan, TTScan):
        return plan
    if isinstance(plan, Filter):
        return Filter(remap_view(plan.child, old_vid, new_vid, perm), plan.col, plan.value)
    if isinstance(plan, EquiJoin):
        return EquiJoin(
            remap_view(plan.left, old_vid, new_vid, perm),
            remap_view(plan.right, old_vid, new_vid, perm),
            plan.pairs,
        )
    if isinstance(plan, Project):
        return Project(remap_view(plan.child, old_vid, new_vid, perm), plan.cols, plan.dedupe)
    raise TypeError(type(plan))


def validate_plan(plan: Plan) -> list[str]:
    """Structural well-formedness of a plan tree: every column an
    operator references must exist in its child's output, join pairs and
    projections must resolve, and ViewRef schemas must be duplicate-free.
    Returns a list of human-readable problems (empty when sound) — the
    static IR verifier turns these into findings instead of letting a
    malformed plan surface as a KeyError mid-compile."""
    problems: list[str] = []
    if isinstance(plan, ViewRef):
        if len(set(plan.schema)) != len(plan.schema):
            problems.append(
                f"ViewRef(v{plan.view_id}) schema has duplicate columns: "
                f"{plan.schema}")
    elif isinstance(plan, TTScan):
        if not plan.columns() and not any(
                isinstance(t, Const) for t in plan.atom.terms()):
            problems.append(f"TTScan {plan.atom!r} has no output columns "
                            "and no constants (empty pattern)")
    elif isinstance(plan, Filter):
        if plan.col not in plan.child.columns():
            problems.append(
                f"Filter references column {plan.col!r} absent from child "
                f"output {plan.child.columns()}")
    elif isinstance(plan, EquiJoin):
        lcols, rcols = plan.left.columns(), plan.right.columns()
        for l, r in plan.pairs:
            if l not in lcols:
                problems.append(f"EquiJoin left column {l!r} absent from "
                                f"{lcols}")
            if r not in rcols:
                problems.append(f"EquiJoin right column {r!r} absent from "
                                f"{rcols}")
    elif isinstance(plan, Project):
        ccols = plan.child.columns()
        for c in plan.cols:
            if c not in ccols:
                problems.append(f"Project column {c!r} absent from child "
                                f"output {ccols}")
    else:
        problems.append(f"unknown plan operator {type(plan).__name__}")
        return problems
    for child in plan.children():
        problems.extend(validate_plan(child))
    return problems


def iter_subplans(plan: Plan):
    """Pre-order traversal over every operator of a plan tree."""
    yield plan
    for c in plan.children():
        yield from iter_subplans(c)


def has_cartesian(plan: Plan) -> bool:
    """True when the plan contains an empty-pairs join (disconnected
    rewriting) — those stay on the oracle path; the device engine only
    compiles connected plans."""
    return any(
        isinstance(p, EquiJoin) and not p.pairs for p in iter_subplans(plan)
    )


def referenced_views(plan: Plan) -> set[int]:
    if isinstance(plan, ViewRef):
        return {plan.view_id}
    out: set[int] = set()
    for c in plan.children():
        out |= referenced_views(c)
    return out


def plan_for_cq(cq, use_tt: bool = True) -> Plan:
    """Left-deep TT-scan plan evaluating a CQ directly over the triple
    table — the paper's no-views baseline, and the shape of view
    materialization jobs."""
    plans: list[Plan] = [TTScan(a) for a in cq.atoms]
    # self-join columns inside one atom are handled by TTScan semantics
    current = plans[0]
    remaining = plans[1:]
    while remaining:
        # pick next scan sharing a column (connected order)
        cur_cols = set(current.columns())
        pick = None
        for i, p in enumerate(remaining):
            shared = cur_cols & set(p.columns())
            if shared:
                pick = (i, tuple(sorted(shared)))
                break
        if pick is None:  # cartesian (disconnected query)
            i, shared = 0, ()
        else:
            i, shared = pick
        nxt = remaining.pop(i)
        current = EquiJoin(current, nxt, tuple((c, c) for c in shared))
    head_cols = tuple(h.name for h in cq.head)
    if head_cols != current.columns():
        current = Project(current, head_cols)
    return current
