"""JAX query engine: static-capacity padded relations.

XLA requires static shapes, so every relation is a `(capacity, width)`
int32 buffer + a valid-row count + an overflow flag.  Capacities come
from the same cardinality estimates the quality function uses
(`cost.capacity_for`).  Invariants:

  * valid rows occupy a prefix `[0, n)`;
  * rows at `[n, capacity)` are scrubbed to -1 (no stale ids);
  * `overflow` latches if any operator's true output exceeded capacity.

Joins are sort + `searchsorted` + bounded expansion via
`jnp.repeat(..., total_repeat_length=cap)` — the TPU-native replacement
for dynamic hash tables.  The probe phase can be delegated to the Pallas
kernel (`kernels/ops.py`) with `use_pallas=True`.
"""
from __future__ import annotations

import functools
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.queries import Const, Var
from repro.query import cost as cost_mod
from repro.query.plan import EquiJoin, Filter, Plan, Project, TTScan, ViewRef

INVALID = jnp.int32(-1)
SENTINEL_HI = jnp.int32(2**31 - 1)


class PRel(NamedTuple):
    data: jax.Array      # (cap, w) int32
    n: jax.Array         # () int32
    overflow: jax.Array  # () bool

    @property
    def cap(self) -> int:
        return self.data.shape[0]

    @property
    def width(self) -> int:
        return self.data.shape[1]


def make_prel(rows: np.ndarray, cap: int) -> PRel:
    rows = np.asarray(rows, dtype=np.int32)
    n = min(len(rows), cap)
    w = rows.shape[1] if rows.ndim == 2 else 0
    buf = np.full((cap, w), -1, dtype=np.int32)
    buf[:n] = rows[:n]
    return PRel(jnp.asarray(buf), jnp.int32(n), jnp.asarray(len(rows) > cap))


def to_numpy(rel: PRel) -> np.ndarray:
    n = int(rel.n)
    return np.asarray(rel.data[:n])


def _valid_mask(rel: PRel) -> jax.Array:
    return jnp.arange(rel.cap, dtype=jnp.int32) < rel.n


def compact(data: jax.Array, mask: jax.Array, overflow: jax.Array) -> PRel:
    """Stable-partition valid rows to the front and scrub the tail."""
    perm = jnp.argsort(~mask)  # False (valid) sorts first; argsort is stable
    data = data[perm]
    n = jnp.sum(mask).astype(jnp.int32)
    keep = jnp.arange(data.shape[0], dtype=jnp.int32) < n
    data = jnp.where(keep[:, None], data, INVALID)
    return PRel(data, n, overflow)


# ----------------------------------------------------------------------
# operators
# ----------------------------------------------------------------------
def filter_eq(rel: PRel, col: int, value) -> PRel:
    # value may be a traced scalar (bucketed execution stacks the filter
    # constants of a whole bucket into one operand array)
    mask = _valid_mask(rel) & (rel.data[:, col] == jnp.asarray(value, jnp.int32))
    return compact(rel.data, mask, rel.overflow)


def join(left: PRel, right: PRel, lcol: int, rcol: int,
         residual: tuple[tuple[int, int], ...], keep_right: tuple[int, ...],
         out_cap: int, use_pallas: bool = False,
         right_sorted: bool = False) -> PRel:
    """Equi-join on one column pair + residual equality pairs.

    Output columns: all of left's, then right's `keep_right`.
    `right_sorted=True` skips the build-side sort (the planner proved the
    input arrives ordered by `rcol` — six-index sort elision).
    """
    lvalid = _valid_mask(left)
    rvalid = _valid_mask(right)
    lkeys = jnp.where(lvalid, left.data[:, lcol], INVALID)
    rkeys = jnp.where(rvalid, right.data[:, rcol], SENTINEL_HI)
    if right_sorted:
        # valid rows are a sorted prefix; the scrubbed tail maps to +inf
        rsorted = right.data
        rkeys_sorted = rkeys
    else:
        order = jnp.argsort(rkeys)
        rsorted = right.data[order]
        rkeys_sorted = rkeys[order]

    if use_pallas:
        from repro.kernels import ops as kops

        lo, counts = kops.join_count(lkeys, rkeys_sorted)
        hi = lo + counts
    else:
        lo = jnp.searchsorted(rkeys_sorted, lkeys, side="left").astype(jnp.int32)
        hi = jnp.searchsorted(rkeys_sorted, lkeys, side="right").astype(jnp.int32)
        counts = hi - lo
    counts = jnp.where(lkeys == INVALID, 0, counts)

    total = jnp.sum(counts)
    offsets = jnp.cumsum(counts) - counts  # exclusive prefix
    left_idx = jnp.repeat(
        jnp.arange(left.cap, dtype=jnp.int32), counts, total_repeat_length=out_cap
    )
    pos = jnp.arange(out_cap, dtype=jnp.int32)
    within = pos - offsets[left_idx]
    right_idx = jnp.clip(lo[left_idx] + within, 0, right.cap - 1)
    valid = pos < jnp.minimum(total, out_cap)

    lrows = left.data[left_idx]
    rrows = rsorted[right_idx]
    for lc, rc in residual:
        valid = valid & (lrows[:, lc] == rrows[:, rc])
    out = jnp.concatenate([lrows, rrows[:, list(keep_right)]], axis=1) if keep_right \
        else lrows
    overflow = left.overflow | right.overflow | (total > out_cap)
    return compact(out, valid, overflow)


def project(rel: PRel, cols: tuple[int, ...], dedupe: bool) -> PRel:
    data = rel.data[:, list(cols)]
    mask = _valid_mask(rel)
    if not dedupe:
        data = jnp.where(mask[:, None], data, INVALID)
        return PRel(data, rel.n, rel.overflow)
    # lexicographic sort: iterate stable argsort minor->major, invalid last
    order = jnp.arange(rel.cap, dtype=jnp.int32)
    for c in reversed(range(data.shape[1])):
        keys = jnp.where(mask[order], data[order, c], SENTINEL_HI)
        order = order[jnp.argsort(keys)]
    sorted_rows = data[order]
    sorted_valid = mask[order]
    prev = jnp.roll(sorted_rows, 1, axis=0)
    same = jnp.all(sorted_rows == prev, axis=1)
    same = same.at[0].set(False)
    keep = sorted_valid & ~same
    return compact(sorted_rows, keep, rel.overflow)


def scan_pattern(index_data: jax.Array, prefix: tuple[tuple[int, int], ...],
                 residual: tuple[tuple[int, int], ...],
                 takes: tuple[int, ...], self_eq: tuple[tuple[int, int], ...],
                 cap: int) -> PRel:
    """Range scan of one sorted TT index for a triple pattern.

    index_data: (N,3) sorted lexicographically; `prefix` gives up to two
    (col, value) bindings covered by the sort order — the matching rows
    are one contiguous range.  A 1-binding prefix uses binary search; a
    2-binding prefix uses a fused rank reduction (lexicographic compare,
    single fused pass — the int32-safe substitute for a 64-bit fused key).
    residual: (col, value) equality filters not covered by the prefix.
    takes: variable positions to output; self_eq: same-var positions.
    Prefix/residual values may be traced scalars (the bucketed executor
    stacks the constants of a whole shape bucket into operand arrays);
    the column positions and `cap` stay static.
    """
    n_tt = index_data.shape[0]
    if len(prefix) == 0:
        lo = jnp.int32(0)
        # padded TT buffers (capacity-class maintenance uploads, shards)
        # end in SENTINEL_HI rows, which sort last in every index order —
        # count real rows so padding doesn't inflate the overflow check
        hi = jnp.sum(index_data[:, 0] != SENTINEL_HI).astype(jnp.int32)
    elif len(prefix) == 1:
        col = index_data[:, prefix[0][0]]
        key = jnp.asarray(prefix[0][1], jnp.int32)
        lo = jnp.searchsorted(col, key, side="left").astype(jnp.int32)
        hi = jnp.searchsorted(col, key, side="right").astype(jnp.int32)
    else:
        (c1, k1), (c2, k2) = prefix
        col1 = index_data[:, c1]
        col2 = index_data[:, c2]
        k1 = jnp.asarray(k1, jnp.int32)
        k2 = jnp.asarray(k2, jnp.int32)
        lt = (col1 < k1) | ((col1 == k1) & (col2 < k2))
        le = (col1 < k1) | ((col1 == k1) & (col2 <= k2))
        lo = jnp.sum(lt).astype(jnp.int32)
        hi = jnp.sum(le).astype(jnp.int32)
    pos = lo + jnp.arange(cap, dtype=jnp.int32)
    valid = pos < hi
    rows = index_data[jnp.clip(pos, 0, max(n_tt - 1, 0))]
    # distributed TT shards are padded with SENTINEL_HI rows; exclude them
    valid = valid & (rows[:, 0] != SENTINEL_HI)
    for c, v in residual:
        valid = valid & (rows[:, c] == jnp.asarray(v, jnp.int32))
    for a, b in self_eq:
        valid = valid & (rows[:, a] == rows[:, b])
    out = rows[:, list(takes)] if takes else rows[:, :0]
    overflow = (hi - lo) > cap
    return compact(out, valid, overflow)


# ----------------------------------------------------------------------
# plan compiler
# ----------------------------------------------------------------------
# all six index orders, as triple positions (s=0, p=1, o=2)
INDEX_NAMES = ("spo", "pos", "osp", "pso", "ops", "sop")
_INDEX_ORDERS = {
    "spo": (0, 1, 2), "pos": (1, 2, 0), "osp": (2, 0, 1),
    "pso": (1, 0, 2), "ops": (2, 1, 0), "sop": (0, 2, 1),
}


def atom_scan_spec(atom, prefer_sorted: str | None = None):
    """Static scan parameters for a TTScan node: pick the index whose sort
    prefix covers the most bound positions (exact contiguous range); among
    ties, prefer the index whose NEXT sort column is the variable a
    downstream merge join wants pre-sorted (sort elision).

    Returns (idx_name, prefix, residual, takes, self_eq, sorted_by) where
    sorted_by is the output variable the rows are ordered by (or None).
    """
    bound = {i: t.id for i, t in enumerate(atom.terms()) if isinstance(t, Const)}
    var_at = {i: t.name for i, t in enumerate(atom.terms())
              if isinstance(t, Var)}

    def next_var(cols, plen):
        for c in cols[plen:]:
            if c in var_at:
                return var_at[c]
            return None  # a bound residual column interrupts sortedness
        return None

    best = None  # (coverage, prefer_hit, idx_name, prefix)
    for idx_name, cols in _INDEX_ORDERS.items():
        prefix = []
        for c in cols:
            if c in bound:
                prefix.append((c, bound[c]))
            else:
                break
        hit = 1 if (prefer_sorted is not None
                    and next_var(cols, len(prefix)) == prefer_sorted) else 0
        key = (len(prefix), hit)
        if best is None or key > best[0]:
            best = (key, idx_name, tuple(prefix))
    _, best_idx, best_prefix = best
    covered = {c for c, _ in best_prefix}
    residual = tuple((c, v) for c, v in bound.items() if c not in covered)
    sorted_by = None
    if not residual:  # residual filters don't reorder, but sortedness on
        # the next column only holds when the prefix is exactly covered
        sorted_by = next_var(_INDEX_ORDERS[best_idx], len(best_prefix))
    takes: list[int] = []
    first: dict[str, int] = {}
    self_eq: list[tuple[int, int]] = []
    for posn, t in enumerate(atom.terms()):
        if isinstance(t, Var):
            if t.name in first:
                self_eq.append((first[t.name], posn))
            else:
                first[t.name] = posn
                takes.append(posn)
    return best_idx, best_prefix, residual, tuple(takes), tuple(self_eq), sorted_by


def range_cardinality(atom, prefix, stats) -> float:
    """Estimated size of the contiguous index range (prefix-bound only) —
    this, not the fully-filtered estimate, sizes the scan buffer."""
    covered = {c for c, _ in prefix}
    p = atom.p.id if (1 in covered and isinstance(atom.p, Const)) else None
    o_val = atom.o.id if (2 in covered and isinstance(atom.o, Const)) else None
    return stats.atom_card(s_bound=0 in covered, p=p, o_bound=2 in covered,
                           o_val=o_val)


def build_executor(plan: Plan, stats, view_infos: dict[int, "cost_mod.RelInfo"],
                   safety: float = 4.0, use_pallas: bool = False,
                   cap_override: Callable[[Plan, float], int] | None = None):
    """Compile a plan into `fn(tt_indexes, views) -> PRel`.

    `tt_indexes`: {"spo"|"pos"|"osp": (N,3) int32 device array}
    `views`: {view_id: PRel}
    `view_infos`: {view_id: cost.RelInfo} — extent cardinality + per-column
    distincts (estimated from the view CQ, or measured after
    materialization).  Buffer capacities are static, sized from the same
    estimates the quality function uses; join lead columns are chosen to
    minimize pre-residual expansion.
    """

    def cap_of(node: Plan, rows: float) -> int:
        if cap_override is not None:
            return cap_override(node, rows)
        return cost_mod.capacity_for(rows, safety=safety)

    def build(node: Plan, prefer_sorted: str | None = None
              ) -> tuple[Callable, tuple[str, ...], "cost_mod.RelInfo", str | None]:
        """returns (fn, cols, info, sorted_by)"""
        est = cost_mod.estimate_plan(node, stats, view_infos)
        if isinstance(node, TTScan):
            idx_name, prefix, residual, takes, self_eq, sorted_by = \
                atom_scan_spec(node.atom, prefer_sorted)
            cap = cap_of(node, range_cardinality(node.atom, prefix, stats))
            cols = node.columns()

            def run(tt, views, _f=functools.partial(
                    scan_pattern, prefix=prefix, residual=residual,
                    takes=takes, self_eq=self_eq, cap=cap), _idx=idx_name):
                return _f(tt[_idx])

            return run, cols, est.info, sorted_by
        if isinstance(node, ViewRef):
            def run(tt, views, _vid=node.view_id):
                return views[_vid]

            return run, node.schema, est.info, None
        if isinstance(node, Filter):
            child_fn, cols, _, sorted_by = build(node.child, prefer_sorted)
            ci = cols.index(node.col)

            def run(tt, views, _fn=child_fn, _ci=ci, _v=node.value):
                return filter_eq(_fn(tt, views), _ci, _v)

            # compact() is stable: filtering preserves row order
            return run, cols, est.info, sorted_by
        if isinstance(node, EquiJoin):
            if not node.pairs:
                raise NotImplementedError(
                    "cartesian products are not compiled to the device engine; "
                    "disconnected rewritings stay on the oracle path"
                )
            # pick the lead pair from static estimates, then build children
            # with the sort preference so scans can elide the join sort
            l_est = cost_mod.estimate_plan(node.left, stats, view_infos)
            r_est = cost_mod.estimate_plan(node.right, stats, view_infos)
            doms = [
                max(l_est.info.dcol(l), r_est.info.dcol(r))
                for l, r in node.pairs
            ]
            lead_k = max(range(len(doms)), key=lambda i: doms[i])
            lead_pair = node.pairs[lead_k]
            lf, lcols, linfo, _ = build(node.left)
            rf, rcols, rinfo, r_sorted_by = build(node.right, lead_pair[1])
            lead = (lcols.index(lead_pair[0]), rcols.index(lead_pair[1]))
            residual = tuple(
                (lcols.index(l), rcols.index(r))
                for k, (l, r) in enumerate(node.pairs) if k != lead_k
            )
            lead_rows = max(linfo.rows * rinfo.rows / doms[lead_k], 1e-3)
            drop = {r for _, r in node.pairs}
            keep_right = tuple(i for i, c in enumerate(rcols) if c not in drop)
            out_cols = lcols + tuple(c for c in rcols if c not in drop)
            cap = cap_of(node, lead_rows)
            r_presorted = r_sorted_by == lead_pair[1]

            def run(tt, views, _lf=lf, _rf=rf, _lead=lead, _res=residual,
                    _keep=keep_right, _cap=cap, _rs=r_presorted):
                return join(_lf(tt, views), _rf(tt, views), _lead[0], _lead[1],
                            _res, _keep, _cap, use_pallas=use_pallas,
                            right_sorted=_rs)

            # join output follows left row-major order: sorted by nothing
            # we track (expansion interleaves groups)
            return run, out_cols, est.info, None
        if isinstance(node, Project):
            child_fn, cols, _, sorted_by = build(node.child, prefer_sorted)
            idx = tuple(cols.index(c) for c in node.cols)
            out_sorted = sorted_by if (not node.dedupe and sorted_by in node.cols) \
                else (node.cols[0] if node.dedupe else None)

            def run(tt, views, _fn=child_fn, _idx=idx, _d=node.dedupe):
                return project(_fn(tt, views), _idx, _d)

            return run, node.cols, est.info, out_sorted
        raise TypeError(type(node))

    fn, cols, info, _ = build(plan)
    fn.out_columns = cols   # type: ignore[attr-defined]
    fn.est_rows = info.rows  # type: ignore[attr-defined]
    return fn


def tt_device_indexes(store) -> dict[str, jax.Array]:
    return {name: jnp.asarray(store.index(name)) for name in INDEX_NAMES}


def tt_device_indexes_padded(store, cap: int) -> dict[str, jax.Array]:
    """TT indexes padded with SENTINEL_HI rows to a fixed capacity class.

    Streaming maintenance re-uploads TT' every batch; padding to a class
    keeps every scan operand shape constant while the store grows, so
    appends never recompile the workload program.  Sentinel rows sort
    after every real id in all six orders, preserving binary-search
    semantics, and `scan_pattern` masks them out."""
    if cap < len(store):
        raise ValueError(
            f"tt capacity class {cap} < store size {len(store)}")
    out = {}
    for name in INDEX_NAMES:
        data = store.index(name)
        buf = np.full((cap, 3), np.iinfo(np.int32).max, dtype=np.int32)
        buf[: len(data)] = data
        out[name] = jnp.asarray(buf)
    return out
