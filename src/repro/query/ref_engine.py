"""Numpy oracle engine: dynamic-shape plan evaluation (ground truth).

Every JAX-engine and kernel result is checked against this module in the
test suite.  Also used to materialize view extents host-side.
"""
from __future__ import annotations

import numpy as np

from repro.core.queries import CQ, Const, Var
from repro.errors import InvariantViolation
from repro.query.plan import EquiJoin, Filter, Plan, Project, TTScan, ViewRef
from repro.rdf.triples import TripleStore


class Relation:
    """(rows, columns): rows is (n, w) int32, columns are variable names."""

    __slots__ = ("rows", "cols")

    def __init__(self, rows: np.ndarray, cols: tuple[str, ...]):
        rows = np.asarray(rows, dtype=np.int32)
        if cols:
            rows = rows.reshape(-1, len(cols))
        else:
            # 0-column relation: row COUNT still matters (boolean filter
            # semantics for fully-bound atoms)
            n = len(rows) if rows.ndim else 0
            rows = rows.reshape(n, 0)
        self.rows = rows
        self.cols = tuple(cols)

    def __len__(self) -> int:
        return len(self.rows)

    def col_index(self, name: str) -> int:
        return self.cols.index(name)

    def as_set(self) -> set[tuple[int, ...]]:
        return {tuple(r) for r in self.rows.tolist()}


def scan_atom(store: TripleStore, atom) -> Relation:
    s = atom.s.id if isinstance(atom.s, Const) else None
    p = atom.p.id if isinstance(atom.p, Const) else None
    o = atom.o.id if isinstance(atom.o, Const) else None
    matched = store.scan(s, p, o)
    # build output columns from variable positions (dedupe repeated vars)
    cols: list[str] = []
    takes: list[int] = []
    eq_pairs: list[tuple[int, int]] = []
    first_pos: dict[str, int] = {}
    for pos, t in enumerate(atom.terms()):
        if isinstance(t, Var):
            if t.name in first_pos:
                eq_pairs.append((first_pos[t.name], pos))
            else:
                first_pos[t.name] = pos
                cols.append(t.name)
                takes.append(pos)
    for a, b in eq_pairs:
        matched = matched[matched[:, a] == matched[:, b]]
    return Relation(matched[:, takes] if cols else matched[:, :0], tuple(cols))


def execute(plan: Plan, store: TripleStore | None,
            views: dict[int, Relation] | None = None) -> Relation:
    views = views or {}
    if isinstance(plan, TTScan):
        if store is None:
            raise InvariantViolation("TTScan requires a triple store")
        return scan_atom(store, plan.atom)
    if isinstance(plan, ViewRef):
        ext = views[plan.view_id]
        if ext.cols != plan.schema:
            # align by position (extent columns follow the view head order)
            if len(ext.cols) != len(plan.schema):
                raise InvariantViolation(
                    f"view {plan.view_id} extent arity {ext.cols} does not "
                    f"match reference schema {plan.schema}")
            return Relation(ext.rows, plan.schema)
        return ext
    if isinstance(plan, Filter):
        child = execute(plan.child, store, views)
        i = child.col_index(plan.col)
        return Relation(child.rows[child.rows[:, i] == plan.value], child.cols)
    if isinstance(plan, EquiJoin):
        left = execute(plan.left, store, views)
        right = execute(plan.right, store, views)
        return _join(left, right, plan.pairs)
    if isinstance(plan, Project):
        child = execute(plan.child, store, views)
        idx = [child.col_index(c) for c in plan.cols]
        rows = child.rows[:, idx]
        if plan.dedupe and len(rows):
            rows = np.unique(rows, axis=0)
        return Relation(rows, plan.cols)
    raise TypeError(type(plan))


def _join(left: Relation, right: Relation,
          pairs: tuple[tuple[str, str], ...]) -> Relation:
    rights_drop = {r for _, r in pairs}
    out_cols = left.cols + tuple(c for c in right.cols if c not in rights_drop)
    if len(left) == 0 or len(right) == 0:
        if not pairs:  # cartesian with empty side
            return Relation(np.zeros((0, len(out_cols)), np.int32), out_cols)
        return Relation(np.zeros((0, len(out_cols)), np.int32), out_cols)
    if not pairs:  # cartesian product
        li = np.repeat(np.arange(len(left)), len(right))
        ri = np.tile(np.arange(len(right)), len(left))
    else:
        lkey = np.stack([left.rows[:, left.col_index(l)] for l, _ in pairs], axis=1)
        rkey = np.stack([right.rows[:, right.col_index(r)] for _, r in pairs], axis=1)
        # hash join via python dict on tuple keys (oracle: clarity > speed)
        buckets: dict[tuple, list[int]] = {}
        for j, k in enumerate(map(tuple, rkey.tolist())):
            buckets.setdefault(k, []).append(j)
        li_l, ri_l = [], []
        for i, k in enumerate(map(tuple, lkey.tolist())):
            for j in buckets.get(k, ()):
                li_l.append(i)
                ri_l.append(j)
        li = np.array(li_l, dtype=np.int64)
        ri = np.array(ri_l, dtype=np.int64)
    keep_right = [i for i, c in enumerate(right.cols) if c not in rights_drop]
    rows = np.concatenate(
        [left.rows[li], right.rows[ri][:, keep_right]], axis=1
    ) if len(li) else np.zeros((0, len(out_cols)), np.int32)
    return Relation(rows, out_cols)


def evaluate_cq(cq: CQ, store: TripleStore) -> Relation:
    """Direct evaluation of a CQ over the triple table (oracle)."""
    from repro.query.plan import plan_for_cq

    return execute(plan_for_cq(cq), store)


def evaluate_ucq(cqs, store: TripleStore) -> set[tuple[int, ...]]:
    out: set[tuple[int, ...]] = set()
    for q in cqs:
        out |= evaluate_cq(q, store).as_set()
    return out
