"""Workload-level common-subexpression DAG over rewriting plans.

Rewritings of one workload overlap heavily: reformulation-group members
share triple-pattern scans, rewritings of different queries scan the
same views, and join subtrees recur across queries.  Per-query tree
compilation re-evaluates every shared fragment once per consumer; this
module hashes `Plan` subtrees across *all* workload rewritings into a
common-subexpression DAG so the physical compiler
(`query/workload.py`) computes each distinct fragment exactly once.

Canonicalization is *positional*: a subtree's key replaces plan-local
column names by structural ordinals (variables by first occurrence
inside an atom, operator arguments by column index in the child's
output).  Two subtrees that are equal up to a renaming of their columns
therefore intern to the same node, and because `Plan.columns()` order is
itself structure-determined, their outputs are positionally aligned —
every consumer can read the shared buffer through its own local names.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.core.queries import Const, Var
from repro.query.plan import (EquiJoin, Filter, Plan, Project, TTScan,
                              ViewRef, iter_subplans)


@dataclass(frozen=True)
class DagNode:
    """One shared physical operator.

    kind/spec are fully positional (no column names):
      scan:    spec = Atom (representative; variable names arbitrary)
      view:    spec = view_id
      filter:  spec = (child_col_idx, value)
      join:    spec = ((left_idx, right_idx), ...) equality pairs
      project: spec = (child_col_idxs, dedupe)
    """

    id: int
    kind: str
    spec: object
    child_ids: tuple[int, ...]
    width: int
    key: tuple
    plan: Plan  # representative subtree (first interned); for debugging


def derived_width(kind: str, spec, child_widths: tuple[int, ...]) -> int:
    """Output width an operator MUST have, derived from its spec and its
    children's widths — the single source of truth shared by the interner
    and the static IR verifier (`repro.analysis.ir_verifier`).  `view`
    widths are not derivable from the spec (a view id); callers check
    those against the representative plan's schema instead."""
    if kind == "scan":
        return len(TTScan(spec).columns())
    if kind == "filter":
        return child_widths[0]
    if kind == "join":
        drop = {r for _, r in spec}
        return child_widths[0] + sum(
            1 for i in range(child_widths[1]) if i not in drop)
    if kind == "project":
        idxs, _dedupe = spec
        return len(idxs)
    raise TypeError(kind)


def _atom_key(atom) -> tuple:
    """Renaming-invariant atom encoding: constants by id, variables by
    first-occurrence ordinal (captures self-join positions)."""
    rename: dict[str, int] = {}
    enc = []
    for t in atom.terms():
        if isinstance(t, Const):
            enc.append(("c", t.id))
        else:
            if t.name not in rename:
                rename[t.name] = len(rename)
            enc.append(("v", rename[t.name]))
    return tuple(enc)


class WorkloadDAG:
    """Interned plan forest: every distinct subtree is one node; roots
    map workload member names to their rewriting's top node."""

    def __init__(self) -> None:
        self.nodes: list[DagNode] = []
        self.roots: dict[str, int] = {}
        self._by_key: dict[tuple, int] = {}
        self.consumers: dict[int, int] = {}  # node id -> consumer edges
        self.intern_hits = 0  # subtree evaluations avoided by sharing

    # ------------------------------------------------------------------
    def intern(self, plan: Plan) -> int:
        if isinstance(plan, TTScan):
            key = ("scan", _atom_key(plan.atom))
            return self._get_or_add(key, "scan", plan.atom, (),
                                    len(plan.columns()), plan)
        if isinstance(plan, ViewRef):
            key = ("view", plan.view_id)
            return self._get_or_add(key, "view", plan.view_id, (),
                                    len(plan.schema), plan)
        if isinstance(plan, Filter):
            cid = self.intern(plan.child)
            ci = plan.child.columns().index(plan.col)
            key = ("filter", cid, ci, plan.value)
            return self._get_or_add(key, "filter", (ci, plan.value), (cid,),
                                    self.nodes[cid].width, plan)
        if isinstance(plan, EquiJoin):
            if not plan.pairs:
                raise NotImplementedError(
                    "cartesian products are not compiled to the device "
                    "engine; disconnected rewritings stay on the oracle path"
                )
            lid = self.intern(plan.left)
            rid = self.intern(plan.right)
            lcols = plan.left.columns()
            rcols = plan.right.columns()
            pairs = tuple((lcols.index(l), rcols.index(r))
                          for l, r in plan.pairs)
            # pair order never changes the output relation, so sort it out
            # of the key (the spec keeps the original order for lead choice)
            key = ("join", lid, rid, tuple(sorted(pairs)))
            width = derived_width(
                "join", pairs,
                (self.nodes[lid].width, self.nodes[rid].width))
            return self._get_or_add(key, "join", pairs, (lid, rid), width, plan)
        if isinstance(plan, Project):
            cid = self.intern(plan.child)
            ccols = plan.child.columns()
            idxs = tuple(ccols.index(c) for c in plan.cols)
            key = ("project", cid, idxs, plan.dedupe)
            return self._get_or_add(key, "project", (idxs, plan.dedupe),
                                    (cid,), len(idxs), plan)
        raise TypeError(type(plan))

    def _get_or_add(self, key: tuple, kind: str, spec, child_ids: tuple,
                    width: int, plan: Plan) -> int:
        nid = self._by_key.get(key)
        if nid is not None:
            self.intern_hits += 1
            return nid
        nid = len(self.nodes)
        self.nodes.append(DagNode(nid, kind, spec, child_ids, width, key, plan))
        self._by_key[key] = nid
        self.consumers.setdefault(nid, 0)
        for c in child_ids:
            self.consumers[c] = self.consumers.get(c, 0) + 1
        return nid

    def add_root(self, name: str, plan: Plan) -> int:
        nid = self.intern(plan)
        self.roots[name] = nid
        self.consumers[nid] = self.consumers.get(nid, 0) + 1
        return nid

    # ------------------------------------------------------------------
    # stable identity across DAGs
    # ------------------------------------------------------------------
    def content_keys(self) -> list[tuple]:
        """One fully-recursive canonical key per node, stable across DAG
        instances: unlike `DagNode.key` (which embeds DAG-local child
        *ids*), a content key embeds the children's content keys, so the
        same logical subtree built in two different workload DAGs — e.g.
        before and after a `swap_state` hot swap — maps to the same key.
        Used to carry learned buffer capacities across program rebuilds.
        """
        out: list[tuple] = []
        for node in self.nodes:
            if node.kind == "scan":
                out.append(("scan", _atom_key(node.spec)))
            elif node.kind == "view":
                out.append(("view", node.spec))
            elif node.kind == "filter":
                out.append(("filter", node.spec, out[node.child_ids[0]]))
            elif node.kind == "join":
                out.append(("join", tuple(sorted(node.spec)),
                            out[node.child_ids[0]], out[node.child_ids[1]]))
            elif node.kind == "project":
                out.append(("project", node.spec, out[node.child_ids[0]]))
            else:
                raise TypeError(node.kind)
        return out

    # ------------------------------------------------------------------
    # sharing telemetry
    # ------------------------------------------------------------------
    def shared_node_ids(self) -> list[int]:
        """Nodes with more than one consumer (computed once, read many)."""
        return [nid for nid, c in self.consumers.items() if c >= 2]

    @property
    def node_reuse_count(self) -> int:
        """Consumer edges saved by sharing: sum over nodes of
        (consumers - 1); equals the number of subtree evaluations a
        per-query compiler would perform beyond the DAG's."""
        return sum(c - 1 for c in self.consumers.values() if c >= 2)

    def tree_node_count(self) -> int:
        """Total operator count if every root were compiled as a tree."""
        return sum(
            sum(1 for _ in iter_subplans(self.nodes[nid].plan))
            for nid in self.roots.values()
        )

    def stats(self) -> dict:
        tree = self.tree_node_count()
        return {
            "dag_nodes": len(self.nodes),
            "tree_nodes": tree,
            "shared_nodes": len(self.shared_node_ids()),
            "node_reuse_count": self.node_reuse_count,
            "hit_rate": 1.0 - len(self.nodes) / max(tree, 1),
        }


def build_dag(rewritings: dict[str, Plan]) -> WorkloadDAG:
    """Canonicalize every rewriting of the workload into one shared DAG.

    Member names are interned in sorted order so the node numbering (and
    therefore capacity planning and compiled programs) is deterministic.
    """
    dag = WorkloadDAG()
    for name in sorted(rewritings):
        dag.add_root(name, rewritings[name])
    return dag
