"""Distributed query engine on a multi-device CPU mesh.

Runs in a subprocess with XLA_FLAGS=--xla_force_host_platform_device_count=8
so the rest of the suite keeps a single device.
"""
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
import jax

from repro.core.queries import CQ, Atom, Const, Var
from repro.launch.mesh import make_mesh
from repro.query import distributed as D
from repro.query import ref_engine as R
from repro.query.cost import RelInfo
from repro.query.plan import plan_for_cq, ViewRef, EquiJoin, Project
from repro.rdf.generator import generate, lubm_workload

uni = generate(n_universities=2, seed=0)
mesh = make_mesh((8,), ("data",))
tt = D.shard_store_by_subject(uni.store, mesh)

# 1) every workload query: distributed == oracle
for q in lubm_workload(uni.dictionary):
    plan = plan_for_cq(q)
    fn = D.build_distributed_executor(plan, uni.store.stats, {}, mesh)
    out = jax.jit(fn)(tt, {})
    assert not bool(np.asarray(out.overflow).any()), f"{q.name} overflowed"
    got = {tuple(r) for r in D.gather_result(out).tolist()}
    want = R.evaluate_cq(q, uni.store).as_set()
    assert got == want, f"{q.name}: {len(got)} vs {len(want)}"
print("workload ok")

# 2) distributed join over sharded view extents (with repartition)
d = uni.dictionary
takes = Const(d.lookup("ub:takesCourse"))
teach = Const(d.lookup("ub:teacherOf"))
x, y, z = Var("x"), Var("y"), Var("z")
cq_a = CQ((x, y), (Atom(x, takes, y),), name="va")
cq_b = CQ((z, y), (Atom(z, teach, y),), name="vb")
ext_a = R.evaluate_cq(cq_a, uni.store)
ext_b = R.evaluate_cq(cq_b, uni.store)
# extent A sharded by x (subject), extent B sharded by z (subject):
# the join on y requires repartition of both sides
views = {
    0: D.shard_prel_rows(ext_a.rows, 0, mesh),
    1: D.shard_prel_rows(ext_b.rows, 0, mesh),
}
infos = {
    0: RelInfo(float(len(ext_a.rows)), {"x": 300.0, "y": 60.0}),
    1: RelInfo(float(len(ext_b.rows)), {"z": 40.0, "y": 60.0}),
}
plan = Project(
    EquiJoin(ViewRef(0, ("x", "y")), ViewRef(1, ("z", "y")), (("y", "y"),)),
    ("x", "z"),
)
fn = D.build_distributed_executor(plan, uni.store.stats, infos, mesh,
                                  partition_cols={0: "x", 1: "z"})
out = jax.jit(fn)(tt, views)
assert not bool(np.asarray(out.overflow).any())
got = {tuple(r) for r in D.gather_result(out).tolist()}
want = R.execute(plan, uni.store, {0: ext_a, 1: ext_b}).as_set()
assert got == want, f"dist view join: {len(got)} vs {len(want)}"
print("view join ok")

# 3) co-partition elision: joining two subject-sharded views on the
# subject column must not change answers (and skips the all_to_all)
cq_c = CQ((x, y), (Atom(x, Const(d.lookup("ub:memberOf")), y),), name="vc")
ext_c = R.evaluate_cq(cq_c, uni.store)
views2 = {
    0: D.shard_prel_rows(ext_a.rows, 0, mesh),
    1: D.shard_prel_rows(ext_c.rows, 0, mesh),
}
infos2 = {
    0: RelInfo(float(len(ext_a.rows)), {"x": 300.0, "y": 60.0}),
    1: RelInfo(float(len(ext_c.rows)), {"x": 300.0, "y": 6.0}),
}
plan2 = EquiJoin(ViewRef(0, ("x", "y")), ViewRef(1, ("x", "w")), (("x", "x"),))
fn2 = D.build_distributed_executor(plan2, uni.store.stats, infos2, mesh,
                                   partition_cols={0: "x", 1: "x"})
lowered = jax.jit(fn2).lower(tt, views2)
hlo = lowered.as_text()
assert "all-to-all" not in hlo, "co-partitioned join must elide all_to_all"
out2 = jax.jit(fn2)(tt, views2)
got2 = {tuple(r) for r in D.gather_result(out2).tolist()}
want2 = R.execute(plan2, uni.store, {0: ext_a, 1: ext_c}).as_set()
assert got2 == want2
print("copartition ok")
"""


def test_distributed_query_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    res = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                         text=True, env=env, cwd=os.path.dirname(os.path.dirname(__file__)))
    assert res.returncode == 0, f"STDOUT:\n{res.stdout}\nSTDERR:\n{res.stderr}"
    assert "workload ok" in res.stdout
    assert "view join ok" in res.stdout
    assert "copartition ok" in res.stdout
