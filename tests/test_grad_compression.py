"""Distributed-optimization knobs: bf16 gradient reduction and compressed
Adam moments keep training stable and close to the fp32 reference."""
import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.models.model import build_model
from repro.train.optimizer import OptConfig
from repro.train.train_step import TrainConfig, init_train_state, make_train_step


def _batch(cfg, seed=0):
    rng = np.random.default_rng(seed)
    toks = jnp.asarray(rng.integers(8, cfg.vocab, size=(4, 16)).astype(np.int32))
    return {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}


def test_bf16_gradient_reduction_tracks_fp32():
    cfg = get_smoke_config("granite-20b")
    model = build_model(cfg)
    batch = _batch(cfg)
    ref_tc = TrainConfig(opt=OptConfig(lr=1e-3), remat="none")
    cmp_tc = TrainConfig(opt=OptConfig(lr=1e-3), remat="none",
                         grad_dtype=jnp.bfloat16)
    state = init_train_state(model, ref_tc, jax.random.key(0))
    s_ref, m_ref = jax.jit(make_train_step(model, ref_tc))(state, batch)
    s_cmp, m_cmp = jax.jit(make_train_step(model, cmp_tc))(state, batch)
    assert abs(float(m_ref["loss"]) - float(m_cmp["loss"])) < 1e-5
    # parameters after one step stay close (bf16 grads ~1e-2 relative)
    for a, b in zip(jax.tree.leaves(s_ref["params"]),
                    jax.tree.leaves(s_cmp["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=0.05, atol=5e-4)


def test_bf16_moments_training_stable():
    cfg = get_smoke_config("rwkv6-3b")
    model = build_model(cfg)
    tc = TrainConfig(opt=OptConfig(lr=1e-3, m_dtype=jnp.bfloat16,
                                   v_dtype=jnp.bfloat16), remat="none")
    state = init_train_state(model, tc, jax.random.key(1))
    assert state["opt"]["m"]["embed"].dtype == jnp.bfloat16
    step = jax.jit(make_train_step(model, tc))
    batch = _batch(cfg, seed=1)
    losses = []
    for _ in range(10):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert all(np.isfinite(losses))
    assert losses[-1] < losses[0]
