"""Async serving frontend: virtual-clock micro-batching, admission
control, per-shard health rollup, and the open-loop load generator.

Everything here runs on the VIRTUAL clock with stub servers — no
time.sleep, no wall-time dependence — and is deterministic under a
fixed seed (the hypothesis property test and the loadgen twin pin it).
"""
from types import SimpleNamespace

import pytest

from repro.distributed.fault import (DEGRADED, DOWN, HEALTHY, STALE_ONLY,
                                     ServingSupervisor)
from repro.serve.frontend import (BEST_EFFORT, FixedServiceModel,
                                  FrontendConfig, QueryClass, ServingFrontend,
                                  VirtualClock)
from repro.serve.loadgen import (ClassSpec, TrafficConfig, generate_schedule,
                                 run_open_loop)


class StubServer:
    """Duck-typed batched server: records batches, applies a fake
    update backlog inside answer_batch (like QueryServer._refresh)."""

    def __init__(self):
        self.stats = SimpleNamespace(updates_applied=0, frontend={})
        self.batches: list[list[str]] = []
        self._pending = 0

    def answer_batch(self, names):
        self.batches.append(list(names))
        self.stats.updates_applied += self._pending
        self._pending = 0
        return [set() for _ in names]

    def submit(self, inserts=None, deletes=None):
        self._pending += len(inserts or [])

    def readiness(self):
        return {"ready": True, "health": "HEALTHY"}


def make_frontend(classes=None, server=None, **cfg):
    cfg.setdefault("queue_cap", 8)
    cfg.setdefault("batching_window", 0.01)
    cfg.setdefault("max_batch", 4)
    model = cfg.pop("service_model", FixedServiceModel(0.01, 0.01))
    fe = ServingFrontend(
        server or StubServer(),
        classes or [QueryClass("c")],
        FrontendConfig(**cfg),
        clock=VirtualClock(),
        service_model=model)
    return fe


class RecordingFrontend(ServingFrontend):
    """Keeps completed Request objects so tests can inspect per-request
    arrival/dispatch/finish times."""

    def __init__(self, *a, **k):
        super().__init__(*a, **k)
        self.records = []

    def _complete_inflight(self):
        self.records.extend(self._inflight)
        super()._complete_inflight()


# ----------------------------------------------------------------------
# deterministic twin: one schedule's exact batch boundaries
# ----------------------------------------------------------------------
def test_batch_boundaries_pinned():
    fe = make_frontend()
    # 4 arrivals fill the batch at t=0.003 -> immediate dispatch
    for i, t in enumerate((0.000, 0.001, 0.002, 0.003)):
        assert fe.offer("q", t=t)
    # two stragglers queue behind the in-flight batch
    assert fe.offer("q", t=0.010)
    assert fe.offer("q", t=0.030)
    end = fe.flush()
    # batch 1: full at 0.003, service 0.01 + 4*0.01 = 0.05 -> done 0.053
    # batch 2: dispatches the moment the server frees (0.053; its window
    # deadline 0.020 already passed), service 0.03 -> done 0.083
    assert fe.batch_log == [(pytest.approx(0.003), 4),
                            (pytest.approx(0.053), 2)]
    assert end == pytest.approx(0.083)
    rec = fe.stats.latency["c"]
    assert rec.count == 6
    assert rec.worst == pytest.approx(0.083 - 0.010)
    assert fe.stats.batch_occupancy == pytest.approx(3.0)
    assert fe.stats.completed == 6 and fe.stats.shed == 0


def test_partial_batch_waits_out_the_window():
    fe = make_frontend()
    fe.offer("q", t=0.0)
    fe.advance_to(0.005)
    assert fe.stats.batches == 0          # window not yet elapsed
    fe.advance_to(0.02)
    assert fe.batch_log == [(pytest.approx(0.01), 1)]


def test_virtual_clock_never_runs_backwards():
    from repro.errors import InvariantViolation

    clock = VirtualClock(5.0)
    with pytest.raises(InvariantViolation):
        clock.advance_to(4.0)
    fe = make_frontend()
    fe.offer("q", t=1.0)
    with pytest.raises(InvariantViolation):
        fe.offer("q", t=0.5)


# ----------------------------------------------------------------------
# hypothesis property: the micro-batcher's wait bound
# ----------------------------------------------------------------------
def test_wait_bound_property():
    """With queue_cap <= max_batch, every dispatched request waits at
    most batching_window + max_batch_service_time from arrival: the
    whole queue fits in one dispatch, so a request is dispatched no
    later than one window plus one full batch service after arriving."""
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    window, base, per_req, max_batch = 0.01, 0.005, 0.002, 4
    s_max = base + per_req * max_batch

    @hyp.settings(deadline=None, max_examples=60)
    @hyp.given(gaps=st.lists(st.floats(0.0, 0.05, allow_nan=False),
                             min_size=0, max_size=60))
    def run(gaps):
        fe = RecordingFrontend(
            StubServer(), [QueryClass("c")],
            FrontendConfig(queue_cap=max_batch, batching_window=window,
                           max_batch=max_batch, admission="none"),
            clock=VirtualClock(),
            service_model=FixedServiceModel(base, per_req))
        t = 0.0
        for g in gaps:
            t += g
            fe.offer("q", t=t)
        fe.flush()
        for r in fe.records:
            wait = r.dispatch - r.arrival
            assert wait <= window + s_max + 1e-9, \
                f"request waited {wait} > {window + s_max}"

    run()


# ----------------------------------------------------------------------
# admission control
# ----------------------------------------------------------------------
def test_slo_admission_sheds_when_estimate_breaches():
    fe = make_frontend(
        classes=[QueryClass("gold", priority=1, slo=0.05)],
        batching_window=0.0, max_batch=1, queue_cap=10,
        service_model=FixedServiceModel(0.02, 0.0))
    assert fe.offer("q", "gold", t=0.0)      # est 0.02 <= 0.05, dispatches
    assert fe.offer("q", "gold", t=0.0)      # est 0.04 <= 0.05, queues
    assert not fe.offer("q", "gold", t=0.0)  # est 0.06 > 0.05 -> shed
    assert fe.stats.shed == 1
    assert fe.stats.shed_by_class == {"gold": 1}
    fe.flush()
    assert fe.stats.completed == 2


def test_downgrade_mode_reroutes_to_best_effort():
    fe = make_frontend(
        classes=[QueryClass("gold", priority=1, slo=0.05)],
        batching_window=0.0, max_batch=1, queue_cap=10,
        admission="downgrade",
        service_model=FixedServiceModel(0.02, 0.0))
    assert BEST_EFFORT in fe.classes         # auto-created floor class
    fe.offer("q", "gold", t=0.0)
    fe.offer("q", "gold", t=0.0)
    assert fe.offer("q", "gold", t=0.0)      # admitted, downgraded
    assert fe.stats.shed == 0
    assert fe.stats.downgraded == 1
    assert fe.stats.downgraded_by_class == {"gold": 1}
    fe.flush()
    assert fe.stats.latency[BEST_EFFORT].count == 1
    assert fe.stats.latency["gold"].count == 2


def test_full_queue_evicts_lower_priority_for_higher():
    fe = make_frontend(
        classes=[QueryClass("gold", priority=2), QueryClass("bulk")],
        batching_window=0.0, max_batch=2, queue_cap=2,
        service_model=FixedServiceModel(0.05, 0.0))
    srv = fe.server
    fe.offer("a", "bulk", t=0.000)           # dispatches alone; busy 0.05
    fe.offer("b", "bulk", t=0.001)
    fe.offer("c", "bulk", t=0.002)           # queue now full (cap 2)
    assert not fe.offer("d", "bulk", t=0.003)  # same priority: shed at door
    assert fe.offer("g", "gold", t=0.004)    # evicts the newest bulk (c)
    assert fe.stats.evicted == 1
    assert fe.stats.shed_by_class == {"bulk": 2}   # d at door + c evicted
    fe.flush()
    # gold rode the next batch ahead of the surviving bulk request
    assert srv.batches[1] == ["g", "b"]
    assert fe.stats.completed == 3


def test_queue_bound_is_hard_without_admission():
    fe = make_frontend(batching_window=5.0, max_batch=100, queue_cap=3,
                       admission="none")
    # first 3 fill the cap and dispatch as one batch (a cap-full queue
    # cannot grow, so it never waits out the window); next 3 queue
    # behind the in-flight batch; the rest hit the hard bound
    admitted = [fe.offer("q", t=0.0) for _ in range(10)]
    assert admitted.count(True) == 6 and fe.stats.shed == 4
    assert fe.stats.max_queue_depth == 3
    fe.flush()
    assert fe.stats.completed == 6


def test_priority_dispatch_orders_batches():
    fe = make_frontend(
        classes=[QueryClass("gold", priority=2), QueryClass("bulk")],
        batching_window=0.0, max_batch=2, queue_cap=8,
        service_model=FixedServiceModel(0.05, 0.0))
    srv = fe.server
    fe.offer("a", "bulk", t=0.000)           # dispatches alone; busy
    fe.offer("b", "bulk", t=0.001)
    fe.offer("c", "bulk", t=0.002)
    fe.offer("d", "bulk", t=0.003)
    fe.offer("g", "gold", t=0.004)           # arrives last, dispatches next
    fe.flush()
    assert srv.batches[1] == ["g", "b"]
    assert srv.batches[2] == ["c", "d"]


# ----------------------------------------------------------------------
# update stream passthrough: maintenance backpressure in latency
# ----------------------------------------------------------------------
def test_update_backlog_stretches_batch_service():
    model = FixedServiceModel(0.01, 0.0, per_maint_triple=0.001)
    fe = make_frontend(batching_window=0.0, max_batch=1, queue_cap=4,
                       service_model=model)
    fe.offer("q", t=0.0)
    fe.flush()
    clean = fe.stats.latency["c"].worst
    fe.submit_update(inserts=[(1, 2, 3)] * 20, t=1.0)
    assert fe.stats.updates_submitted == 1
    fe.offer("q", t=1.0)
    fe.flush()
    # the drained 20-triple backlog cost 20 * 0.001 extra virtual time
    assert fe.stats.latency["c"].worst == pytest.approx(clean + 0.02)


def test_telemetry_mirrors_into_server_stats_and_readiness():
    fe = make_frontend()
    fe.offer("q", t=0.0)
    fe.flush()
    mirrored = fe.server.stats.frontend
    assert mirrored["completed"] == 1 and mirrored["latency"]["c"]["count"] == 1
    probe = fe.readiness()
    assert probe["ready"] and probe["queue_depth"] == 0
    assert probe["virtual_time"] == fe.clock.now()


# ----------------------------------------------------------------------
# per-shard health rollup (distributed/fault.py)
# ----------------------------------------------------------------------
def test_one_degraded_shard_rolls_up_degraded_not_down():
    sup = ServingSupervisor()
    for d in range(4):
        sup.observe_shard(d, 0)
    assert sup.rollup() == HEALTHY
    sup.observe_shard(2, 2)                  # host-fallback tier
    assert sup.worst() == DEGRADED
    assert sup.quorum()
    assert sup.rollup() == DEGRADED          # NOT DOWN
    sup.observe_shard(2, 0)                  # shard restored
    assert sup.rollup() == HEALTHY


def test_quorum_loss_degrades_to_stale_then_down():
    sup = ServingSupervisor()
    for d in range(4):
        sup.observe_shard(d, None)           # all shards unservable
    assert sup.worst() == DOWN and not sup.quorum()
    assert sup.rollup() == DOWN
    sup.observe_shard(0, 3)                  # one shard: stale cache only
    assert sup.rollup() == STALE_ONLY
    # two exact shards of four is NOT a strict majority yet
    sup.observe_shard(1, 1)
    sup.observe_shard(2, 1)
    assert not sup.quorum() and sup.rollup() == STALE_ONLY
    # third exact shard restores the quorum -> DEGRADED
    sup.observe_shard(3, 1)
    assert sup.quorum() and sup.rollup() == DEGRADED
    assert sup.quorum(minimum=4) is False


def test_empty_shard_map_is_healthy():
    sup = ServingSupervisor()
    assert sup.worst() == HEALTHY and sup.quorum()


# ----------------------------------------------------------------------
# load generator
# ----------------------------------------------------------------------
CLASSES = (ClassSpec("gold", 0.2, ("q1", "q2"), priority=2, slo=0.05),
           ClassSpec("bulk", 0.8, ("q3", "q4"), priority=0, slo=1.0))


def loaded_frontend(admission="shed", priority_dispatch=True,
                    queue_cap=64):
    return ServingFrontend(
        StubServer(),
        [QueryClass(c.name, priority=c.priority, slo=c.slo)
         for c in CLASSES],
        FrontendConfig(queue_cap=queue_cap, batching_window=0.005,
                       max_batch=16, admission=admission,
                       priority_dispatch=priority_dispatch),
        clock=VirtualClock(),
        service_model=FixedServiceModel(0.004, 0.001))


def test_schedule_is_deterministic_and_open_loop():
    cfg = TrafficConfig(rate=500.0, duration=1.0, classes=CLASSES, seed=3,
                        update_rate=20.0, update_size=5)
    s1, s2 = generate_schedule(cfg), generate_schedule(cfg)
    assert s1 == s2
    assert generate_schedule(
        TrafficConfig(rate=500.0, duration=1.0, classes=CLASSES,
                      seed=4)) != s1
    ts = [a.t for a in s1]
    assert ts == sorted(ts) and ts[-1] < 1.0
    kinds = {a.kind for a in s1}
    assert kinds == {"query", "update"}
    # open loop: arrival count tracks rate, not server speed
    nq = sum(a.kind == "query" for a in s1)
    assert 400 < nq < 600


def test_overload_admission_holds_top_class_slo():
    """The BENCH_serve acceptance story, miniature: under ~1.5x offered
    overload, admission control sheds load and keeps the gold p99 SLO;
    the no-admission FIFO baseline breaches it."""
    cfg = TrafficConfig(rate=1200.0, duration=1.5, classes=CLASSES, seed=7)
    adm = run_open_loop(loaded_frontend(), cfg)
    base = run_open_loop(
        loaded_frontend(admission="none", priority_dispatch=False,
                        queue_cap=1 << 16), cfg)
    assert adm.shed_rate > 0
    assert adm.per_class["gold"].slo_met is True
    assert base.shed_rate == 0
    assert base.per_class["gold"].slo_met is False
    # determinism: same seed, same report
    again = run_open_loop(loaded_frontend(), cfg)
    assert again.as_dict() == adm.as_dict()


def test_update_events_flow_to_server():
    cfg = TrafficConfig(rate=100.0, duration=0.5, classes=CLASSES, seed=1,
                        update_rate=30.0, update_size=4)
    fe = loaded_frontend()
    rep = run_open_loop(
        fe, cfg, update_fn=lambda rng: ([(1, 2, 3)] * 4, None))
    assert fe.stats.updates_submitted > 0
    assert rep.completed == fe.stats.completed > 0


# ----------------------------------------------------------------------
# API integration: TuningSession.serve_async over a real executor
# ----------------------------------------------------------------------
@pytest.fixture(scope="module")
def tuned_session():
    from repro.api import SearchConfig, TuningSession, WizardConfig
    from repro.rdf.generator import generate, lubm_workload

    uni = generate(n_universities=1, seed=0, dept_per_univ=2,
                   prof_per_dept=4, stud_per_dept=12, course_per_dept=5)
    wl = lubm_workload(uni.dictionary)[:4]
    s = TuningSession(uni.store, wl, schema=uni.schema, type_id=uni.type_id,
                      cfg=WizardConfig(search=SearchConfig(
                          strategy="greedy", max_states=60)))
    s.retune()
    s.apply()
    return s


def test_serve_async_answers_match_session(tuned_session):
    s = tuned_session
    fe = s.serve_async(
        classes=[QueryClass("gold", priority=1, slo=10.0),
                 QueryClass("bulk")],
        frontend=FrontendConfig(queue_cap=16, batching_window=0.005,
                                max_batch=8),
        service_model=FixedServiceModel(0.002, 0.0005))
    names = [q.name for q in s.workload]
    for i, n in enumerate(names * 2):
        fe.offer(n, "gold" if i % 2 else "bulk", t=i * 0.001)
    fe.flush()
    assert fe.stats.completed == len(names) * 2
    # the mirrored summary rides the real ServeStats + readiness probe
    assert fe.server.stats.frontend["completed"] == len(names) * 2
    probe = fe.server.readiness()
    assert probe["ready"] and "frontend" in probe
    assert fe.readiness()["health"] == "HEALTHY"
    # answers through the frontend's server match direct session answers
    got = fe.server.answer_batch(names)
    assert got == [s.answer(n) for n in names]


def test_serve_async_sharded_rejects_maintenance(tuned_session):
    with pytest.raises(ValueError, match="static-store"):
        tuned_session.serve_async(sharded=True, maintenance=True)
