"""Tests for the RDF substrate: dictionary, triple store, schema, parsers."""
import numpy as np
import pytest

from repro.rdf.dictionary import Dictionary, RDF_TYPE
from repro.rdf.generator import generate, lubm_workload
from repro.rdf.parser import parse_ntriples, parse_sparql
from repro.rdf.schema import RDFSchema
from repro.rdf.triples import TripleStore


def test_dictionary_roundtrip(tmp_path):
    d = Dictionary()
    ids = [d.encode(s) for s in ["a", "b", "a", "c"]]
    assert ids == [0, 1, 0, 2]
    assert d.decode(1) == "b"
    assert len(d) == 3
    p = tmp_path / "dict.json"
    d.save(str(p))
    d2 = Dictionary.load(str(p))
    assert d2.lookup("c") == 2


def test_triple_store_dedupe_and_scan():
    t = np.array([[0, 1, 2], [0, 1, 2], [0, 1, 3], [4, 1, 2], [4, 5, 6]], np.int32)
    ts = TripleStore(t)
    assert len(ts) == 4
    assert len(ts.scan(0, 1, None)) == 2
    assert len(ts.scan(None, 1, 2)) == 2
    assert len(ts.scan(None, None, None)) == 4
    assert len(ts.scan(4, None, None)) == 2
    assert len(ts.scan(0, 1, 3)) == 1
    assert len(ts.scan(9, None, None)) == 0


def test_triple_store_indexes_sorted():
    rng = np.random.default_rng(0)
    t = rng.integers(0, 50, size=(300, 3)).astype(np.int32)
    ts = TripleStore(t)
    for name, cols in [("spo", (0, 1, 2)), ("pos", (1, 2, 0)), ("osp", (2, 0, 1))]:
        data = ts.index(name)[:, cols]
        keys = data[:, 0].astype(np.int64) * 10**6 + data[:, 1] * 10**3 + data[:, 2]
        assert np.all(np.diff(keys) >= 0), name


def test_scan_matches_bruteforce():
    rng = np.random.default_rng(1)
    t = rng.integers(0, 20, size=(500, 3)).astype(np.int32)
    ts = TripleStore(t)
    uniq = ts.triples
    for s, p, o in [(3, None, None), (None, 7, None), (None, None, 11),
                    (3, 7, None), (None, 7, 11), (3, None, 11), (3, 7, 11)]:
        got = ts.scan(s, p, o)
        mask = np.ones(len(uniq), bool)
        if s is not None:
            mask &= uniq[:, 0] == s
        if p is not None:
            mask &= uniq[:, 1] == p
        if o is not None:
            mask &= uniq[:, 2] == o
        want = uniq[mask]
        assert {tuple(r) for r in got.tolist()} == {tuple(r) for r in want.tolist()}


def test_schema_closure():
    sch = RDFSchema()
    sch.add_subclass(1, 2)
    sch.add_subclass(2, 3)
    sch.add_subclass(4, 3)
    assert sch.superclasses(1) == {1, 2, 3}
    assert sch.subclasses(3) == {1, 2, 3, 4}
    sch.add_subprop(10, 11)
    assert sch.subproperties(11) == {10, 11}
    sch.set_domain(10, 2)
    assert sch.props_with_domain_under(3) == {10}
    assert sch.props_with_domain_under(1) == set()


def test_schema_saturation():
    sch = RDFSchema()
    TYPE = 0
    sch.add_subclass(1, 2)
    sch.set_domain(5, 1)
    triples = np.array([[100, 5, 200]], np.int32)
    sat = sch.saturate_instance(triples, TYPE)
    got = {tuple(r) for r in sat.tolist()}
    assert (100, TYPE, 1) in got
    assert (100, TYPE, 2) in got  # via subclass of inferred type


def test_generator_and_workload():
    uni = generate(n_universities=1, seed=0)
    assert len(uni.store) > 100
    qs = lubm_workload(uni.dictionary)
    assert len(qs) == 6
    names = {q.name for q in qs}
    assert names == {"q1", "q2", "q3", "q4", "q5", "q6"}
    for q in qs:
        assert q.is_connected()
        assert q.weight > 0


def test_sparql_parser():
    d = Dictionary()
    q = parse_sparql(
        "SELECT ?x ?y WHERE { ?x rdf:type ub:Student . ?x ub:takesCourse ?y }",
        d, name="p1",
    )
    assert len(q.atoms) == 2
    assert [h.name for h in q.head] == ["x", "y"]
    assert q.atoms[0].p.id == d.lookup(RDF_TYPE)

    with pytest.raises(Exception):
        parse_sparql("SELECT ?x WHERE { ?x ?p }", d)


def test_ntriples_parser():
    d = Dictionary()
    arr = parse_ntriples("<a> <p> <b> .\n<b> <p> \"lit\" .", d)
    assert arr.shape == (2, 3)
    assert arr[0, 1] == arr[1, 1]
