"""Sharded serving backend on a multi-device CPU mesh.

Runs in a subprocess with XLA_FLAGS=--xla_force_host_platform_device_count=8
so the rest of the suite keeps a single device.  Covers:

  * exact answers through the SPMD backend (== host reference engine),
  * one corrupted shard -> per-shard DEGRADED + host fallback, answers
    stay exact, rollup never reports whole-server DOWN,
  * restore -> HEALTHY again,
  * the async frontend over the sharded backend (serve_async(sharded=True)),
  * empty-shard regressions: ndev > distinct subjects, fully empty
    stores, and degenerate empty extents all produce valid zero-row
    sorted indexes instead of crashing downstream searchsorted.
"""
import os
import subprocess
import sys

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
import jax

from repro.api import SearchConfig, TuningSession, WizardConfig, QueryClass
from repro.core.queries import CQ, Atom, Const, Var
from repro.launch.mesh import make_mesh
from repro.query import distributed as D
from repro.query import ref_engine as R
from repro.query.plan import plan_for_cq
from repro.rdf.generator import generate, lubm_workload
from repro.rdf.triples import TripleStore
from repro.serve.frontend import FixedServiceModel
from repro.serve.sharded import ShardedBackend

uni = generate(n_universities=1, seed=0, dept_per_univ=2, prof_per_dept=4,
               stud_per_dept=12, course_per_dept=5)
wl = lubm_workload(uni.dictionary)[:4]
s = TuningSession(uni.store, wl, schema=uni.schema, type_id=uni.type_id,
                  cfg=WizardConfig(search=SearchConfig(
                      strategy="greedy", max_states=60)))
s.retune()
s.apply()
mesh = make_mesh((8,), ("data",))
names = [q.name for q in s.workload]
want = [s.executor.answer_group_direct(n) for n in names]

be = ShardedBackend(s.executor, mesh=mesh)
got = be.answer_batch(names)
assert got == want, "sharded answers != host reference"
assert be.supervisor.health == "HEALTHY", be.supervisor.health
assert be.stats.served_tier == 0
print("sharded exact ok")

# one corrupted shard: per-shard DEGRADED + exact host fallback — the
# rollup must NOT flip the whole server DOWN
be.corrupt_shard(3)
got2 = be.answer_batch(names)
assert got2 == want, "degraded-shard answers must stay exact"
assert be.supervisor.health == "DEGRADED", be.supervisor.health
probe = be.readiness()
assert probe["ready"] and probe["quorum"]
assert probe["shards"][3] == "DEGRADED"
assert all(h == "HEALTHY" for d, h in probe["shards"].items() if d != 3)
assert be.stats.degraded_answers == len(names)
be.restore_shard(3)
got3 = be.answer_batch(names)
assert got3 == want and be.supervisor.health == "HEALTHY"
print("shard failover ok")

# async frontend over the sharded backend
fe = s.serve_async(sharded=True, mesh=mesh, classes=[QueryClass("c")],
                   service_model=FixedServiceModel(0.002, 0.0005))
for i, n in enumerate(names * 2):
    fe.offer(n, t=i * 0.001)
fe.flush()
assert fe.stats.completed == 2 * len(names)
r = fe.readiness()
assert r["health"] == "HEALTHY" and r["quorum"] and r["queue_depth"] == 0
print("frontend sharded ok")

# ---- empty-shard regressions ----------------------------------------
# ndev > distinct subjects: both triples hash to shard 0, shards 1-7
# are empty but still produce valid zero-row sorted indexes
tiny = TripleStore(np.array([[0, 1, 2], [8, 1, 3]], np.int32))
tt_t, shards_t = D.shard_store_by_subject(tiny, mesh, with_shards=True)
assert [len(sh) for sh in shards_t] == [2, 0, 0, 0, 0, 0, 0, 0]
x, y = Var("x"), Var("y")
q = CQ((x, y), (Atom(x, Const(1), y),), name="tiny")
fn = D.build_distributed_executor(plan_for_cq(q), tiny.stats, {}, mesh)
out = jax.jit(fn)(tt_t, {})
assert not bool(np.asarray(out.overflow).any())
got_t = {tuple(r) for r in D.gather_result(out).tolist()}
assert got_t == R.evaluate_cq(q, tiny).as_set() == {(0, 2), (8, 3)}

# a fully empty store shards without crashing and scans to zero rows
empty = TripleStore(np.zeros((0, 3), np.int32))
tt_e = D.shard_store_by_subject(empty, mesh)
fn_e = D.build_distributed_executor(plan_for_cq(q), empty.stats, {}, mesh)
out_e = jax.jit(fn_e)(tt_e, {})
assert len(D.gather_result(out_e)) == 0

# degenerate empty extents: the 1-D empty array numpy makes for [] and
# a well-shaped (0, w) both shard into valid all-empty PRels
for rows in (np.array([], np.int32), np.zeros((0, 3), np.int32)):
    pr = D.shard_prel_rows(rows, 0, mesh, width=3)
    assert int(np.asarray(pr.n).sum()) == 0
    assert not bool(np.asarray(pr.overflow).any())
print("empty shards ok")
"""


def test_sharded_serving_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    res = subprocess.run(
        [sys.executable, "-c", SCRIPT], capture_output=True, text=True,
        env=env, cwd=os.path.dirname(os.path.dirname(__file__)))
    assert res.returncode == 0, f"STDOUT:\n{res.stdout}\nSTDERR:\n{res.stderr}"
    assert "sharded exact ok" in res.stdout
    assert "shard failover ok" in res.stdout
    assert "frontend sharded ok" in res.stdout
    assert "empty shards ok" in res.stdout
