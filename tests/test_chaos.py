"""Fault-tolerant serving core (repro.serve.chaos + the degradation
ladder in repro.serve.query_server).

Correctness bar: under EVERY injectable fault class, an answered batch
either equals the host reference engine over the server's current store
or is explicitly flagged degraded/stale in `ServeStats.last_batch` —
never silently wrong.  Availability bar: the only batches that fail
raise `ServiceUnavailable` (all tiers + last-known-good exhausted), and
once a fault clears the server returns to HEALTHY within the breaker's
deterministic cooldown.  Property-tested over random fault schedules
with a deterministic twin, in the house style of test_maintenance.py.
"""
import numpy as np
import pytest

from repro.core.queries import CQ, Atom, Const, Var
from repro.distributed.fault import (CircuitBreaker, RetryPolicy,
                                     ServingSupervisor)
from repro.errors import ServiceUnavailable
from repro.maintenance import MaintenanceConfig
from repro.rdf.triples import TripleStore
from repro.serve.chaos import FaultInjector, FaultSpec, InjectedFault

PREDS = [1, 2, 3, 4, 5]


def _random_store(rng, n=600, n_ids=60):
    tt = np.stack([rng.integers(0, n_ids, n), rng.choice(PREDS, n),
                   rng.integers(0, n_ids, n)], axis=1).astype(np.int32)
    return TripleStore(tt)


def _random_batch(rng, n, n_ids=60):
    return np.stack([rng.integers(0, n_ids, n), rng.choice(PREDS, n),
                     rng.integers(0, n_ids, n)], axis=1).astype(np.int32)


def _chain_cq(name, p1, p2):
    x, y, z = Var("x"), Var("y"), Var("z")
    return CQ(name=name, head=(x, y, z),
              atoms=(Atom(x, Const(p1), y), Atom(y, Const(p2), z)))


def _session(store, workload):
    from repro.api import TuningSession

    s = TuningSession(store, workload=workload)
    s.retune()
    s.apply()
    return s


def _streaming_server(rng, queries=(("q1", 1, 2),), chaos=None, policy=None,
                      cfg=None):
    """A maintenance-enabled server: submitting a delta before a batch
    forces the fused program to actually re-run (cache dropped), so the
    device-side fault sites fire."""
    sess = _session(_random_store(rng),
                    [_chain_cq(n, a, b) for n, a, b in queries])
    srv = sess.serve(maintenance=cfg or MaintenanceConfig(), chaos=chaos,
                     policy=policy)
    return sess, srv


def _oracle(srv, name):
    return srv.executor.answer_group_direct(name)


# ----------------------------------------------------------------------
# primitives: breaker, supervisor, injector
# ----------------------------------------------------------------------
def test_circuit_breaker_opens_probes_and_backs_off():
    b = CircuitBreaker(RetryPolicy(failure_threshold=2, cooldown_batches=2,
                                   backoff_factor=2.0, max_cooldown=4))
    assert b.allow() and b.state == "closed"
    b.record_failure()
    assert b.state == "closed"  # below threshold
    b.record_failure()
    assert b.state == "open" and b.opens == 1
    assert not b.allow()        # cooldown tick 1
    assert b.allow() and b.state == "half_open"  # probe admitted
    b.record_failure()          # failed probe: cooldown 2 -> 4 (capped)
    assert b.state == "open" and b.opens == 2
    assert not b.allow() and not b.allow() and not b.allow()
    assert b.allow() and b.state == "half_open"
    b.record_success()
    assert b.state == "closed" and b.allow()


def test_supervisor_health_transitions_logged_and_bounded():
    sup = ServingSupervisor()
    sup.begin_batch()
    assert sup.observe(0, stale=False) == "HEALTHY" and sup.ready()
    sup.begin_batch()
    assert sup.observe(1, stale=False) == "DEGRADED"
    sup.begin_batch()
    assert sup.observe(0, stale=False, degraded=True) == "DEGRADED"
    sup.begin_batch()
    assert sup.observe(3, stale=True) == "STALE_ONLY" and sup.ready()
    sup.begin_batch()
    assert sup.observe(None, stale=False) == "DOWN" and not sup.ready()
    sup.begin_batch()
    assert sup.observe(0, stale=False) == "HEALTHY"
    assert [t.health for t in sup.transitions] == \
        ["DEGRADED", "STALE_ONLY", "DOWN", "HEALTHY"]
    for _ in range(3 * sup.MAX_TRANSITIONS):
        sup.observe(1, stale=False)
        sup.observe(0, stale=False)
    assert len(sup.transitions) <= sup.MAX_TRANSITIONS


def test_fault_injector_schedule_and_autoclear():
    chaos = FaultInjector()
    chaos.arm("device_call", after=1, count=2)
    chaos.fire("device_call")  # clean (after=1)
    with pytest.raises(InjectedFault):
        chaos.fire("device_call")
    with pytest.raises(InjectedFault):
        chaos.fire("device_call")
    chaos.fire("device_call")  # exhausted: auto-cleared
    assert not chaos.armed("device_call") and chaos.injected == 2
    with pytest.raises(ValueError):
        FaultSpec(site="nonsense")


# ----------------------------------------------------------------------
# satellite: telemetry key regression (bucket_cache_misses)
# ----------------------------------------------------------------------
def test_bucket_cache_misses_wired_from_real_key():
    rng = np.random.default_rng(0)
    sess = _session(_random_store(rng), [_chain_cq("q1", 1, 2)])
    srv = sess.serve()
    srv.answer("q1")
    t = sess.executor.telemetry()
    assert "bucket_cache_misses" in t
    assert srv.stats.bucket_cache_misses == t["bucket_cache_misses"]


# ----------------------------------------------------------------------
# ladder: one fault class at a time
# ----------------------------------------------------------------------
def test_single_device_fault_masked_by_in_batch_retry():
    rng = np.random.default_rng(1)
    chaos = FaultInjector()
    sess, srv = _streaming_server(rng, chaos=chaos)
    srv.submit(inserts=_random_batch(rng, 8))
    chaos.arm("device_call", count=1)  # one failure < max_attempts
    got = srv.answer("q1")
    assert got == _oracle(srv, "q1")
    assert srv.stats.health == "HEALTHY"
    assert srv.stats.last_batch == {"tier": 0, "degraded": False,
                                    "stale": False}
    assert chaos.injected == 1  # the fault really fired


def test_device_fault_degrades_to_per_query_then_recovers():
    rng = np.random.default_rng(2)
    chaos = FaultInjector()
    sess, srv = _streaming_server(rng, chaos=chaos)
    srv.submit(inserts=_random_batch(rng, 8))
    chaos.arm("device_call", count=2)  # defeats both in-batch attempts
    got = srv.answer("q1")
    assert got == _oracle(srv, "q1")  # tier 1 is exact
    assert srv.stats.served_tier == 1
    assert srv.stats.health == "DEGRADED"
    assert srv.stats.fused_failures == 1
    assert srv.stats.breaker_opens == 1
    assert srv.readiness()["ready"]
    # fault cleared: the next batch is the breaker's half-open probe
    srv.submit(inserts=_random_batch(rng, 8))
    got = srv.answer("q1")
    assert got == _oracle(srv, "q1")
    assert srv.stats.served_tier == 0 and srv.stats.health == "HEALTHY"
    assert srv.stats.breaker_state == "closed"


def test_timeout_fault_counts_as_failure():
    rng = np.random.default_rng(3)
    chaos = FaultInjector()
    sess, srv = _streaming_server(rng, chaos=chaos)
    srv.submit(inserts=_random_batch(rng, 8))
    chaos.arm("device_call", count=2, kind="timeout")
    assert srv.answer("q1") == _oracle(srv, "q1")
    assert srv.stats.health == "DEGRADED" and srv.stats.served_tier == 1


def test_capacity_overflow_storm_degrades_and_recovers():
    rng = np.random.default_rng(4)
    chaos = FaultInjector()
    sess, srv = _streaming_server(rng, chaos=chaos)
    srv.submit(inserts=_random_batch(rng, 8))
    chaos.arm("capacity_overflow", count=2)
    assert srv.answer("q1") == _oracle(srv, "q1")
    assert srv.stats.health == "DEGRADED"
    srv.submit(inserts=_random_batch(rng, 8))
    assert srv.answer("q1") == _oracle(srv, "q1")
    assert srv.stats.health == "HEALTHY"


def test_compile_fault_on_hot_swapped_program():
    rng = np.random.default_rng(5)
    chaos = FaultInjector()
    sess, srv = _streaming_server(rng, chaos=chaos)
    assert srv.answer("q1") == _oracle(srv, "q1")
    srv.invalidate()             # fresh program: next run must compile
    chaos.arm("compile", count=2)
    assert srv.answer("q1") == _oracle(srv, "q1")
    assert srv.stats.served_tier == 1 and srv.stats.health == "DEGRADED"
    assert srv.answer("q1") == _oracle(srv, "q1")
    assert srv.stats.health == "HEALTHY"


def test_maintenance_fault_requeues_delta_and_serves_stale():
    rng = np.random.default_rng(6)
    chaos = FaultInjector()
    sess, srv = _streaming_server(rng, chaos=chaos)
    pre = srv.answer("q1")                  # healthy baseline
    delta = _random_batch(rng, 16)
    srv.submit(inserts=delta)
    chaos.arm("maintenance_apply", count=1)
    got = srv.answer("q1")
    # the failed pass rolled back: answers match the PRE-delta store,
    # and the batch is flagged stale (backlog exceeds the 0 budget)
    assert got == pre == _oracle(srv, "q1")
    assert srv.stats.maintenance_failures == 1
    assert srv.stats.last_batch["stale"] is True
    assert srv.stats.health == "DEGRADED"
    assert srv.stream.pending_triples == len(delta)  # requeued, not lost
    # fault cleared: the requeued delta drains and serving is fresh
    got = srv.answer("q1")
    assert srv.stream.pending_triples == 0
    assert got == _oracle(srv, "q1")
    assert srv.stats.health == "HEALTHY"
    assert srv.stats.last_batch["stale"] is False


def test_mid_pass_maintenance_failure_rolls_back_executor():
    from repro.maintenance import Delta, ViewMaintainer

    rng = np.random.default_rng(7)
    sess = _session(_random_store(rng), [_chain_cq("q1", 1, 2)])
    m = ViewMaintainer(sess.executor, MaintenanceConfig())
    before = {vid: rel.rows.copy()
              for vid, rel in sess.executor.extents.items()}
    store_before = sess.executor.store

    class Boom(RuntimeError):
        pass

    def explode(*a, **k):
        raise Boom("mid-pass device failure")

    m._insert_pass = explode    # fail AFTER the delete pass + TT upload
    with pytest.raises(Boom):
        m.apply(Delta.of(_random_batch(rng, 16), None))
    assert sess.executor.store is store_before  # bindings rolled back
    for vid, rows in before.items():
        np.testing.assert_array_equal(sess.executor.extents[vid].rows, rows)
    assert sess.answer("q1") == sess.executor.answer_group_direct("q1")


def test_corrupted_extent_detected_repaired_never_served():
    rng = np.random.default_rng(8)
    chaos = FaultInjector()
    sess, srv = _streaming_server(rng, chaos=chaos)
    assert srv.answer("q1") == _oracle(srv, "q1")
    vid = chaos.corrupt_extent(srv.executor)
    assert len(srv.executor.extents[vid].rows) != \
        int(srv.executor.device_views[vid].n)
    got = srv.answer("q1")
    assert got == _oracle(srv, "q1")   # repaired BEFORE serving: exact
    assert srv.stats.integrity_failures == 1
    assert srv.stats.repairs == 1
    assert srv.stats.health == "DEGRADED"  # repair marks the batch
    assert srv.answer("q1") == _oracle(srv, "q1")
    assert srv.stats.health == "HEALTHY"


# ----------------------------------------------------------------------
# transactional retunes (satellite: retune_online rollback)
# ----------------------------------------------------------------------
def test_retune_online_rolls_back_on_retune_failure():
    rng = np.random.default_rng(9)
    chaos = FaultInjector()
    sess, srv = _streaming_server(rng, chaos=chaos)
    baseline = srv.answer("q1")
    names_before = {q.name for q in sess.workload}
    best_before = sess.best
    chaos.arm("retune", count=1)
    with pytest.raises(InjectedFault):
        srv.retune_online(add=[_chain_cq("q9", 2, 3)])
    # the docstring's promise: a failed edit leaves EVERYTHING untouched
    assert {q.name for q in sess.workload} == names_before
    assert sess.best is best_before
    assert srv.stats.retune_rollbacks == 1 and srv.stats.retunes == 0
    assert srv.answer("q1") == baseline == _oracle(srv, "q1")
    assert "q9" not in srv.executor.groups


def test_retune_online_rolls_back_on_apply_failure():
    rng = np.random.default_rng(10)
    chaos = FaultInjector()
    sess, srv = _streaming_server(rng, chaos=chaos)
    srv.answer("q1")
    best_before = sess.best
    chaos.arm("apply", count=1)   # retune succeeds, the hot swap dies
    with pytest.raises(InjectedFault):
        srv.retune_online(add=[_chain_cq("q9", 2, 3)])
    assert sess.best is best_before   # retune result rolled back too
    assert "q9" not in {q.name for q in sess.workload}
    assert srv.answer("q1") == _oracle(srv, "q1")  # old program serves
    # and the edit succeeds once the fault is gone
    srv.retune_online(add=[_chain_cq("q9", 2, 3)])
    assert srv.stats.retunes == 1
    assert srv.answer("q9") == _oracle(srv, "q9")


def test_drift_retune_failure_never_takes_serving_down():
    rng = np.random.default_rng(11)
    chaos = FaultInjector()
    sess = _session(_random_store(rng),
                    [_chain_cq("q1", 1, 2), _chain_cq("q2", 2, 3)])
    srv = sess.serve(maintenance=MaintenanceConfig(
        staleness_budget=0, drift_window=3, drift_rate_factor=2.0,
        drift_min_triples=32), chaos=chaos)
    for _ in range(4):
        srv.submit(inserts=_random_batch(rng, 4))
        srv.answer("q1")
    chaos.arm("retune", count=None)  # sticky: every drift retune dies
    for _ in range(6):
        b = _random_batch(rng, 160)
        b[:, 1] = 5
        srv.submit(inserts=b)
        assert srv.answer("q1") == _oracle(srv, "q1")
    assert srv.stats.retune_failures >= 1   # drift fired and was absorbed
    assert srv.stats.drift_retunes == 0
    chaos.clear()
    assert srv.answer("q2") == _oracle(srv, "q2")
    assert srv.stats.health == "HEALTHY"


# ----------------------------------------------------------------------
# deep ladder: last-known-good and DOWN
# ----------------------------------------------------------------------
def _arm_all_exact_tiers(chaos):
    chaos.arm("device_call", count=None)
    chaos.arm("per_query_call", count=None)
    chaos.arm("ref_engine_call", count=None)


def test_last_known_good_serves_stale_when_all_tiers_fail():
    rng = np.random.default_rng(12)
    chaos = FaultInjector()
    sess, srv = _streaming_server(rng, chaos=chaos)
    lkg = srv.answer("q1")            # healthy batch populates the LKG
    _arm_all_exact_tiers(chaos)
    srv.submit(inserts=_random_batch(rng, 16))  # forces a real re-run
    got = srv.answer("q1")
    assert got == lkg                 # the cached answer, not garbage
    assert srv.stats.served_tier == 3
    assert srv.stats.health == "STALE_ONLY"
    assert srv.stats.last_batch["stale"] is True
    assert srv.readiness()["ready"]   # stale is still ready
    chaos.clear()
    got = srv.answer("q1")
    assert got == _oracle(srv, "q1")  # fresh again (post-delta oracle)
    assert srv.stats.health == "HEALTHY"


def test_service_unavailable_when_no_tier_and_no_lkg():
    rng = np.random.default_rng(13)
    chaos = FaultInjector()
    sess, srv = _streaming_server(rng, chaos=chaos)
    srv.invalidate()                  # drop the warmed result cache
    _arm_all_exact_tiers(chaos)       # fresh server: LKG is empty
    with pytest.raises(ServiceUnavailable):
        srv.answer("q1")
    assert srv.stats.health == "DOWN"
    assert not srv.readiness()["ready"]
    chaos.clear()
    assert srv.answer("q1") == _oracle(srv, "q1")
    assert srv.stats.health == "HEALTHY" and srv.readiness()["ready"]


# ----------------------------------------------------------------------
# property: no silently wrong answers under ANY fault schedule
# ----------------------------------------------------------------------
def _chaos_invariant_stream(seed, steps=6):
    """Random fault schedule; the invariant is checked every batch:
    an answered batch equals the reference engine over the server's
    current store, OR it is flagged degraded/stale.  HEALTHY batches
    must be exact and fresh."""
    rng = np.random.default_rng(seed)
    chaos = FaultInjector()
    sess, srv = _streaming_server(rng, queries=(("q1", 1, 2), ("q2", 2, 3)),
                                  chaos=chaos)
    srv.answer_batch(["q1", "q2"])  # healthy start: LKG populated
    sites = ["device_call", "capacity_overflow", "compile",
             "maintenance_apply", "per_query_call", "ref_engine_call"]
    for _ in range(steps):
        site = sites[int(rng.integers(0, len(sites)))]
        chaos.arm(site, count=int(rng.integers(1, 4)))
        if int(rng.integers(0, 2)):
            chaos.arm(sites[int(rng.integers(0, len(sites)))],
                      count=int(rng.integers(1, 3)))
        srv.submit(inserts=_random_batch(rng, int(rng.integers(4, 24))))
        try:
            out = srv.answer_batch(["q1", "q2"])
        except ServiceUnavailable:
            assert srv.stats.health == "DOWN"
            chaos.clear()
            continue
        last = srv.stats.last_batch
        for name, got in zip(["q1", "q2"], out):
            if last["degraded"] or last["stale"]:
                continue          # flagged: allowed to lag the store
            assert got == srv.executor.answer_group_direct(name), \
                f"silently wrong answer for {name} under {site}"
        if srv.stats.health == "HEALTHY":
            assert not last["degraded"] and not last["stale"]
        chaos.clear()
    # recovery: with no faults armed the server must return to HEALTHY
    for _ in range(3):
        srv.answer_batch(["q1", "q2"])
    assert srv.stats.health == "HEALTHY"
    assert srv.answer("q1") == _oracle(srv, "q1")
    return srv


def test_chaos_property_random_fault_schedules():
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    # few examples: each replays a full serving stream under injected
    # faults (the compile cache makes later examples cheap)
    @settings(max_examples=4, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(seed=st.integers(0, 10**6))
    def run(seed):
        _chaos_invariant_stream(seed, steps=4)

    run()


def test_chaos_deterministic_twin():
    srv = _chaos_invariant_stream(seed=4242)
    assert srv.stats.batches >= 10
    assert srv.stats.faults  # the schedule really injected something
