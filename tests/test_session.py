"""TuningSession lifecycle: warm-started incremental retuning, delta
view swap, session persistence, online serving, tune() compatibility."""
import warnings

import numpy as np
import pytest

from repro.api import (QualityWeights, SearchConfig, TuningSession,
                       WizardConfig)
from repro.api import serde
from repro.core.reformulation import infer_type_id
from repro.core.search import search
from repro.core.state import initial_state
from repro.core.wizard import WizardReport, tune
from repro.rdf.generator import generate, lubm_workload
from repro.views import materializer


@pytest.fixture(scope="module")
def uni():
    return generate(n_universities=1, seed=0, dept_per_univ=2,
                    prof_per_dept=4, stud_per_dept=12, course_per_dept=5)


@pytest.fixture(scope="module")
def wl(uni):
    return lubm_workload(uni.dictionary)


def make_cfg():
    # weights under which the navigator genuinely iterates (fusion pays)
    return WizardConfig(search=SearchConfig(
        strategy="greedy", max_states=3000,
        weights=QualityWeights(w_exec=1.0, w_maint=1.0, w_space=1.0)))


@pytest.fixture(scope="module")
def cold_full(uni, wl):
    """Cold tune over the FULL workload — the warm path's baseline."""
    s = TuningSession(uni.store, wl, schema=uni.schema, type_id=uni.type_id,
                      cfg=make_cfg())
    return s.retune()


# ----------------------------------------------------------------------
# lifecycle: cold retune -> apply -> evolve -> warm retune -> delta apply
# ----------------------------------------------------------------------
def test_cold_retune_then_apply_answers_workload(uni, wl):
    s = TuningSession(uni.store, wl[:5], schema=uni.schema,
                      type_id=uni.type_id, cfg=make_cfg())
    rep = s.retune()
    assert not rep.warm and rep.added == [] and rep.removed == []
    ap = s.apply()
    assert ap.full and sorted(ap.materialized) == sorted(s.best.views)
    assert ap.reused == [] and ap.dropped == []
    for q in wl[:5]:
        assert s.answer(q.name) == s.executor.answer_group_direct(q.name), q.name


def test_warm_retune_explores_strictly_fewer_states(uni, wl, cold_full):
    """Acceptance: on a workload perturbed by one added query, warm
    retune explores strictly fewer states than the cold tune while
    reaching an equal-or-better quality total."""
    s = TuningSession(uni.store, wl[:5], schema=uni.schema,
                      type_id=uni.type_id, cfg=make_cfg())
    s.retune()
    s.add_query(wl[5])
    rep = s.retune()
    assert rep.warm and rep.removed == []
    assert len(rep.added) >= 1  # q6's reformulation members grafted
    assert rep.result.explored < cold_full.result.explored
    assert (rep.result.best_quality.total
            <= cold_full.result.best_quality.total + 1e-9)


def test_apply_delta_materializes_only_new_views(uni, wl, monkeypatch):
    s = TuningSession(uni.store, wl[:5], schema=uni.schema,
                      type_id=uni.type_id, cfg=make_cfg())
    s.retune()
    s.apply()
    applied_keys = [v.cq.canonical_key() for v in s.best.views.values()]

    calls = []
    real = materializer.materialize_view

    def counting(cq, store):
        calls.append(cq.name)
        return real(cq, store)

    monkeypatch.setattr(materializer, "materialize_view", counting)
    s.add_query(wl[5])
    s.retune()
    ap = s.apply()
    assert not ap.full
    # only the genuinely new views were evaluated...
    assert len(calls) == len(ap.materialized)
    assert 0 < len(ap.materialized) < len(s.best.views)
    assert len(ap.reused) >= 1
    assert sorted(ap.materialized + ap.reused) == sorted(s.best.views)
    # ...and reuse really keyed on the canonical form
    remaining = list(applied_keys)
    for vid in ap.reused:
        remaining.remove(s.best.views[vid].cq.canonical_key())
    for vid in ap.materialized:
        assert s.best.views[vid].cq.canonical_key() not in remaining
    # the swapped executor still answers the whole workload exactly
    for q in wl:
        assert s.answer(q.name) == s.executor.answer_group_direct(q.name), q.name


def test_remove_query_drops_dead_views(uni, wl):
    s = TuningSession(uni.store, wl[:3], schema=uni.schema,
                      type_id=uni.type_id, cfg=make_cfg())
    s.retune()
    s.apply()
    s.remove_query("q1")
    rep = s.retune()
    assert rep.warm and len(rep.removed) >= 1
    ap = s.apply()
    assert len(ap.dropped) >= 1
    assert "q1" not in s.groups
    for q in wl[1:3]:
        assert s.answer(q.name) == s.executor.answer_group_direct(q.name), q.name


def test_workload_evolution_guards(uni, wl):
    s = TuningSession(uni.store, cfg=make_cfg())
    with pytest.raises(ValueError, match="empty workload"):
        s.retune()
    with pytest.raises(RuntimeError, match="retune"):
        s.apply()
    s.add_query(wl[0])
    with pytest.raises(ValueError, match="duplicate"):
        s.add_query(wl[0])
    with pytest.raises(KeyError):
        s.remove_query("nope")


# ----------------------------------------------------------------------
# warm-start plumbing in the navigator itself
# ----------------------------------------------------------------------
def test_search_config_initial_overrides_seed(uni, wl):
    from dataclasses import replace

    cfg = make_cfg().search
    st_small = initial_state(wl[:2])
    st_big = initial_state(wl[:5])
    # the positional seed is ignored when cfg.initial is set
    res = search(st_small, uni.store.stats, replace(cfg, initial=st_big))
    baseline = search(st_big, uni.store.stats, cfg)
    assert res.best.key() == baseline.best.key()
    assert res.explored == baseline.explored
    assert {q.name for q in res.best.queries} == \
        {q.name for q in st_big.queries}


# ----------------------------------------------------------------------
# persistence
# ----------------------------------------------------------------------
def test_save_load_roundtrip_resumes_retuning(uni, wl, tmp_path, cold_full):
    cfg = make_cfg()
    s = TuningSession(uni.store, wl[:5], schema=uni.schema,
                      type_id=uni.type_id, cfg=cfg)
    s.retune()
    path = s.save(str(tmp_path))
    assert (tmp_path / "step_00000000" / "session.json").exists()
    assert path.endswith("step_00000000")

    s2 = TuningSession.load(str(tmp_path), cfg=cfg)
    assert [q.name for q in s2.workload] == [q.name for q in s.workload]
    assert s2.best.key() == s.best.key()
    assert np.array_equal(s2.store.triples, uni.store.triples)
    assert s2.store.dictionary.lookup("ub:takesCourse") == \
        uni.dictionary.lookup("ub:takesCourse")
    # resumed session warm-starts: strictly fewer states than cold
    s2.add_query(wl[5])
    rep = s2.retune()
    assert rep.warm
    assert rep.result.explored < cold_full.result.explored
    s2.apply()
    for q in wl:
        assert s2.answer(q.name) == s2.executor.answer_group_direct(q.name), q.name


def test_state_serde_roundtrip(uni, wl):
    s = TuningSession(uni.store, wl[:4], schema=uni.schema,
                      type_id=uni.type_id, cfg=make_cfg())
    rep = s.retune()
    st = rep.result.best
    back = serde.state_from_json(serde.state_to_json(st))
    assert back.key() == st.key()
    assert back.rewritings == st.rewritings
    assert back.next_view_id == st.next_view_id
    assert [q.name for q in back.queries] == [q.name for q in st.queries]
    assert [q.weight for q in back.queries] == [q.weight for q in st.queries]


def test_load_missing_checkpoint_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        TuningSession.load(str(tmp_path / "void"))


def test_load_restores_config_and_objective(uni, wl, tmp_path):
    s = TuningSession(uni.store, wl[:3], schema=uni.schema,
                      type_id=uni.type_id, cfg=make_cfg())
    s.retune()
    s.save(str(tmp_path))
    s2 = TuningSession.load(str(tmp_path))  # no cfg=: saved one restored
    w = s2.cfg.search.weights
    assert (w.w_exec, w.w_maint, w.w_space) == (1.0, 1.0, 1.0)
    assert s2.cfg.search.strategy == "greedy"
    assert s2.cfg.search.max_states == 3000
    # same objective => identical recomputed quality for the saved best
    assert abs(s2.best_quality.total - s.best_quality.total) < 1e-6


def test_delta_swap_carries_device_buffers(uni, wl):
    """Surviving views under an identity permutation keep their device
    buffers — reuse is not a host-side re-upload."""
    s = TuningSession(uni.store, wl[:5], schema=uni.schema,
                      type_id=uni.type_id, cfg=make_cfg())
    s.retune()
    s.apply()
    before = {id(p) for p in s.executor.device_views.values()}
    s.add_query(wl[5])
    s.retune()
    ap = s.apply()
    carried = [vid for vid in ap.reused
               if id(s.executor.device_views[vid]) in before]
    assert carried, "identity-permutation reuse must carry buffers over"
    for vid in ap.materialized:
        assert id(s.executor.device_views[vid]) not in before


# ----------------------------------------------------------------------
# online serving
# ----------------------------------------------------------------------
def test_serve_retunes_online_behind_batched_endpoint(uni, wl):
    s = TuningSession(uni.store, wl[:5], schema=uni.schema,
                      type_id=uni.type_id, cfg=make_cfg())
    srv = s.serve()
    ex = srv.executor
    names = [q.name for q in wl[:5]]
    for name, ans in zip(names, srv.answer_batch(names)):
        assert ans == ex.answer_group_direct(name), name
    out = srv.retune_online(add=[wl[5]])
    assert out["retune"].warm and not out["apply"].full
    assert srv.executor is ex  # hot swap: same executor object serves on
    assert srv.stats.retunes == 1
    answers = srv.answer_batch(names + ["q6"])
    assert all(a is not None for a in answers)
    assert answers[-1] == ex.answer_group_direct("q6")
    srv.retune_online(remove=["q1"])
    assert srv.answer("q1") is None  # unknown now
    assert srv.answer("q6") == ex.answer_group_direct("q6")


def test_retune_online_validates_before_mutating(uni, wl):
    s = TuningSession(uni.store, wl[:3], schema=uni.schema,
                      type_id=uni.type_id, cfg=make_cfg())
    srv = s.serve()
    # invalid edit (adding a name that survives the removes): atomic no-op
    with pytest.raises(ValueError, match="duplicate"):
        srv.retune_online(remove=["q1"], add=[wl[1]])
    assert {q.name for q in s.workload} == {"q1", "q2", "q3"}
    with pytest.raises(KeyError):
        srv.retune_online(remove=["never_there"])
    assert srv.stats.retunes == 0
    # the remove+re-add spelling of a weight change IS valid
    srv.retune_online(remove=["q1"], add=[wl[0]])
    assert srv.stats.retunes == 1


def test_invalidate_keeps_session_on_serving_store(uni, wl):
    from repro.rdf.triples import TripleStore

    s = TuningSession(uni.store, wl[:3], schema=uni.schema,
                      type_id=uni.type_id, cfg=make_cfg())
    srv = s.serve()
    t = uni.store.triples
    smaller = TripleStore(t[: int(len(t) * 0.8)], uni.dictionary)
    srv.invalidate(smaller)
    assert s.store is smaller  # retune stats + save() follow the server
    srv.retune_online(add=[wl[3]])
    for q in wl[:4]:
        assert srv.answer(q.name) == \
            srv.executor.answer_group_direct(q.name), q.name


def test_from_tuned_honors_subclass(uni, wl):
    from repro.serve.query_server import QueryServer

    class SubServer(QueryServer):
        pass

    srv = SubServer.from_tuned(uni.store, wl[:2], uni.schema, uni.type_id,
                               make_cfg())
    assert isinstance(srv, SubServer)
    assert srv.session is not None
    assert srv.answer("q1") == srv.executor.answer_group_direct("q1")


# ----------------------------------------------------------------------
# tune() compatibility shim
# ----------------------------------------------------------------------
def test_tune_old_signature_regression(uni, wl):
    """Pin the original positional call shape + WizardReport fields."""
    cfg = WizardConfig(search=SearchConfig(strategy="greedy", max_states=200))
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        rep = tune(uni.store, wl, uni.schema, uni.type_id, cfg)
    assert any(issubclass(w.category, DeprecationWarning) for w in caught)
    assert isinstance(rep, WizardReport)
    assert rep.initial_quality.total >= rep.result.best_quality.total - 1e-9
    assert rep.summary()
    assert set(rep.groups) == {q.name for q in wl}
    q = wl[0]
    assert rep.executor.answer_group(q.name) == \
        rep.executor.answer_group_direct(q.name)


def test_tune_without_schema_keeps_working(uni, wl):
    cfg = WizardConfig(search=SearchConfig(strategy="greedy", max_states=100),
                       use_schema=False)
    rep = tune(uni.store, wl[:2], None, None, cfg)
    for q in wl[:2]:
        assert rep.executor.answer_group(q.name) == \
            rep.executor.answer_group_direct(q.name)


def test_tune_infers_type_id_when_unambiguous(uni, wl):
    cfg = WizardConfig(search=SearchConfig(strategy="greedy", max_states=100))
    inferred = tune(uni.store, wl, uni.schema, None, cfg)
    explicit = tune(uni.store, wl, uni.schema, uni.type_id, cfg)
    assert inferred.result.best.key() == explicit.result.best.key()
    assert infer_type_id(wl, uni.schema) == uni.type_id


def test_tune_raises_value_error_when_type_id_unresolvable(uni, wl):
    # q2's atoms are all schema properties: no type atom, no evidence
    no_type_evidence = [wl[1]]
    assert infer_type_id(no_type_evidence, uni.schema) is None
    with pytest.raises(ValueError, match="type_id"):
        tune(uni.store, no_type_evidence, uni.schema, None, WizardConfig())
