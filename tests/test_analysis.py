"""Static-analysis subsystem: each analyzer family must (a) stay silent
on sound inputs and (b) catch a deliberately seeded defect."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import (analyze_capacity, analyze_repo, check_cache_keys,
                            check_source, lint_program, lint_traced,
                            verify_dag)
from repro.core.queries import Atom, Const, Var
from repro.errors import InvariantViolation, require
from repro.query import cost as cost_mod
from repro.query import ref_engine as R
from repro.query.buckets import BucketedProgram, CompileCache
from repro.query.dag import build_dag
from repro.query.plan import EquiJoin, Filter, TTScan, rename_columns
from repro.query.workload import WorkloadExecutor
from repro.rdf.triples import TripleStore


def _store() -> TripleStore:
    triples = [(s, 1, 10 + s % 3) for s in range(6)]
    triples += [(s, 2, s - 9) for s in range(10, 14)]
    return TripleStore(np.array(triples, np.int32))


def _plans():
    x, y, z = Var("x"), Var("y"), Var("z")
    scan1 = TTScan(Atom(x, Const(1), y))
    scan2 = TTScan(Atom(y, Const(2), z))
    return {"q_join": EquiJoin(scan1, scan2, (("y", "y"),)),
            "q_filt": Filter(scan1, "y", 10)}


def _dag():
    return build_dag(_plans())


def _rules(findings):
    return {f.rule for f in findings}


# ----------------------------------------------------------------------
# IR verifier
# ----------------------------------------------------------------------
def test_verify_dag_clean():
    assert verify_dag(_dag(), expected_members={"q_join", "q_filt"}) == []


def test_ir_catches_corrupt_width():
    dag = _dag()
    join_id = dag.roots["q_join"]
    dag.nodes[join_id] = dataclasses.replace(
        dag.nodes[join_id], width=dag.nodes[join_id].width + 2)
    assert _rules(verify_dag(dag)) == {"ir/width"}


def test_ir_catches_cycle():
    dag = _dag()
    filt_id = dag.roots["q_filt"]
    dag.nodes[filt_id] = dataclasses.replace(
        dag.nodes[filt_id], child_ids=(filt_id,))
    assert "ir/cycle" in _rules(verify_dag(dag))


def test_ir_catches_key_collision():
    dag = _dag()
    scan = dag.nodes[0]
    # a second live node with the same content, hidden from the interner
    # behind a divergent structural key — exactly the corruption the
    # canonical-key machinery must never let happen
    dup = dataclasses.replace(scan, id=len(dag.nodes),
                              key=("scan", ("corrupt",)))
    dag.nodes.append(dup)
    dag.consumers[dup.id] = 0
    assert "ir/key-collision" in _rules(verify_dag(dag))


def test_ir_catches_corrupt_key_structure():
    dag = _dag()
    filt_id = dag.roots["q_filt"]
    node = dag.nodes[filt_id]
    ci, value = node.spec
    dag.nodes[filt_id] = dataclasses.replace(
        node, key=("filter", node.child_ids[0], ci + 1, value))
    assert _rules(verify_dag(dag)) == {"ir/key-structure"}


def test_ir_catches_missing_root():
    findings = verify_dag(_dag(), expected_members={"q_join", "q_gone"})
    assert _rules(findings) == {"ir/root-coverage"}
    assert "q_gone" in findings[0].location


def test_ir_catches_consumer_drift():
    dag = _dag()
    dag.consumers[0] += 1
    assert _rules(verify_dag(dag)) == {"ir/consumers"}


# ----------------------------------------------------------------------
# canonical-key soundness (deterministic; randomized twin lives in
# test_properties.py under hypothesis)
# ----------------------------------------------------------------------
def test_renamed_plans_intern_to_same_node_with_equal_answers():
    store = _store()
    plans = _plans()
    renamed = {name: rename_columns(p, {"x": "a", "y": "b", "z": "c"})
               for name, p in plans.items()}
    dag = build_dag({**plans,
                     **{f"{n}_renamed": p for n, p in renamed.items()}})
    for name, plan in plans.items():
        assert dag.roots[name] == dag.roots[f"{name}_renamed"]
        got = sorted(map(tuple, R.execute(plan, store).rows.tolist()))
        want = sorted(map(tuple,
                          R.execute(renamed[name], store).rows.tolist()))
        assert got == want
    assert verify_dag(dag) == []


# ----------------------------------------------------------------------
# capacity analyzer
# ----------------------------------------------------------------------
def test_capacity_clean_on_planned_caps():
    store = _store()
    assert analyze_capacity(_dag(), store.stats, {}) == []


def test_capacity_catches_seeded_hazards():
    dag = _dag()
    stats = _store().stats
    n = len(dag.nodes)
    scan_ids = [nd.id for nd in dag.nodes if nd.kind == "scan"]
    join_id = dag.roots["q_join"]

    caps = [128] * n
    caps[scan_ids[0]] = 100           # not a power of two
    demands = [10.0] * n
    demands[join_id] = float(1 << 23)  # beyond the ceiling
    rules = _rules(analyze_capacity(dag, stats, {}, caps=caps,
                                    demands=demands))
    assert {"cap/invalid", "cap/ceiling"} <= rules

    caps = [128] * n
    demands = [10.0] * n
    demands[join_id] = 1000.0          # overflow predicted on first run
    demands[scan_ids[1]] = 100.0       # < 2x headroom
    findings = analyze_capacity(dag, stats, {}, caps=caps, demands=demands)
    assert {"cap/undersized", "cap/headroom"} <= _rules(findings)
    assert all(f.severity == "warning" for f in findings)


def test_promotion_chain_bounded():
    chain = cost_mod.promotion_chain(128)
    assert chain[0] == 256 and chain[-1] == 1 << 22
    assert all(b == 2 * a for a, b in zip([128] + chain, chain))
    assert cost_mod.promotion_chain(1 << 22) == []


# ----------------------------------------------------------------------
# jaxpr lint
# ----------------------------------------------------------------------
def test_lint_program_clean_on_real_buckets():
    store = _store()
    dag = _dag()
    program = BucketedProgram(dag, store.stats, {})
    assert lint_program(program, n_tt=len(store)) == []


def test_lint_catches_float64_promotion():
    spec = jax.ShapeDtypeStruct((4,), jnp.int32)
    jax.config.update("jax_enable_x64", True)
    try:
        findings = lint_traced(lambda x: x.astype(jnp.float64) * 2.0, (spec,))
    finally:
        jax.config.update("jax_enable_x64", False)
    assert "jaxpr/float64" in _rules(findings)


def test_lint_catches_float_in_engine_body():
    spec = jax.ShapeDtypeStruct((4,), jnp.int32)
    findings = lint_traced(lambda x: (x * 1.5).astype(jnp.int32), (spec,))
    assert _rules(findings) == {"jaxpr/weak-float"}
    assert lint_traced(lambda x: (x * 1.5).astype(jnp.int32), (spec,),
                       forbid_floats=False) == []


def test_lint_catches_host_callback():
    spec = jax.ShapeDtypeStruct((4,), jnp.int32)

    def body(x):
        return jax.pure_callback(
            lambda a: a, jax.ShapeDtypeStruct(x.shape, x.dtype), x)

    assert "jaxpr/callback" in _rules(lint_traced(body, (spec,)))


def test_lint_reports_trace_failure():
    def broken(x):
        raise ValueError("boom")

    findings = lint_traced(broken, (jax.ShapeDtypeStruct((2,), jnp.int32),))
    assert _rules(findings) == {"jaxpr/trace-error"}
    assert "boom" in findings[0].message


def test_cache_key_checks():
    good = [(("sig_a",), ("key_a",), "a"), (("sig_b",), ("key_b",), "b")]
    assert check_cache_keys(good) == []
    collide = [(("sig_a",), ("key",), "a"), (("sig_b",), ("key",), "b")]
    assert _rules(check_cache_keys(collide)) == {"jaxpr/key-collision"}
    unhashable = [(("sig",), ["list", "key"], "c")]
    assert _rules(check_cache_keys(unhashable)) == {"jaxpr/key-unhashable"}


# ----------------------------------------------------------------------
# repo rules
# ----------------------------------------------------------------------
def test_rules_catch_bare_assert():
    src = "def f(x):\n    assert x > 0\n    return x\n"
    assert _rules(check_source(src, "m.py")) == {"rules/bare-assert"}
    allowed = "def f(x):\n    assert x > 0  # lint: allow-assert\n"
    assert check_source(allowed, "m.py") == []


def test_rules_catch_mutable_default():
    assert _rules(check_source("def f(x, acc=[]):\n    return acc\n",
                               "m.py")) == {"rules/mutable-default"}
    assert _rules(check_source("def f(x, *, acc=dict()):\n    return acc\n",
                               "m.py")) == {"rules/mutable-default"}
    assert check_source("def f(x, acc=None):\n    return acc\n", "m.py") == []


def test_rules_catch_unhashable_static_arg():
    src = (
        "from functools import partial\n"
        "import jax\n\n"
        "@partial(jax.jit, static_argnames=('cfg',))\n"
        "def f(x, cfg={}):\n"
        "    return x\n"
    )
    assert "rules/unhashable-static" in _rules(check_source(src, "m.py"))
    src_nums = (
        "import jax\n\n"
        "def g(x, opts=[]):\n"
        "    return x\n\n"
        "g_jit = jax.jit(g, static_argnums=(1,))\n"
    )
    rules = _rules(check_source(src_nums, "m.py"))
    assert {"rules/unhashable-static", "rules/mutable-default"} <= rules


def test_rules_catch_swallowed_exception():
    swallow = (
        "def f():\n"
        "    try:\n"
        "        g()\n"
        "    except Exception:\n"
        "        pass\n"
    )
    # only the serving/maintenance/api packages are in scope
    assert _rules(check_source(swallow, "serve/m.py")) \
        == {"rules/swallowed-exception"}
    assert _rules(check_source(swallow, "maintenance/m.py")) \
        == {"rules/swallowed-exception"}
    assert check_source(swallow, "query/m.py") == []
    # a bare `except:` that only rebinds a name swallows too
    bare = "try:\n    g()\nexcept:\n    x = None\n"
    assert _rules(check_source(bare, "api/m.py")) \
        == {"rules/swallowed-exception"}
    # handlers that re-raise, call anything (rollback/telemetry), or
    # catch a NARROW type are the fault-tolerant contract — not flagged
    reraise = "try:\n    g()\nexcept Exception:\n    raise\n"
    assert check_source(reraise, "serve/m.py") == []
    handled = "try:\n    g()\nexcept Exception as e:\n    log(e)\n"
    assert check_source(handled, "serve/m.py") == []
    narrow = "try:\n    g()\nexcept KeyError:\n    x = None\n"
    assert check_source(narrow, "serve/m.py") == []
    optout = ("try:\n    g()\n"
              "except Exception:  # lint: allow-swallow\n    pass\n")
    assert check_source(optout, "serve/m.py") == []


def test_rules_catch_unbounded_queue():
    grow = (
        "from collections import deque\n"
        "class S:\n"
        "    def __init__(self):\n"
        "        self.q = deque()\n"
        "        self.log = []\n"
        "    def push(self, x):\n"
        "        self.q.append(x)\n"
        "        self.log.append(x)\n"
    )
    # a capless deque + two unbounded persistent appends, serve/ only
    fs = check_source(grow, "serve/m.py")
    assert _rules(fs) == {"rules/unbounded-queue"} and len(fs) == 3
    assert check_source(grow, "query/m.py") == []
    # every bounding idiom passes: deque(maxlen=), len() guard,
    # del-trim, slice self-trim, the opt-out marker, and local lists
    ok = (
        "from collections import deque\n"
        "class S:\n"
        "    MAX = 8\n"
        "    def __init__(self):\n"
        "        self.q = deque(maxlen=8)\n"
        "        self.guarded = []\n"
        "        self.trimmed = []\n"
        "        self.sliced = []\n"
        "        self.marked = []\n"
        "    def push(self, x):\n"
        "        self.q.append(x)\n"
        "        if len(self.guarded) < self.MAX:\n"
        "            self.guarded.append(x)\n"
        "        self.trimmed.append(x)\n"
        "        del self.trimmed[:-self.MAX]\n"
        "        self.sliced.append(x)\n"
        "        self.sliced = self.sliced[-self.MAX:]\n"
        "        self.marked.append(x)  # lint: allow-unbounded\n"
        "        local = []\n"
        "        local.append(x)\n"
    )
    assert check_source(ok, "serve/m.py") == []


def test_repo_rules_clean_on_library():
    report = analyze_repo()
    assert report.clean(), report.format()
    assert report.checked["files"] > 20


# ----------------------------------------------------------------------
# typed exceptions (python -O safe)
# ----------------------------------------------------------------------
def test_require_raises_typed_invariant():
    require(True, "fine")
    with pytest.raises(InvariantViolation, match="broken"):
        require(False, "broken")
    assert issubclass(InvariantViolation, RuntimeError)


# ----------------------------------------------------------------------
# bounded compile cache
# ----------------------------------------------------------------------
def test_compile_cache_lru_eviction():
    cache = CompileCache(max_entries=2)
    spec = (jax.ShapeDtypeStruct((2,), jnp.int32),)

    def build(k):
        return lambda: (lambda x: x + k)

    for k in range(3):
        _, cached, _ = cache.get(("k", k), build(k), spec)
        assert not cached
    s = cache.stats()
    assert s["entries"] == 2 and s["evictions"] == 1 and s["misses"] == 3
    # ("k", 0) was least-recently used → gone; ("k", 2) survives
    _, cached, _ = cache.get(("k", 2), build(2), spec)
    assert cached
    _, cached, _ = cache.get(("k", 0), build(0), spec)
    assert not cached
    cache.resize(1)
    assert cache.stats()["entries"] == 1
    with pytest.raises(ValueError):
        cache.resize(0)


def test_executor_telemetry_exposes_cache_stats():
    store = _store()
    ex = WorkloadExecutor(_dag(), store.stats, {})
    t = ex.telemetry()
    for key in ("entries", "max_entries", "hits", "misses", "evictions"):
        assert key in t["compile_cache"]


# ----------------------------------------------------------------------
# ops wrappers validate operands up front
# ----------------------------------------------------------------------
def test_ops_validation_errors():
    from repro.kernels import ops

    with pytest.raises(TypeError, match="probe must be int32"):
        ops.join_count(jnp.zeros(4, jnp.float32), jnp.zeros(4, jnp.int32))
    with pytest.raises(ValueError, match="must be 1-D"):
        ops.join_count(jnp.zeros((4, 1), jnp.int32), jnp.zeros(4, jnp.int32))
    with pytest.raises(ValueError, match="must be 2-D"):
        ops.filter_mask(jnp.zeros(4, jnp.int32), ((0, 1),))
    with pytest.raises(ValueError, match="out of range"):
        ops.filter_mask(jnp.zeros((4, 2), jnp.int32), ((5, 1),))
    with pytest.raises(TypeError, match="static"):
        ops.filter_mask(jnp.zeros((4, 2), jnp.int32), ((jnp.int32(0), 1),))
    q = jnp.zeros((1, 128, 4, 8), jnp.float32)
    kv = jnp.zeros((1, 128, 2, 8), jnp.float32)
    with pytest.raises(ValueError, match="4-D"):
        ops.flash_attention(q[0], kv, kv)
    with pytest.raises(ValueError, match="k and v must agree"):
        ops.flash_attention(q, kv, kv[:, :64])
    with pytest.raises(ValueError, match="multiple of kv heads"):
        ops.flash_attention(q, kv[:, :, :1][:, :, [0, 0, 0]], kv[:, :, [0, 0, 0]])
    with pytest.raises(ValueError, match="window"):
        ops.flash_attention(q, kv, kv, window=-1)


# ----------------------------------------------------------------------
# CLI + session entry points
# ----------------------------------------------------------------------
def test_cli_rules_only_passes(capsys):
    from repro.analysis.cli import run

    assert run(["--rules-only", "--strict"]) == 0
    out = capsys.readouterr().out
    assert "analysis: clean" in out


def test_analyze_state_on_tuned_session():
    from repro.analysis import analyze_state
    from repro.analysis.cli import build_session

    session = build_session("quickstart", max_states=10)
    report = analyze_state(session.best, session.store.stats)
    assert report.ok, report.format()
    assert report.checked["nodes"] > 0 and report.checked["buckets"] > 0
    # session.verify() routes the unapplied session through the same path
    assert session.verify().ok
