"""Expert-parallel MoE (shard_map) == dense reference, on an 8-device
mesh in a subprocess (§Perf iteration A2's correctness gate)."""
import os
import subprocess
import sys

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.launch.mesh import make_mesh
from repro.distributed.sharding import DEFAULT_RULES, axis_ctx, param_shardings
from repro.configs import get_smoke_config
from repro.models import layers as L
from repro.models.params import init_params

mesh = make_mesh((2, 4), ("data", "model"))
xsh = NamedSharding(mesh, P("data"))

for arch in ["granite-moe-1b-a400m", "llama4-maverick-400b-a17b"]:
    cfg = get_smoke_config(arch)
    # dropless capacity so EP (per-shard capacity) and dense agree exactly
    cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
        cfg.moe, capacity_factor=8.0))
    tpl = L.moe_template(cfg)
    params = init_params(tpl, jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (4, 8, cfg.d_model), jnp.float32)
    dense = jax.jit(lambda p, x: L._moe_dense(p, cfg, x))(params, x)
    psh = param_shardings(tpl, DEFAULT_RULES, mesh)

    def f(p, xx):
        with axis_ctx(mesh, DEFAULT_RULES):
            return L.moe(p, cfg, xx)

    ep = jax.jit(f, in_shardings=(psh, xsh))(
        jax.device_put(params, psh), jax.device_put(x, xsh))
    np.testing.assert_allclose(np.asarray(dense), np.asarray(ep),
                               rtol=2e-4, atol=2e-5)

    def loss(p, xx):
        with axis_ctx(mesh, DEFAULT_RULES):
            return jnp.sum(L.moe(p, cfg, xx) ** 2)

    g = jax.jit(jax.grad(loss), in_shardings=(psh, xsh))(
        jax.device_put(params, psh), jax.device_put(x, xsh))
    assert all(bool(jnp.isfinite(v).all()) for v in jax.tree.leaves(g))
    print(f"ok {arch}")
"""


def test_moe_expert_parallel_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    res = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                         text=True, env=env, cwd=root, timeout=900)
    assert res.returncode == 0, f"STDOUT:\n{res.stdout}\nSTDERR:\n{res.stderr}"
    assert res.stdout.count("ok ") == 2
