"""Workload-level compilation: shared-subplan DAG, fused jitted
executor, adaptive capacity recovery, device materialization, serving."""
import numpy as np
import pytest

from repro.core.queries import Atom, CQ, Const, Var
from repro.core.reformulation import reformulate_workload
from repro.core.search import SearchConfig
from repro.core.wizard import WizardConfig, tune
from repro.query import engine as E
from repro.query import ref_engine as R
from repro.query.buckets import clear_compile_cache
from repro.query.dag import build_dag
from repro.query.plan import TTScan, plan_for_cq
from repro.query.workload import WorkloadExecutor
from repro.rdf.generator import generate, lubm_workload
from repro.serve.query_server import QueryServer
from repro.views.materializer import (materialize_state,
                                      materialize_state_device)


@pytest.fixture(scope="module")
def uni():
    return generate(n_universities=1, seed=0, dept_per_univ=2,
                    prof_per_dept=4, stud_per_dept=12, course_per_dept=5)


@pytest.fixture(scope="module")
def members(uni):
    ms, groups = reformulate_workload(
        list(lubm_workload(uni.dictionary)), uni.schema, uni.type_id, 2048)
    return ms, groups


@pytest.fixture(scope="module")
def baseline_dag(members):
    ms, _ = members
    return build_dag({m.name: plan_for_cq(m) for m in ms})


@pytest.fixture(scope="module")
def report(uni):
    cfg = WizardConfig(search=SearchConfig(strategy="greedy", max_states=400))
    return tune(uni.store, lubm_workload(uni.dictionary), uni.schema,
                uni.type_id, cfg)


# ----------------------------------------------------------------------
# DAG canonicalization + sharing
# ----------------------------------------------------------------------
def test_dag_shares_subplans_across_rewritings(baseline_dag):
    """Distinct rewritings of the workload must share at least one node,
    visible through the DAG's node-reuse counter."""
    st = baseline_dag.stats()
    assert st["shared_nodes"] >= 1
    assert baseline_dag.node_reuse_count >= 1
    assert st["dag_nodes"] < st["tree_nodes"]


def test_dag_sharing_is_renaming_invariant(uni):
    """The same triple pattern under different variable names interns to
    one scan node; different constants stay distinct."""
    from repro.core.queries import Atom, CQ, Const, Var

    takes = Const(uni.dictionary.lookup("ub:takesCourse"))
    adv = Const(uni.dictionary.lookup("ub:advisor"))
    q1 = CQ((Var("x"),), (Atom(Var("x"), takes, Var("y")),), name="a")
    q2 = CQ((Var("s"),), (Atom(Var("s"), takes, Var("t")),), name="b")
    q3 = CQ((Var("s"),), (Atom(Var("s"), adv, Var("t")),), name="c")
    dag = build_dag({q.name: plan_for_cq(q) for q in (q1, q2, q3)})
    kinds = [n.kind for n in dag.nodes]
    assert kinds.count("scan") == 2  # q1/q2 share, q3 distinct
    assert dag.roots["a"] == dag.roots["b"]  # whole rewriting deduped


def test_fused_executor_matches_oracle(uni, members, baseline_dag):
    """One device call answers every workload member identically to
    direct evaluation (set semantics)."""
    ms, _ = members
    wl = WorkloadExecutor(baseline_dag, uni.store.stats, {})
    roots = wl.run(E.tt_device_indexes(uni.store), {})
    for m in ms:
        got = {tuple(r) for r in E.to_numpy(roots[m.name]).tolist()}
        want = R.evaluate_cq(m, uni.store).as_set()
        assert got == want, m.name
    assert wl.compiles == 1 and wl.runs == 1 and wl.recompiles == 0


# ----------------------------------------------------------------------
# adaptive capacity recovery
# ----------------------------------------------------------------------
def test_overflow_recovers_by_doubling(uni, members, baseline_dag):
    """Pathologically tiny capacities overflow; the driver doubles the
    offending nodes and recompiles until every answer is exact."""
    ms, _ = members
    wl = WorkloadExecutor(baseline_dag, uni.store.stats, {},
                          cap_planner=lambda node, rows: 32, max_retries=24)
    roots = wl.run(E.tt_device_indexes(uni.store), {})
    assert wl.recompiles >= 1
    assert wl.cap_history  # some node actually grew
    for nid, hist in wl.cap_history.items():
        assert hist == sorted(hist) and hist[-1] > hist[0]
    for m in ms:
        got = {tuple(r) for r in E.to_numpy(roots[m.name]).tolist()}
        want = R.evaluate_cq(m, uni.store).as_set()
        assert got == want, m.name


def test_overflow_retry_budget_trips(uni, baseline_dag):
    wl = WorkloadExecutor(baseline_dag, uni.store.stats, {},
                          cap_planner=lambda node, rows: 2, max_retries=1)
    with pytest.raises(RuntimeError, match="overflow persists"):
        wl.run(E.tt_device_indexes(uni.store), {})
    assert wl.recompiles == 1  # budget consumed, then raised


def test_executor_answer_recovers_from_overflow(uni, report):
    """QueryExecutor no longer raises on overflow: tiny initial caps are
    recovered adaptively and answers still match the oracle."""
    from repro.core.executor import QueryExecutor

    ex = QueryExecutor(uni.store, report.result.best, report.groups,
                       cap_planner=lambda node, rows: 8, max_retries=24)
    for q in lubm_workload(uni.dictionary):
        assert ex.answer_group(q.name) == ex.answer_group_direct(q.name)
    t = ex.telemetry()
    assert t["runs"] >= 1 and t["compiles"] == t["recompiles"] + 1


# ----------------------------------------------------------------------
# shape-bucketed execution
# ----------------------------------------------------------------------
def _course_scan_workload(uni):
    """Three same-shape course scans (one bucket) + one advisor scan
    (structurally different -> its own bucket).  Every plan root is the
    scan itself, so bucket attribution is exact."""
    d = uni.dictionary
    takes = Const(d.lookup("ub:takesCourse"))
    adv = Const(d.lookup("ub:advisor"))
    x, y = Var("x"), Var("y")
    qs = [CQ((x,), (Atom(x, takes, Const(d.lookup(c))),), name=f"takes{i}")
          for i, c in enumerate(["u0.d0.c0", "u0.d0.c1", "u0.d1.c0"])]
    qs.append(CQ((x, y), (Atom(x, adv, y),), name="adv"))
    return qs, takes


def test_overflow_promotes_only_offending_bucket(uni):
    """Force an overflow inside ONE bucket: the adaptive driver promotes
    that bucket to the next capacity class and recompiles ONLY its body
    — the other bucket never recompiles — and answers stay exact."""
    clear_compile_cache()
    qs, takes = _course_scan_workload(uni)
    dag = build_dag({q.name: plan_for_cq(q) for q in qs})

    def planner(plan, rows):
        if isinstance(plan, TTScan) and plan.atom.p == takes:
            return 2  # guaranteed too small: every course has >2 takers
        return 512

    wl = WorkloadExecutor(dag, uni.store.stats, {}, cap_planner=planner,
                          max_retries=16)
    roots = wl.run(E.tt_device_indexes(uni.store), {})
    for q in qs:
        got = {tuple(r) for r in E.to_numpy(roots[q.name]).tolist()}
        assert got == R.evaluate_cq(q, uni.store).as_set(), q.name
    assert wl.recompiles >= 1
    t = wl.telemetry()
    assert t["mode"] == "bucketed"
    promoted = [b for b in wl._prog.buckets if b.promotions > 0]
    assert len(promoted) == 1  # exactly one bucket grew...
    assert promoted[0].kind == "scan" and len(promoted[0].node_ids) == 3
    # ...and every compile past the initial set recompiled THAT bucket:
    # same 3-member batch, at a promoted capacity class
    log = t["bucket_compile_log"]
    assert len(log) == t["buckets"] + promoted[0].promotions
    for entry in log[t["buckets"]:]:
        assert entry["kind"] == "scan"
        assert entry["batch"] == 3 and entry["cap"] > 2
    # untouched bucket compiled exactly once across all retries
    assert sum(1 for e in log if e["batch"] == 1) == 1
    assert t["bucket_promotions"] == promoted[0].promotions


def test_bucketed_matches_unrolled(uni):
    """A/B: the bucketed lowering answers member-for-member identically
    to the unrolled reference program."""
    qs, _ = _course_scan_workload(uni)
    dag = build_dag({q.name: plan_for_cq(q) for q in qs})
    tt = E.tt_device_indexes(uni.store)
    rb = WorkloadExecutor(dag, uni.store.stats, {},
                          mode="bucketed").run(tt, {})
    ru = WorkloadExecutor(dag, uni.store.stats, {},
                          mode="unrolled").run(tt, {})
    assert set(rb) == set(ru)
    for name in rb:
        got_b = {tuple(r) for r in E.to_numpy(rb[name]).tolist()}
        got_u = {tuple(r) for r in E.to_numpy(ru[name]).tolist()}
        assert got_b == got_u, name


def test_learned_caps_carry_to_successor(uni, members, baseline_dag):
    """Capacities grown adaptively carry into a successor executor over
    a fresh DAG instance: the successor never re-learns them."""
    ms, _ = members
    tt = E.tt_device_indexes(uni.store)
    wl1 = WorkloadExecutor(baseline_dag, uni.store.stats, {},
                           cap_planner=lambda node, rows: 32, max_retries=24)
    wl1.run(tt, {})
    assert wl1.recompiles >= 1
    carry = wl1.learned_caps()
    assert carry  # keyed by content key, not node id
    assert all(isinstance(k, tuple) for k in carry)

    dag2 = build_dag({m.name: plan_for_cq(m) for m in ms})  # fresh ids
    wl2 = WorkloadExecutor(dag2, uni.store.stats, {},
                           cap_planner=lambda node, rows: 32, max_retries=24,
                           carry_caps=carry)
    roots = wl2.run(tt, {})
    assert wl2.recompiles == 0  # healed capacities carried over
    for m in ms:
        got = {tuple(r) for r in E.to_numpy(roots[m.name]).tolist()}
        assert got == R.evaluate_cq(m, uni.store).as_set(), m.name


def test_swap_state_carries_caps_and_prewarms(uni):
    """The hot-swap path threads learned capacities into the incoming
    program and pre-warms it: after swap_state the results cache is
    already seeded and nothing re-learns old overflows."""
    from repro.core.executor import QueryExecutor
    from repro.core.state import State

    qs, takes = _course_scan_workload(uni)
    # a state executing straight off the TT (scan nodes that CAN overflow
    # — the tiny LUBM instance tunes to view-only rewritings otherwise)
    state = State(views={}, queries=tuple(qs),
                  rewritings={q.name: plan_for_cq(q) for q in qs})
    groups = {q.name: [q.name] for q in qs}

    def planner(plan, rows):
        if isinstance(plan, TTScan) and plan.atom.p == takes:
            return 2
        return 512

    ex = QueryExecutor(uni.store, state, groups, cap_planner=planner,
                       max_retries=16)
    ex.answer_workload()
    assert ex.workload.recompiles >= 1
    grown = ex.workload.learned_caps()
    assert grown  # tiny caps forced adaptive growth
    ex.swap_state(state, groups)  # warm=True default
    assert ex.workload.carry_caps == grown
    assert ex.workload.recompiles == 0
    assert ex.workload.runs >= 1  # pre-warmed on the swap
    assert ex._results is not None  # serving cache seeded
    for q in qs:
        assert ex.answer_group(q.name) == ex.answer_group_direct(q.name)


def test_bucket_telemetry_reaches_server_stats(uni, report):
    srv = QueryServer(report.executor)
    srv.answer_batch([q.name for q in lubm_workload(uni.dictionary)])
    t = report.executor.telemetry()
    assert t["mode"] == "bucketed"
    assert t["buckets"] >= 1
    assert t["bucket_compiles"] + t["bucket_cache_hits"] >= t["buckets"]
    assert t["compile_cache"]["entries"] >= 1
    s = srv.stats
    assert s.buckets == t["buckets"]
    assert s.bucket_compiles == t["bucket_compiles"]
    assert s.bucket_cache_hits == t["bucket_cache_hits"]
    assert s.bucket_cache_misses == t["bucket_compiles"]
    assert s.bucket_compile_seconds == t["bucket_compile_seconds"]
    assert s.compile_cache_entries == t["compile_cache"]["entries"]


# ----------------------------------------------------------------------
# executor integration
# ----------------------------------------------------------------------
def test_executor_single_device_call_for_workload(uni, report):
    ex = report.executor
    ex.answer_workload()
    first_runs = ex.workload.runs
    # every member answer comes from the same cached fused run
    for name in ex._fns:
        got = {tuple(r) for r in ex.answer(name).tolist()}
        assert got == ex.answer_direct(name), name
    assert ex.workload.runs == first_runs
    assert ex.workload.compiles >= 1


def test_legacy_per_query_path_matches(uni, report):
    ex = report.executor
    for name in list(ex._fns)[:4]:
        got = {tuple(r) for r in ex.answer_per_query(name).tolist()}
        assert got == ex.answer_direct(name), name


# ----------------------------------------------------------------------
# device materialization
# ----------------------------------------------------------------------
def test_device_materialization_matches_oracle(uni, report):
    state = report.result.best
    ext_o, dev_o, info_o = materialize_state(state, uni.store)
    ext_d, dev_d, info_d = materialize_state_device(state, uni.store)
    assert set(ext_o) == set(ext_d)
    for vid in ext_o:
        assert ext_o[vid].cols == ext_d[vid].cols
        assert ext_o[vid].as_set() == ext_d[vid].as_set(), vid
        assert info_o[vid].rows == info_d[vid].rows
        assert int(dev_d[vid].n) == len(ext_o[vid].rows)


def test_executor_with_device_materialization(uni, report):
    from repro.core.executor import QueryExecutor

    ex = QueryExecutor(uni.store, report.result.best, report.groups,
                       device_materialize=True)
    for q in lubm_workload(uni.dictionary):
        assert ex.answer_group(q.name) == report.executor.answer_group(q.name)


# ----------------------------------------------------------------------
# serving
# ----------------------------------------------------------------------
def test_query_server_batched_requests(uni, report):
    srv = QueryServer(report.executor)
    names = [q.name for q in lubm_workload(uni.dictionary)]
    batch = names + names[:2] + ["no_such_query"]
    answers = srv.answer_batch(batch)
    for name, ans in zip(batch, answers):
        if name == "no_such_query":
            assert ans is None
        else:
            assert ans == report.executor.answer_group_direct(name), name
    assert srv.stats.requests == len(batch)
    assert srv.stats.unknown == 1
    assert srv.stats.device_runs >= 1
    # repeat batches never trigger extra device work
    runs = srv.stats.device_runs
    srv.answer_batch(names)
    assert srv.stats.device_runs == runs


def test_server_invalidate_refreshes_after_maintenance(uni, report):
    """invalidate(new_store) re-materializes views + re-uploads the TT:
    answers reflect the maintained store, not stale device snapshots."""
    from repro.core.executor import QueryExecutor
    from repro.rdf.triples import TripleStore

    srv = QueryServer(QueryExecutor(uni.store, report.result.best,
                                    report.groups))
    q = lubm_workload(uni.dictionary)[0]
    before = srv.answer(q.name)
    assert before == srv.executor.answer_group_direct(q.name)
    # crude maintenance event: drop a third of the triple table
    t = uni.store.triples
    srv.invalidate(TripleStore(t[: int(len(t) * 0.7)], uni.dictionary))
    after = srv.answer(q.name)
    assert after == srv.executor.answer_group_direct(q.name)
