"""RDFS reformulation: completeness w.r.t. instance saturation (claim 4)."""
import numpy as np
import pytest

from repro.core.queries import CQ, Atom, Const, Var
from repro.core.reformulation import reformulate, reformulate_workload
from repro.query import ref_engine as R
from repro.rdf.generator import generate, lubm_workload
from repro.rdf.triples import TripleStore


@pytest.fixture(scope="module")
def uni():
    return generate(n_universities=1, seed=0, dept_per_univ=1,
                    prof_per_dept=4, stud_per_dept=10, course_per_dept=5)


def saturated_store(uni):
    sat = uni.schema.saturate_instance(uni.store.triples, uni.type_id)
    return TripleStore(sat, uni.dictionary)


def test_type_query_reformulation_complete(uni):
    """eval(reformulated, raw) == eval(original, saturated)"""
    d = uni.dictionary
    x = Var("x")
    q = CQ((x,), (Atom(x, Const(uni.type_id), Const(d.lookup("ub:Student"))),),
           name="students")
    members = reformulate(q, uni.schema, uni.type_id)
    assert len(members) > 1  # subclasses + domain properties fired
    got = R.evaluate_ucq(members, uni.store)
    want = R.evaluate_cq(q, saturated_store(uni)).as_set()
    assert got == want
    assert len(want) > 0


def test_subproperty_reformulation_complete(uni):
    d = uni.dictionary
    x, y = Var("x"), Var("y")
    q = CQ((x, y), (Atom(x, Const(d.lookup("ub:worksFor")), y),), name="wf")
    members = reformulate(q, uni.schema, uni.type_id)
    # headOf is a subproperty of worksFor
    assert len(members) == 2
    got = R.evaluate_ucq(members, uni.store)
    want = R.evaluate_cq(q, saturated_store(uni)).as_set()
    assert got == want


def test_faculty_query_needs_reasoning(uni):
    """Plain evaluation misses answers the schema entails (the paper's
    motivation for reformulation)."""
    d = uni.dictionary
    x, y = Var("x"), Var("y")
    q = CQ((x, y), (
        Atom(x, Const(uni.type_id), Const(d.lookup("ub:Faculty"))),
        Atom(x, Const(d.lookup("ub:worksFor")), y),
    ), name="q4")
    plain = R.evaluate_cq(q, uni.store).as_set()
    members = reformulate(q, uni.schema, uni.type_id)
    got = R.evaluate_ucq(members, uni.store)
    want = R.evaluate_cq(q, saturated_store(uni)).as_set()
    assert plain == set()      # nothing is directly typed Faculty
    assert got == want and len(got) > 0


def test_whole_workload_reformulation_complete(uni):
    workload = lubm_workload(uni.dictionary)
    members, groups = reformulate_workload(workload, uni.schema, uni.type_id)
    sat = saturated_store(uni)
    for q in workload:
        got = set()
        member_by_name = {m.name: m for m in members}
        for name in groups[q.name]:
            got |= R.evaluate_cq(member_by_name[name], uni.store).as_set()
        want = R.evaluate_cq(q, sat).as_set()
        assert got == want, q.name


def test_reformulation_cap():
    from repro.rdf.dictionary import Dictionary
    from repro.rdf.schema import RDFSchema

    d = Dictionary()
    type_id = d.encode("rdf:type")
    sch = RDFSchema()
    base = d.encode("C0")
    for i in range(1, 40):
        sch.add_subclass(d.encode(f"C{i}"), base)
    x, y, z = Var("x"), Var("y"), Var("z")
    q = CQ((x,), (
        Atom(x, Const(type_id), Const(base)),
        Atom(y, Const(type_id), Const(base)),
        Atom(z, Const(type_id), Const(base)),
    ), name="big")
    with pytest.raises(ValueError, match="cap"):
        reformulate(q, sch, type_id, max_reformulations=100)
