"""Shape-bucket planning, the persistent compile cache, and bucketed
execution mechanics (query/buckets.py)."""
import pytest

from repro.core.queries import Atom, CQ, Const, Var
from repro.query import engine as E
from repro.query import ref_engine as R
from repro.query.buckets import (CAP_CEIL, BucketedProgram,
                                 clear_compile_cache, compile_cache,
                                 node_waves)
from repro.query.dag import build_dag
from repro.query.plan import plan_for_cq
from repro.rdf.generator import generate


@pytest.fixture(scope="module")
def uni():
    return generate(n_universities=1, seed=0, dept_per_univ=2,
                    prof_per_dept=4, stud_per_dept=12, course_per_dept=5)


def _queries(uni):
    """Two same-shape scans (different course constants), one
    different-shape scan, one join query."""
    d = uni.dictionary
    takes = Const(d.lookup("ub:takesCourse"))
    member = Const(d.lookup("ub:memberOf"))
    x, y = Var("x"), Var("y")
    return [
        CQ((x,), (Atom(x, takes, Const(d.lookup("u0.d0.c0"))),), name="c0"),
        CQ((x,), (Atom(x, takes, Const(d.lookup("u0.d0.c1"))),), name="c1"),
        CQ((x, y), (Atom(x, member, y),), name="m"),
        CQ((x, y), (Atom(x, takes, y),
                    Atom(x, member, Const(d.lookup("u0.d0")))), name="j"),
    ]


def _dag(uni, qs):
    return build_dag({q.name: plan_for_cq(q) for q in qs})


# ----------------------------------------------------------------------
# bucket planning
# ----------------------------------------------------------------------
def test_node_waves_topology(uni):
    dag = _dag(uni, _queries(uni))
    waves = node_waves(dag)
    for node in dag.nodes:
        for c in node.child_ids:
            assert waves[c] < waves[node.id]
    assert all(waves[n.id] == 0 for n in dag.nodes if not n.child_ids)


def test_same_shape_scans_share_a_bucket(uni):
    """Scans differing only in their bound constant are one bucket (the
    constant is scanned-over data); a structurally different scan is
    not."""
    qs = _queries(uni)
    dag = _dag(uni, qs[:3])  # c0, c1, m
    prog = BucketedProgram(dag, uni.store.stats, {},
                           cap_planner=lambda node, rows: 64)
    scan_buckets = [b for b in prog.buckets if b.kind == "scan"]
    assert sorted(len(b.node_ids) for b in scan_buckets) == [1, 2]
    shared = next(b for b in scan_buckets if len(b.node_ids) == 2)
    assert {dag.roots["c0"], dag.roots["c1"]} == set(shared.node_ids)
    # per-member constants stacked once at build time
    assert shared.pvals.shape[0] == 2


def test_buckets_split_by_capacity_class(uni):
    """Same structure, different planned capacity class -> different
    buckets (a batch must be shape-uniform)."""
    qs = _queries(uni)
    dag = _dag(uni, qs[:2])
    c0_root = dag.roots["c0"]

    def planner(plan, rows):
        # tell the two course scans apart via their bound object
        return 64 if plan.atom.o.id == qs[0].atoms[0].o.id else 128

    prog = BucketedProgram(dag, uni.store.stats, {}, cap_planner=planner)
    scan_buckets = [b for b in prog.buckets if b.kind == "scan"]
    assert len(scan_buckets) == 2
    assert {b.cap for b in scan_buckets} == {64, 128}
    assert prog.node_bucket[c0_root].cap == 64


def test_content_keys_stable_across_dag_instances(uni):
    """Content keys identify logical subtrees independent of DAG-local
    node ids — the contract behind capacity carry across hot swaps."""
    qs = _queries(uni)
    dag1 = _dag(uni, [qs[0], qs[2]])
    dag2 = _dag(uni, [qs[2], qs[1], qs[0]])  # different build order
    k1, k2 = dag1.content_keys(), dag2.content_keys()
    assert k1[dag1.roots["c0"]] == k2[dag2.roots["c0"]]
    assert k1[dag1.roots["m"]] == k2[dag2.roots["m"]]
    assert k2[dag2.roots["c0"]] != k2[dag2.roots["c1"]]


# ----------------------------------------------------------------------
# persistent compile cache
# ----------------------------------------------------------------------
def test_compile_cache_persists_across_programs(uni):
    """A rebuilt program over the same shapes pays zero compiles: every
    bucket body hits the process-global cache."""
    clear_compile_cache()
    qs = _queries(uni)
    tt = E.tt_device_indexes(uni.store)
    planner = lambda node, rows: 256

    p1 = BucketedProgram(_dag(uni, qs), uni.store.stats, {},
                         cap_planner=planner)
    roots, own = p1.execute(tt, {})
    assert not own.any()
    assert p1.cache_misses == p1.n_buckets and p1.cache_hits == 0
    assert p1.compile_seconds > 0

    p2 = BucketedProgram(_dag(uni, qs), uni.store.stats, {},
                         cap_planner=planner)
    roots2, own2 = p2.execute(tt, {})
    assert not own2.any()
    assert p2.cache_misses == 0 and p2.cache_hits == p2.n_buckets
    assert compile_cache().stats()["entries"] == p1.n_buckets
    for q in qs:
        got = {tuple(r) for r in E.to_numpy(roots2[q.name]).tolist()}
        assert got == R.evaluate_cq(q, uni.store).as_set(), q.name


# ----------------------------------------------------------------------
# promotion + padding
# ----------------------------------------------------------------------
def test_promotion_moves_whole_bucket_and_pads_consumers(uni):
    """Promoting via ONE member moves every member of the bucket to the
    next capacity class; consumers pad operands up to the new class and
    results stay oracle-exact."""
    clear_compile_cache()
    qs = _queries(uni)
    dag = _dag(uni, qs)
    tt = E.tt_device_indexes(uni.store)
    prog = BucketedProgram(dag, uni.store.stats, {},
                           cap_planner=lambda node, rows: 128)
    _, own1 = prog.execute(tt, {})
    assert not own1.any()

    scan_bucket = next(b for b in prog.buckets
                       if b.kind == "scan" and len(b.node_ids) >= 2)
    grown = prog.promote([scan_bucket.node_ids[0]])
    assert {nid for nid, _, _ in grown} == set(scan_bucket.node_ids)
    assert all(old == 128 and new == 256 for _, old, new in grown)
    assert scan_bucket.cap == 256 and scan_bucket.promotions == 1

    roots2, own2 = prog.execute(tt, {})
    assert not own2.any()
    for q in qs:
        got = {tuple(r) for r in E.to_numpy(roots2[q.name]).tolist()}
        assert got == R.evaluate_cq(q, uni.store).as_set(), q.name


def test_promotion_stops_at_ceiling(uni):
    qs = _queries(uni)
    dag = _dag(uni, qs[:1])
    prog = BucketedProgram(dag, uni.store.stats, {},
                           cap_planner=lambda node, rows: CAP_CEIL)
    assert prog.promote([dag.roots["c0"]]) == []


def test_promotion_skips_capacityless_buckets(uni):
    """Filter/project buckets have no own buffer (cap 0) — promoting
    through them is a no-op."""
    qs = _queries(uni)
    dag = _dag(uni, qs)
    prog = BucketedProgram(dag, uni.store.stats, {},
                           cap_planner=lambda node, rows: 64)
    capless = [nid for nid, b in prog.node_bucket.items() if b.cap == 0]
    if capless:  # plan shapes may or may not include filter/project
        assert prog.promote(capless) == []
