"""Training substrate: optimizer, train loop, checkpoint/restart (bitwise
resume), elastic re-sharding, straggler monitor, data pipeline."""
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.checkpoint import checkpoint as C
from repro.data.pipeline import PipelineConfig, RDFTokenPipeline, SyntheticPipeline
from repro.distributed.fault import StragglerMonitor, TrainSupervisor
from repro.models.model import build_model
from repro.train.optimizer import OptConfig, lr_at
from repro.train.train_step import (TrainConfig, init_train_state,
                                    make_train_step)


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("qwen2.5-32b")
    model = build_model(cfg)
    tc = TrainConfig(opt=OptConfig(lr=1e-3, warmup_steps=2, total_steps=50),
                     remat="none")
    state = init_train_state(model, tc, jax.random.key(0))
    step = jax.jit(make_train_step(model, tc))
    pipe = iter(SyntheticPipeline(PipelineConfig(seq_len=16, batch_size=4,
                                                 vocab=cfg.vocab)))
    return model, tc, state, step, pipe


def test_loss_decreases_over_steps(setup):
    model, tc, state, step, _ = setup
    # memorize one small batch: loss must drop steeply
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(8, 100, size=(4, 16)).astype(np.int32)),
    }
    batch["labels"] = jnp.roll(batch["tokens"], -1, axis=1)
    losses = []
    for _ in range(20):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] * 0.7, losses[:3] + losses[-3:]
    assert all(np.isfinite(losses))


def test_grad_accumulation_matches_full_batch():
    cfg = get_smoke_config("granite-20b")
    model = build_model(cfg)
    base = TrainConfig(opt=OptConfig(lr=1e-3, clip_norm=1e9), remat="none")
    accum = TrainConfig(opt=OptConfig(lr=1e-3, clip_norm=1e9), remat="none",
                        accum_steps=2)
    state0 = init_train_state(model, base, jax.random.key(1))
    rng = np.random.default_rng(1)
    batch = {
        "tokens": jnp.asarray(rng.integers(8, 100, size=(4, 16)).astype(np.int32)),
    }
    batch["labels"] = jnp.roll(batch["tokens"], -1, axis=1)
    s1, m1 = jax.jit(make_train_step(model, base))(state0, batch)
    s2, m2 = jax.jit(make_train_step(model, accum))(state0, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-5)
    l1 = jax.tree.leaves(s1["params"])
    l2 = jax.tree.leaves(s2["params"])
    for a, b in zip(l1, l2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-6)


def test_lr_schedule():
    oc = OptConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_frac=0.1)
    assert float(lr_at(oc, jnp.int32(0))) == 0.0
    assert abs(float(lr_at(oc, jnp.int32(10))) - 1.0) < 1e-6
    assert float(lr_at(oc, jnp.int32(100))) == pytest.approx(0.1, rel=1e-3)


def test_checkpoint_restart_bitwise(tmp_path, setup):
    """Preemption drill: train 6 steps with saves, 'crash', resume from
    step 4, replay -> final state identical to the uninterrupted run."""
    model, tc, state0, step, _ = setup
    rng = np.random.default_rng(2)
    batches = []
    for _ in range(6):
        t = jnp.asarray(rng.integers(8, 100, size=(4, 16)).astype(np.int32))
        batches.append({"tokens": t, "labels": jnp.roll(t, -1, axis=1)})

    ckpt = str(tmp_path / "ckpts")
    sup = TrainSupervisor(ckpt, save_every=2, keep=5)
    state = state0
    for i, b in enumerate(batches, start=1):
        state, _ = step(state, b)
        sup.maybe_save(i, state)
    final_uninterrupted = state

    # simulated preemption: process restarts, resumes from latest (step 6)
    # then from an older step (4) replaying the tail
    state_r, start = sup.resume_or_init(lambda: state0)
    assert start == 6
    state4 = C.restore(ckpt, 4, state0)
    for b in batches[4:]:
        state4, _ = step(state4, b)
    for a, b in zip(jax.tree.leaves(final_uninterrupted),
                    jax.tree.leaves(state4)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_gc_and_atomicity(tmp_path, setup):
    model, tc, state, _, _ = setup
    ckpt = str(tmp_path / "gc")
    for s in [1, 2, 3, 4, 5]:
        C.save(ckpt, s, {"x": jnp.ones((4,)) * s}, keep=2)
    assert C.list_steps(ckpt) == [4, 5]
    assert not any(p.endswith(".tmp") for p in os.listdir(ckpt))


def test_elastic_restore_new_mesh(tmp_path):
    """Save unsharded, restore onto a different (simulated) topology: the
    manifest path is mesh-agnostic, restore re-shards via device_put."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import make_host_mesh

    state = {"w": jnp.arange(16, dtype=jnp.float32).reshape(4, 4)}
    ckpt = str(tmp_path / "elastic")
    C.save(ckpt, 1, state)
    mesh = make_host_mesh(1)
    sh = {"w": NamedSharding(mesh, P("data"))}
    restored = C.restore(ckpt, 1, state, sh)
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(state["w"]))
    assert restored["w"].sharding == sh["w"]


def test_straggler_monitor():
    mon = StragglerMonitor(window=10, threshold=2.0)
    for step in range(10):
        for host in range(8):
            mon.record(host, 1.0 + 0.01 * host)
        mon.record(8, 5.0)  # slow host
    assert mon.check() == {8}


def test_rdf_pipeline_feeds_training(tmp_path):
    """End-to-end paper->trainer integration: wizard-tuned views feed
    token batches."""
    from repro.core.search import SearchConfig
    from repro.core.wizard import WizardConfig, tune
    from repro.rdf.generator import generate, lubm_workload

    uni = generate(1, seed=0, dept_per_univ=1, prof_per_dept=3,
                   stud_per_dept=8, course_per_dept=4)
    rep = tune(uni.store, lubm_workload(uni.dictionary), uni.schema,
               uni.type_id,
               WizardConfig(search=SearchConfig(strategy="greedy",
                                                max_states=100)))
    cfg = get_smoke_config("rwkv6-3b")
    model = build_model(cfg)
    pipe = iter(RDFTokenPipeline(rep.executor,
                                 PipelineConfig(seq_len=16, batch_size=2,
                                                vocab=cfg.vocab)))
    tc = TrainConfig(remat="none")
    state = init_train_state(model, tc, jax.random.key(3))
    step = jax.jit(make_train_step(model, tc))
    for _ in range(3):
        batch = next(pipe)
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        state, metrics = step(state, batch)
        assert np.isfinite(float(metrics["loss"]))
