"""Prefill -> decode cache handoff: prefill S0 tokens, then teacher-forced
decode must reproduce the parallel forward's logits at every continued
position — for every cache family (full KV, rolling-window KV, SSM state,
WKV state, shared-attn hybrid, enc-dec cross-attn)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.models.model import build_model

ARCHS = ["qwen2.5-32b", "gemma3-12b", "rwkv6-3b", "zamba2-1.2b",
         "granite-moe-1b-a400m", "whisper-base", "qwen2-vl-2b"]


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_then_decode_matches_forward(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    B, S0, S1 = 1, 8, 12  # prefill 8, decode 4 more
    if cfg.ssm is not None:
        # full-sequence reference + prefill both need chunk-divisible seqs
        S0 = max(S0, cfg.ssm.chunk)
        S1 = 2 * S0
    cache_len = S1 + 4
    rng = np.random.default_rng(7)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, size=(B, S1)).astype(np.int32))
    kw = {}
    if cfg.encoder is not None:
        kw["enc_frames"] = jnp.asarray(
            rng.normal(size=(B, 8, cfg.encoder.d_input)).astype(np.float32))

    ref = model.forward(params, tokens=tokens, **kw)

    logits0, cache = model.prefill_with_cache(
        params, tokens=tokens[:, :S0], cache_len=cache_len, **kw)
    np.testing.assert_allclose(np.asarray(logits0), np.asarray(ref[:, :S0]),
                               rtol=3e-2, atol=3e-2)

    step = jax.jit(model.decode_step)
    for t in range(S0, S1):
        logits, cache = step(params, tokens[:, t: t + 1], jnp.int32(t), cache)
        np.testing.assert_allclose(
            np.asarray(logits[:, 0]), np.asarray(ref[:, t]),
            rtol=3e-2, atol=3e-2,
            err_msg=f"{arch}: divergence at position {t}")
