"""The CLI launchers run end-to-end (subprocess smoke)."""
import os
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(args, timeout=600):
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    return subprocess.run([sys.executable, "-m"] + args, capture_output=True,
                          text=True, env=env, cwd=ROOT, timeout=timeout)


def test_tune_cli():
    res = _run(["repro.launch.tune", "--universities", "1",
                "--strategy", "greedy", "--max-states", "100", "--verify"])
    assert res.returncode == 0, res.stderr[-2000:]
    assert "verification: PASSED" in res.stdout


def test_train_cli_with_checkpoint_resume(tmp_path):
    ckpt = str(tmp_path / "ck")
    res = _run(["repro.launch.train", "--arch", "whisper-base", "--smoke",
                "--steps", "6", "--batch", "2", "--seq", "16",
                "--data", "synthetic", "--ckpt", ckpt, "--save-every", "2"])
    assert res.returncode == 0, res.stderr[-2000:]
    assert "done" in res.stdout
    # resume continues from the saved step
    res2 = _run(["repro.launch.train", "--arch", "whisper-base", "--smoke",
                 "--steps", "8", "--batch", "2", "--seq", "16",
                 "--data", "synthetic", "--ckpt", ckpt, "--save-every", "2"])
    assert res2.returncode == 0, res2.stderr[-2000:]
    assert "resumed from step 6" in res2.stdout
