"""Per-kernel validation: interpret-mode Pallas vs pure-jnp oracles,
swept across shapes/dtypes, plus engine integration (use_pallas=True)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.filter_compact import filter_mask_pallas
from repro.kernels.join_count import join_count_pallas

SENTINEL = 2**31 - 1


def _random_join_inputs(rng, n_probe, n_build, key_space, invalid_frac=0.1):
    probe = rng.integers(0, key_space, size=n_probe).astype(np.int32)
    inv = rng.random(n_probe) < invalid_frac
    probe[inv] = -1
    build = np.sort(rng.integers(0, key_space, size=n_build).astype(np.int32))
    n_pad = rng.integers(0, max(n_build // 4, 1))
    build[n_build - n_pad:] = SENTINEL
    return jnp.asarray(probe), jnp.asarray(build)


@pytest.mark.parametrize("n_probe,n_build", [
    (1, 1), (7, 13), (128, 256), (300, 1000), (1024, 64), (513, 511),
])
@pytest.mark.parametrize("key_space", [4, 1000])
def test_join_count_shapes(n_probe, n_build, key_space):
    rng = np.random.default_rng(n_probe * 31 + n_build)
    probe, build = _random_join_inputs(rng, n_probe, n_build, key_space)
    lo, cnt = join_count_pallas(probe, build, interpret=True)
    lo_ref, cnt_ref = ref.join_count_ref(probe, build)
    np.testing.assert_array_equal(np.asarray(lo), np.asarray(lo_ref))
    np.testing.assert_array_equal(np.asarray(cnt), np.asarray(cnt_ref))


@pytest.mark.parametrize("bl,bs", [(8, 16), (256, 512), (64, 1024)])
def test_join_count_block_shapes(bl, bs):
    rng = np.random.default_rng(0)
    probe, build = _random_join_inputs(rng, 500, 700, 50)
    lo, cnt = join_count_pallas(probe, build, bl=bl, bs=bs, interpret=True)
    lo_ref, cnt_ref = ref.join_count_ref(probe, build)
    np.testing.assert_array_equal(np.asarray(lo), np.asarray(lo_ref))
    np.testing.assert_array_equal(np.asarray(cnt), np.asarray(cnt_ref))


def test_join_count_all_invalid():
    probe = jnp.full((64,), -1, jnp.int32)
    build = jnp.sort(jnp.arange(32, dtype=jnp.int32))
    lo, cnt = join_count_pallas(probe, build, interpret=True)
    assert int(cnt.sum()) == 0


def test_join_count_duplicates_heavy():
    probe = jnp.asarray(np.full(200, 7, np.int32))
    build = jnp.asarray(np.sort(np.full(300, 7, np.int32)))
    lo, cnt = join_count_pallas(probe, build, interpret=True)
    assert int(lo[0]) == 0
    np.testing.assert_array_equal(np.asarray(cnt), np.full(200, 300))


@pytest.mark.parametrize("n,w", [(1, 2), (100, 3), (999, 5), (2048, 7)])
@pytest.mark.parametrize("nconds", [0, 1, 2])
def test_filter_mask_shapes(n, w, nconds):
    rng = np.random.default_rng(n * 7 + w)
    rows = rng.integers(0, 9, size=(n, w)).astype(np.int32)
    rows[rng.random(n) < 0.1] = -1  # invalid rows
    conds = tuple((int(rng.integers(0, w)), int(rng.integers(0, 9)))
                  for _ in range(nconds))
    mask, counts = filter_mask_pallas(jnp.asarray(rows), conds, interpret=True)
    mask_ref, counts_ref = ref.filter_mask_ref(jnp.asarray(rows), conds, br=512)
    np.testing.assert_array_equal(np.asarray(mask), np.asarray(mask_ref))
    np.testing.assert_array_equal(np.asarray(counts), np.asarray(counts_ref))
    assert int(counts.sum()) == int(mask.sum())


@pytest.mark.parametrize("br", [8, 128, 512])
def test_filter_mask_block_sweep(br):
    rng = np.random.default_rng(3)
    rows = rng.integers(0, 5, size=(777, 4)).astype(np.int32)
    conds = ((1, 2), (3, 4))
    mask, counts = filter_mask_pallas(jnp.asarray(rows), conds, br=br,
                                      interpret=True)
    mask_ref, counts_ref = ref.filter_mask_ref(jnp.asarray(rows), conds, br=br)
    np.testing.assert_array_equal(np.asarray(mask), np.asarray(mask_ref))
    np.testing.assert_array_equal(np.asarray(counts), np.asarray(counts_ref))


def test_engine_with_pallas_join_matches_oracle():
    """End-to-end: the JAX engine with use_pallas=True answers the whole
    LUBM workload identically to the oracle."""
    from repro.query import engine as E
    from repro.query import ref_engine as R
    from repro.query.plan import plan_for_cq
    from repro.rdf.generator import generate, lubm_workload

    uni = generate(n_universities=1, seed=0)
    tt = E.tt_device_indexes(uni.store)
    for q in lubm_workload(uni.dictionary):
        fn = E.build_executor(plan_for_cq(q), uni.store.stats, {}, use_pallas=True)
        out = fn(tt, {})
        assert not bool(out.overflow)
        got = {tuple(r) for r in E.to_numpy(out).tolist()}
        want = R.evaluate_cq(q, uni.store).as_set()
        assert got == want, q.name


def test_property_join_count_random():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings
    from hypothesis import strategies as st

    @settings(max_examples=30, deadline=None)
    @given(seed=st.integers(0, 10**6), n_probe=st.integers(1, 400),
           n_build=st.integers(1, 400), ks=st.integers(1, 30))
    def inner(seed, n_probe, n_build, ks):
        rng = np.random.default_rng(seed)
        probe, build = _random_join_inputs(rng, n_probe, n_build, ks)
        lo, cnt = join_count_pallas(probe, build, bl=64, bs=128, interpret=True)
        lo_ref, cnt_ref = ref.join_count_ref(probe, build)
        np.testing.assert_array_equal(np.asarray(lo), np.asarray(lo_ref))
        np.testing.assert_array_equal(np.asarray(cnt), np.asarray(cnt_ref))

    inner()


# ----------------------------------------------------------------------
# flash attention kernel
# ----------------------------------------------------------------------
@pytest.mark.parametrize("B,S,H,Hkv,hd", [
    (1, 32, 4, 2, 16), (2, 64, 4, 4, 32), (1, 128, 8, 2, 16),
])
@pytest.mark.parametrize("window", [0, 16])
def test_flash_attention_matches_ref(B, S, H, Hkv, hd, window):
    from repro.kernels.flash_attn import flash_attention_pallas

    rng = np.random.default_rng(B * 97 + S + window)
    q = jnp.asarray(rng.normal(size=(B, S, H, hd)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(B, S, Hkv, hd)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, S, Hkv, hd)).astype(np.float32))
    got = flash_attention_pallas(q, k, v, window=window, cq=16, ck=16,
                                 interpret=True)
    want = ref.flash_attention_ref(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("cq,ck", [(8, 32), (32, 8), (64, 64)])
def test_flash_attention_block_sweep(cq, ck):
    from repro.kernels.flash_attn import flash_attention_pallas

    rng = np.random.default_rng(5)
    q = jnp.asarray(rng.normal(size=(1, 64, 4, 16)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(1, 64, 2, 16)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(1, 64, 2, 16)).astype(np.float32))
    got = flash_attention_pallas(q, k, v, cq=cq, ck=ck, interpret=True)
    want = ref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_flash_attention_bf16():
    from repro.kernels.flash_attn import flash_attention_pallas

    rng = np.random.default_rng(6)
    q = jnp.asarray(rng.normal(size=(1, 32, 2, 16))).astype(jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(1, 32, 2, 16))).astype(jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(1, 32, 2, 16))).astype(jnp.bfloat16)
    got = flash_attention_pallas(q, k, v, cq=16, ck=16, interpret=True)
    want = ref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=3e-2, atol=3e-2)
