"""Serving layer: batched server loop + prefill entry point."""
import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.models.model import build_model
from repro.serve.serve_step import (BatchedServer, ServeConfig, make_prefill,
                                    make_serve_step)


def test_batched_server_produces_tokens():
    cfg = get_smoke_config("gemma3-12b")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    srv = BatchedServer(model, params, ServeConfig(cache_len=32), batch=4,
                        max_new=4)
    done = srv.run(steps=8)
    assert len(done) == 8  # 4 slots x (8 steps / 4 max_new)
    for seq in done:
        assert all(0 <= t < cfg.vocab for t in seq)


def test_serve_step_sampling_deterministic_greedy():
    cfg = get_smoke_config("granite-20b")
    model = build_model(cfg)
    params = model.init(jax.random.key(1))
    step = jax.jit(make_serve_step(model, ServeConfig(temperature=0.0)))
    cache = model.init_cache(2, 16)
    tok = jnp.ones((2, 1), jnp.int32)
    t1, _ = step(params, cache, tok, jnp.int32(0), jax.random.key(0))
    t2, _ = step(params, cache, tok, jnp.int32(0), jax.random.key(1))
    np.testing.assert_array_equal(np.asarray(t1), np.asarray(t2))


def test_prefill_matches_forward():
    cfg = get_smoke_config("qwen2-vl-2b")
    model = build_model(cfg)
    params = model.init(jax.random.key(2))
    prefill = jax.jit(make_prefill(model))
    toks = jnp.arange(8, dtype=jnp.int32)[None, :] % cfg.vocab
    np.testing.assert_array_equal(
        np.asarray(prefill(params, toks)),
        np.asarray(model.forward(params, tokens=toks)))
