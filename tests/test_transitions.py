"""Transition correctness: the state invariant (rewritings answer the
workload exactly) must hold after any sequence of transitions.

Includes a hypothesis property test driving random transition paths.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from repro.core.queries import CQ, Atom, Const, Var
from repro.core.state import initial_state
from repro.core.transitions import (apply_fusion, apply_join_cut,
                                    apply_selection_cut, fusion_candidates,
                                    is_fully_relaxed, join_cut_candidates,
                                    selection_cut_candidates, successors)
from repro.query import ref_engine as R
from repro.rdf.generator import generate, lubm_workload


@pytest.fixture(scope="module")
def uni():
    return generate(n_universities=1, seed=0)


@pytest.fixture(scope="module")
def workload(uni):
    return lubm_workload(uni.dictionary)


def check_invariant(state, store):
    """Materialize views (oracle) and check every rewriting answers its query."""
    extents = {
        vid: R.evaluate_cq(v.cq, store) for vid, v in state.views.items()
    }
    for q in state.queries:
        got = R.execute(state.rewritings[q.name], store, extents).as_set()
        want = R.evaluate_cq(q, store).as_set()
        assert got == want, (
            f"{q.name} broken after {state.path}: "
            f"extra={list(got - want)[:3]} missing={list(want - got)[:3]}"
        )


def test_initial_state_invariant(uni, workload):
    st0 = initial_state(workload)
    assert len(st0.views) == len(workload)
    check_invariant(st0, uni.store)


def test_selection_cut(uni, workload):
    st0 = initial_state(workload)
    cands = list(selection_cut_candidates(st0))
    assert cands, "workload has constants to cut"
    for cand in cands:
        st1 = apply_selection_cut(st0, *cand)
        check_invariant(st1, uni.store)
        # the cut view got strictly fewer constants
        assert st1.key() != st0.key()


def test_join_cut(uni, workload):
    st0 = initial_state(workload)
    cands = list(join_cut_candidates(st0))
    assert cands, "workload has joins to cut"
    for cand in cands[:10]:
        st1 = apply_join_cut(st0, *cand)
        check_invariant(st1, uni.store)


def test_fusion_after_relaxation(uni):
    d = uni.dictionary
    t = Const(uni.type_id)
    takes = Const(d.lookup("ub:takesCourse"))
    grad = Const(d.lookup("ub:GraduateStudent"))
    under = Const(d.lookup("ub:UndergraduateStudent"))
    x, y = Var("x"), Var("y")
    q_a = CQ((x, y), (Atom(x, t, grad), Atom(x, takes, y)), name="qa")
    q_b = CQ((x, y), (Atom(x, t, under), Atom(x, takes, y)), name="qb")
    st0 = initial_state([q_a, q_b])
    assert not list(fusion_candidates(st0))
    # cut the differing constants -> views become isomorphic -> fusion fires
    st1 = st0
    for vid, ai, pos in list(selection_cut_candidates(st1)):
        if vid in st1.views:
            st1 = apply_selection_cut(st1, vid, ai, pos)
    # re-enumerate on the new state (ids changed)
    while True:
        cands = list(selection_cut_candidates(st1))
        if not cands:
            break
        st1 = apply_selection_cut(st1, *cands[0])
    fus = list(fusion_candidates(st1))
    assert fus, "fully-relaxed identical views must be fusable"
    st2 = apply_fusion(st1, *fus[0])
    assert len(st2.views) < len(st1.views)
    check_invariant(st2, uni.store)


def test_fusion_identical_queries(uni, workload):
    q1 = workload[0]
    q1_dup = CQ(q1.head, q1.atoms, name="q1dup", weight=2.0)
    st0 = initial_state([q1, q1_dup])
    fus = list(fusion_candidates(st0))
    assert fus
    st1 = apply_fusion(st0, *fus[0])
    assert len(st1.views) == 1
    check_invariant(st1, uni.store)


def test_fully_relaxed_detection():
    x, y, p = Var("x"), Var("y"), Var("p")
    q = CQ((x, y), (Atom(x, p, y),), name="q")
    st0 = initial_state([q])
    assert is_fully_relaxed(st0)
    q2 = CQ((x, y), (Atom(x, Const(5), y),), name="q2")
    assert not is_fully_relaxed(initial_state([q2]))


@settings(max_examples=20, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(seed=st.integers(0, 10_000), steps=st.integers(1, 6))
def test_random_transition_paths_preserve_answers(seed, steps):
    """PROPERTY: any transition path preserves workload answers."""
    rng = np.random.default_rng(seed)
    uni = generate(n_universities=1, seed=1, dept_per_univ=1,
                   prof_per_dept=3, stud_per_dept=8, course_per_dept=4)
    workload = lubm_workload(uni.dictionary)[:4]
    state = initial_state(workload)
    for _ in range(steps):
        succ = list(successors(state))
        if not succ:
            break
        state = succ[int(rng.integers(0, len(succ)))]
    check_invariant(state, uni.store)


def test_transition_paths_with_predicate_cuts(uni, workload):
    state = initial_state(workload[:2])
    rng = np.random.default_rng(7)
    for _ in range(4):
        succ = list(successors(state, allow_predicate_cut=True))
        if not succ:
            break
        state = succ[int(rng.integers(0, len(succ)))]
    check_invariant(state, uni.store)
