"""Per-architecture smoke tests: reduced configs, one forward + one train
step + decode steps on CPU; asserts shapes and finiteness.

Also checks decode-vs-forward consistency for the cached attention path
(prefill-free: step-by-step decode must match the parallel forward).
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config, list_archs
from repro.models.model import build_model

ARCHS = list_archs()


def _inputs(cfg, batch=2, seq=16, key=0):
    rng = np.random.default_rng(key)
    kw = {}
    if cfg.encoder is not None:
        kw["enc_frames"] = jnp.asarray(
            rng.normal(size=(batch, 8, cfg.encoder.d_input)).astype(np.float32))
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, size=(batch, seq)).astype(np.int32))
    if cfg.mrope:
        pos = np.broadcast_to(np.arange(seq, dtype=np.int32)[None, :, None],
                              (batch, seq, 3)).copy()
        # make h/w coordinates diverge for a few "image" positions
        pos[:, : seq // 2, 1] += 3
        pos[:, : seq // 2, 2] += 5
        kw["positions"] = jnp.asarray(pos)
    return tokens, kw


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finiteness(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    tokens, kw = _inputs(cfg)
    logits = jax.jit(lambda p, t: model.forward(p, tokens=t, **kw))(params, tokens)
    assert logits.shape == (2, 16, cfg.vocab)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite logits"


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step_reduces_loss_finite_grads(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.key(1))
    tokens, kw = _inputs(cfg, batch=2, seq=16, key=1)
    labels = jnp.roll(tokens, -1, axis=1)

    def loss_fn(p):
        logits = model.forward(p, tokens=tokens, **kw)
        lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        nll = -jnp.take_along_axis(lp, labels[..., None], axis=-1)
        return nll.mean()

    loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
    assert bool(jnp.isfinite(loss))
    flat = jax.tree.leaves(grads)
    assert all(bool(jnp.isfinite(g).all()) for g in flat), f"{arch}: bad grads"
    # one SGD step must change the loss
    params2 = jax.tree.map(lambda p, g: p - 0.1 * g, params, grads)
    loss2 = jax.jit(loss_fn)(params2)
    assert bool(jnp.isfinite(loss2))
    assert float(loss2) != float(loss)


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_steps_finite(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.key(2))
    B, cache_len = 2, 32
    enc_len = 8 if cfg.encoder is not None else 0
    cache = model.init_cache(B, cache_len, enc_len)
    step = jax.jit(model.decode_step)
    tok = jnp.zeros((B, 1), jnp.int32)
    for pos in range(3):
        logits, cache = step(params, tok, jnp.int32(pos), cache)
        assert logits.shape == (B, 1, cfg.vocab)
        assert bool(jnp.isfinite(logits).all()), f"{arch}: step {pos}"
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)


@pytest.mark.parametrize("arch", ["qwen2.5-32b", "gemma3-12b", "rwkv6-3b",
                                  "zamba2-1.2b", "granite-moe-1b-a400m"])
def test_decode_matches_forward(arch):
    """Teacher-forced step-by-step decode == parallel forward logits."""
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.key(3))
    B, S = 1, 8
    rng = np.random.default_rng(5)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, size=(B, S)).astype(np.int32))
    ref = model.forward(params, tokens=tokens)

    cache = model.init_cache(B, cache_len=S)
    step = jax.jit(model.decode_step)
    outs = []
    for t in range(S):
        logits, cache = step(params, tokens[:, t: t + 1], jnp.int32(t), cache)
        outs.append(logits[:, 0])
    got = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-2, atol=2e-2)


def test_param_counts_match_templates():
    """Analytic param_count tracks the template within 12% (sanity of the
    roofline MODEL_FLOPS term)."""
    from repro.models.params import count_params

    for arch in ARCHS:
        cfg = get_smoke_config(arch)
        model = build_model(cfg)
        tpl = count_params(model.template)
        analytic = cfg.param_count()
        assert abs(tpl - analytic) / tpl < 0.12, (
            f"{arch}: template={tpl} analytic={analytic}")


def test_full_configs_construct():
    """Full published configs build templates (no allocation) with sane
    parameter counts."""
    from repro.configs import get_config
    from repro.models.params import count_params

    expected_b = {
        "granite-moe-1b-a400m": (0.8, 2.0),
        "llama4-maverick-400b-a17b": (300, 800),
        "qwen2.5-32b": (28, 40),
        "deepseek-67b": (60, 75),
        "gemma3-12b": (9, 16),
        # assignment config w/ SwiGLU FFN: 3 matrices (published granite
        # uses a 2-matrix GPT-BigCode FFN, hence "20b")
        "granite-20b": (18, 30),
        "rwkv6-3b": (2.5, 5),
        "qwen2-vl-2b": (1.2, 2.5),
        "whisper-base": (0.05, 0.12),
        "zamba2-1.2b": (0.9, 1.8),
    }
    for arch, (lo, hi) in expected_b.items():
        cfg = get_config(arch)
        model = build_model(cfg)
        n = count_params(model.template) / 1e9
        assert lo <= n <= hi, f"{arch}: {n:.2f}B params out of range [{lo},{hi}]"
