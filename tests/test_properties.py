"""Hypothesis property tests on system invariants beyond the transition
suite: canonicalization, padded-join equivalence, capacity planning,
reformulation completeness under random schemas."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import HealthCheck, assume, given, settings
from hypothesis import strategies as st

import jax.numpy as jnp

from repro.core.queries import CQ, Atom, Const, Var, full_projection
from repro.query import engine as E
from repro.query.cost import capacity_for


# ----------------------------------------------------------------------
# canonicalization: invariant under atom permutation + variable renaming
# ----------------------------------------------------------------------
def _random_cq(rng, n_atoms, n_vars, n_consts):
    vars_ = [Var(f"v{i}") for i in range(n_vars)]
    atoms = []
    for _ in range(n_atoms):
        terms = []
        for _ in range(3):
            if rng.random() < 0.5:
                terms.append(vars_[int(rng.integers(0, n_vars))])
            else:
                terms.append(Const(int(rng.integers(0, n_consts))))
        atoms.append(Atom(*terms))
    return full_projection(atoms, name="q")


@settings(max_examples=60, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(seed=st.integers(0, 10**6), n_atoms=st.integers(1, 4),
       n_vars=st.integers(1, 4))
def test_canonical_key_invariance(seed, n_atoms, n_vars):
    rng = np.random.default_rng(seed)
    cq = _random_cq(rng, n_atoms, n_vars, 5)
    key = cq.canonical_key()

    # permute atoms
    perm = rng.permutation(len(cq.atoms))
    cq_p = full_projection([cq.atoms[i] for i in perm], name="p")
    assert cq_p.canonical_key() == key

    # rename variables bijectively
    mapping = {v: Var(f"w{i+100}") for i, v in enumerate(cq.all_vars())}
    cq_r = full_projection([a.substitute(mapping) for a in cq.atoms], name="r")
    assert cq_r.canonical_key() == key

    # changing a constant must change the key (unless it collides with the
    # same shape... we pick a fresh constant id to guarantee a difference)
    for i, a in enumerate(cq.atoms):
        consts = a.consts()
        if consts:
            pos, _ = consts[0]
            terms = list(a.terms())
            terms[pos] = Const(999)
            atoms2 = list(cq.atoms)
            atoms2[i] = Atom(*terms)
            cq_c = full_projection(atoms2, name="c")
            assert cq_c.canonical_key() != key
            break


# ----------------------------------------------------------------------
# padded join == numpy reference on random relations
# ----------------------------------------------------------------------
@settings(max_examples=40, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(seed=st.integers(0, 10**6), nl=st.integers(0, 40),
       nr=st.integers(0, 40), ks=st.integers(1, 8),
       right_sorted=st.booleans())
def test_padded_join_matches_numpy(seed, nl, nr, ks, right_sorted):
    rng = np.random.default_rng(seed)
    lrows = rng.integers(0, ks, size=(nl, 2)).astype(np.int32)
    rrows = rng.integers(0, ks, size=(nr, 2)).astype(np.int32)
    if right_sorted and nr:
        rrows = rrows[np.argsort(rrows[:, 0], kind="stable")]
    left = E.make_prel(lrows, cap=64)
    right = E.make_prel(rrows, cap=64)
    out = E.join(left, right, 0, 0, residual=(), keep_right=(1,),
                 out_cap=1 << 12, right_sorted=right_sorted)
    assert not bool(out.overflow)
    got = sorted(map(tuple, E.to_numpy(out).tolist()))
    want = sorted(
        (int(a), int(b), int(d))
        for a, b in lrows.tolist()
        for c, d in rrows.tolist()
        if a == c
    )
    assert got == want


@settings(max_examples=40, deadline=None)
@given(rows=st.floats(0.1, 1e7), safety=st.floats(1.0, 8.0))
def test_capacity_planner_properties(rows, safety):
    cap = capacity_for(rows, safety=safety)
    assert cap >= min(rows * safety, 1 << 22) * 0.999 or cap == 1 << 22
    assert cap & (cap - 1) == 0  # power of two
    assert 128 <= cap <= 1 << 22


# ----------------------------------------------------------------------
# workload-DAG canonical-key soundness: interning two plans to one node
# must be answer-preserving, and renaming columns must not split nodes
# ----------------------------------------------------------------------
@settings(max_examples=40, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(seed=st.integers(0, 10**6), n_atoms=st.integers(1, 3),
       n_vars=st.integers(1, 4))
def test_dag_canonical_keys_sound(seed, n_atoms, n_vars):
    from repro.analysis import verify_dag
    from repro.query import ref_engine as R
    from repro.query.dag import build_dag
    from repro.query.plan import (has_cartesian, plan_for_cq,
                                  rename_columns)
    from repro.rdf.triples import TripleStore

    rng = np.random.default_rng(seed)
    cq = _random_cq(rng, n_atoms, n_vars, 4)
    plan = plan_for_cq(cq)
    assume(not has_cartesian(plan))  # oracle-only; never reaches the DAG

    # bijectively rename every column: the positional canonicalization
    # must intern both plans to the SAME node...
    mapping = {v.name: f"w{i}" for i, v in enumerate(cq.all_vars())}
    renamed = rename_columns(plan, mapping)
    dag = build_dag({"orig": plan, "renamed": renamed})
    assert dag.roots["orig"] == dag.roots["renamed"]

    # ...the merged DAG must pass the static IR verifier...
    assert verify_dag(dag, expected_members={"orig", "renamed"}) == []

    # ...and equal DagNode keys must mean identical reference-engine
    # answers (positionally — shared buffers are read by column index)
    triples = rng.integers(0, 4, size=(40, 3)).astype(np.int32)
    store = TripleStore(triples)
    got = sorted(map(tuple, R.execute(plan, store).rows.tolist()))
    want = sorted(map(tuple, R.execute(renamed, store).rows.tolist()))
    assert got == want


# ----------------------------------------------------------------------
# reformulation completeness under random schemas
# ----------------------------------------------------------------------
@settings(max_examples=25, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(seed=st.integers(0, 10**6))
def test_reformulation_complete_random_schema(seed):
    from repro.core.reformulation import reformulate
    from repro.query import ref_engine as R
    from repro.rdf.schema import RDFSchema
    from repro.rdf.triples import TripleStore

    rng = np.random.default_rng(seed)
    TYPE = 0
    n_cls, n_props, n_inst = 6, 5, 30
    classes = list(range(1, 1 + n_cls))
    props = list(range(10, 10 + n_props))
    sch = RDFSchema()
    for c in classes[1:]:
        if rng.random() < 0.7:
            sch.add_subclass(c, int(rng.choice(classes[:classes.index(c) + 1])))
    for i, p in enumerate(props[1:], 1):
        if rng.random() < 0.5:
            sch.add_subprop(p, props[i - 1])
    for p in props:
        if rng.random() < 0.6:
            sch.set_domain(p, int(rng.choice(classes)))
        if rng.random() < 0.6:
            sch.set_range(p, int(rng.choice(classes)))

    triples = []
    for _ in range(60):
        s = int(rng.integers(100, 100 + n_inst))
        if rng.random() < 0.4:
            triples.append((s, TYPE, int(rng.choice(classes))))
        else:
            triples.append((s, int(rng.choice(props)),
                            int(rng.integers(100, 100 + n_inst))))
    store = TripleStore(np.array(triples, np.int32))
    sat = TripleStore(sch.saturate_instance(store.triples, TYPE))

    x, y = Var("x"), Var("y")
    queries = [
        CQ((x,), (Atom(x, Const(TYPE), Const(int(rng.choice(classes)))),),
           name="qt"),
        CQ((x, y), (Atom(x, Const(int(rng.choice(props))), y),), name="qp"),
    ]
    for q in queries:
        members = reformulate(q, sch, TYPE, max_reformulations=4096)
        got = R.evaluate_ucq(members, store)
        want = R.evaluate_cq(q, sat).as_set()
        assert got == want, (q.name, seed)
