"""Search strategies + quality function behaviour (paper claims 2/3/5)."""
import pytest

from repro.core.quality import QualityWeights, quality
from repro.core.search import SearchConfig, search
from repro.core.state import initial_state
from repro.rdf.generator import generate, lubm_workload


@pytest.fixture(scope="module")
def uni():
    return generate(n_universities=1, seed=0, dept_per_univ=2,
                    prof_per_dept=4, stud_per_dept=15, course_per_dept=6)


@pytest.fixture(scope="module")
def workload(uni):
    return lubm_workload(uni.dictionary)


def test_initial_state_best_exec_cost(uni, workload):
    """Paper: initial state = materialize workload = best execution time."""
    st0 = initial_state(workload)
    q0 = quality(st0, uni.store.stats)
    cfg = SearchConfig(strategy="best_first", max_states=300)
    res = search(st0, uni.store.stats, cfg)
    assert res.best_quality.exec_cost >= q0.exec_cost - 1e-9 or \
        res.best_quality.exec_cost / max(q0.exec_cost, 1e-9) > 0.99


def test_search_never_worse_than_initial(uni, workload):
    st0 = initial_state(workload)
    q0 = quality(st0, uni.store.stats)
    for strat in ["greedy", "beam", "best_first", "anneal", "exhaustive_dfs"]:
        cfg = SearchConfig(strategy=strat, max_states=200, max_seconds=20)
        res = search(st0, uni.store.stats, cfg)
        assert res.best_quality.total <= q0.total + 1e-9, strat


def test_heuristics_explore_fewer_states(uni, workload):
    """Paper claim: heuristics significantly prune the search space."""
    st0 = initial_state(workload[:3])
    stats = uni.store.stats
    full = search(st0, stats, SearchConfig(strategy="best_first",
                                           max_states=1500, max_seconds=60))
    greedy = search(st0, stats, SearchConfig(strategy="greedy",
                                             max_states=1500, max_seconds=60))
    assert greedy.explored < full.explored
    # bounded quality loss (greedy's local optimum is within 2x here)
    assert greedy.best_quality.total <= 2.0 * full.best_quality.total + 1e-9


def test_weights_steer_choice(uni, workload):
    """Paper: tuning w_exec/w_space steers the selected configuration."""
    st0 = initial_state(workload)
    stats = uni.store.stats
    exec_heavy = search(st0, stats, SearchConfig(
        strategy="greedy", max_states=500,
        weights=QualityWeights(w_exec=100.0, w_maint=0.0, w_space=1e-6)))
    space_heavy = search(st0, stats, SearchConfig(
        strategy="greedy", max_states=500,
        weights=QualityWeights(w_exec=1e-6, w_maint=0.0, w_space=100.0)))
    # space-heavy search must give up storage relative to exec-heavy
    assert space_heavy.best_quality.space_bytes <= exec_heavy.best_quality.space_bytes
    assert exec_heavy.best_quality.exec_cost <= space_heavy.best_quality.exec_cost


def test_search_budget_respected(uni, workload):
    st0 = initial_state(workload)
    res = search(st0, uni.store.stats,
                 SearchConfig(strategy="exhaustive_dfs", max_states=50))
    assert res.explored <= 51


def test_search_log_monotone(uni, workload):
    st0 = initial_state(workload)
    res = search(st0, uni.store.stats,
                 SearchConfig(strategy="best_first", max_states=300))
    totals = [e["total"] for e in res.log]
    assert totals == sorted(totals, reverse=True)
