"""Chunked (flash-style) attention == dense attention, fwd + grads,
including sliding-window layers (§Perf iteration A5/B1's gate)."""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.models.model import build_model


@pytest.mark.parametrize("arch", ["qwen2.5-32b", "gemma3-12b",
                                  "granite-20b", "zamba2-1.2b"])
def test_chunked_matches_dense(arch):
    cfg = get_smoke_config(arch)
    cfg_d = dataclasses.replace(cfg, attn_impl="dense")
    cfg_c = dataclasses.replace(cfg, attn_impl="chunked", attn_chunk=8)
    md, mc = build_model(cfg_d), build_model(cfg_c)
    params = md.init(jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 32), 0, cfg.vocab)
    a = md.forward(params, tokens=toks)
    b = mc.forward(params, tokens=toks)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=3e-3, atol=3e-3)


def test_chunked_gradients_match():
    cfg = get_smoke_config("qwen2.5-32b")
    cfg_d = dataclasses.replace(cfg, attn_impl="dense")
    cfg_c = dataclasses.replace(cfg, attn_impl="chunked", attn_chunk=8)
    md, mc = build_model(cfg_d), build_model(cfg_c)
    params = md.init(jax.random.key(2))
    toks = jax.random.randint(jax.random.key(3), (1, 16), 0, cfg.vocab)

    def loss(model):
        return lambda p: jnp.sum(
            model.forward(p, tokens=toks).astype(jnp.float32) ** 2) / 1e3

    ga = jax.grad(loss(md))(params)
    gb = jax.grad(loss(mc))(params)
    for x, y in zip(jax.tree.leaves(ga), jax.tree.leaves(gb)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=2e-2, atol=2e-2)


def test_chunked_fallback_on_indivisible_seq():
    """Sequences not divisible by the chunk silently use the dense path
    (semantics identical either way)."""
    cfg = dataclasses.replace(get_smoke_config("qwen2.5-32b"),
                              attn_impl="chunked", attn_chunk=64)
    m = build_model(cfg)
    params = m.init(jax.random.key(4))
    toks = jax.random.randint(jax.random.key(5), (1, 10), 0, cfg.vocab)
    out = m.forward(params, tokens=toks)  # 10 % 64 != 0 -> dense path
    assert bool(jnp.isfinite(out).all())
