"""End-to-end wizard + executor + maintenance tests (paper claims 1/3/4)."""
import numpy as np
import pytest

from repro.core.quality import QualityWeights
from repro.core.search import SearchConfig
from repro.core.wizard import WizardConfig, tune
from repro.query import ref_engine as R
from repro.rdf.generator import generate, lubm_workload
from repro.rdf.triples import TripleStore
from repro.views.maintenance import maintain
from repro.views.materializer import materialize_view


@pytest.fixture(scope="module")
def uni():
    return generate(n_universities=1, seed=0, dept_per_univ=2,
                    prof_per_dept=4, stud_per_dept=12, course_per_dept=5)


@pytest.fixture(scope="module")
def report(uni):
    cfg = WizardConfig(search=SearchConfig(strategy="greedy", max_states=400))
    return tune(uni.store, lubm_workload(uni.dictionary), uni.schema,
                uni.type_id, cfg)


def test_wizard_end_to_end_answers(uni, report):
    """Rewritings over materialized views == saturated-store answers."""
    sat = TripleStore(
        uni.schema.saturate_instance(uni.store.triples, uni.type_id),
        uni.dictionary,
    )
    for q in lubm_workload(uni.dictionary):
        got = report.executor.answer_group(q.name)
        want = R.evaluate_cq(q, sat).as_set()
        assert got == want, q.name


def test_wizard_improves_quality(uni, report):
    assert report.result.best_quality.total <= report.initial_quality.total


def test_wizard_without_schema(uni):
    cfg = WizardConfig(search=SearchConfig(strategy="greedy", max_states=200),
                       use_schema=False)
    rep = tune(uni.store, lubm_workload(uni.dictionary), None, None, cfg)
    for q in lubm_workload(uni.dictionary):
        got = rep.executor.answer_group(q.name)
        want = rep.executor.answer_group_direct(q.name)
        assert got == want, q.name


def test_maintenance_incremental_equals_recompute(uni):
    workload = lubm_workload(uni.dictionary)
    view_cq = None
    from repro.core.queries import full_projection

    view_cq = full_projection(workload[1].atoms, name="vq2")
    store = uni.store
    extent = materialize_view(view_cq, store).rows
    rng = np.random.default_rng(3)
    d = uni.dictionary
    takes = d.lookup("ub:takesCourse")
    adv = d.lookup("ub:advisor")
    teach = d.lookup("ub:teacherOf")
    students = store.scan(None, d.lookup("ub:memberOf"), None)[:, 0]
    courses = store.scan(None, takes, None)[:, 2]
    profs = store.scan(None, teach, None)[:, 0]
    for _ in range(8):
        kind = rng.integers(0, 3)
        if kind == 0:
            t = (int(rng.choice(students)), takes, int(rng.choice(courses)))
        elif kind == 1:
            t = (int(rng.choice(students)), adv, int(rng.choice(profs)))
        else:
            t = (int(rng.choice(profs)), teach, int(rng.choice(courses)))
        extent, store, delta = maintain(view_cq, extent, store, t)
        want = materialize_view(view_cq, store).rows
        assert {tuple(r) for r in extent.tolist()} == {tuple(r) for r in want.tolist()}


def test_executor_jax_matches_oracle_per_member(uni, report):
    for name in report.executor._fns:
        got = {tuple(r) for r in report.executor.answer(name).tolist()}
        want = report.executor.answer_direct(name)
        assert got == want, name
