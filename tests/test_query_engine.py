"""JAX padded engine vs numpy oracle: identical answers on random data."""
import numpy as np
import pytest

import jax

from repro.core.queries import CQ, Atom, Const, Var
from repro.query import engine as E
from repro.query import ref_engine as R
from repro.query.plan import EquiJoin, Filter, Project, TTScan, ViewRef, plan_for_cq
from repro.rdf.generator import generate, lubm_workload
from repro.rdf.triples import TripleStore


@pytest.fixture(scope="module")
def uni():
    return generate(n_universities=1, seed=0)


def _measured_info(rel):
    from repro.query.cost import RelInfo
    rows = float(len(rel.rows))
    distinct = {
        c: float(len(np.unique(rel.rows[:, i]))) if len(rel.rows) else 1.0
        for i, c in enumerate(rel.cols)
    }
    return RelInfo(max(rows, 1e-3), distinct)


def _run_plan(plan, store, views_np=None, view_cards=None, use_pallas=False):
    views_np = views_np or {}
    view_cards = view_cards or {vid: _measured_info(rel) for vid, rel in views_np.items()}
    fn = E.build_executor(plan, store.stats, view_cards, use_pallas=use_pallas)
    tt = E.tt_device_indexes(store)
    views = {
        vid: E.make_prel(rel.rows, cap=max(128, 1 << int(np.ceil(np.log2(max(len(rel.rows), 1) + 1)))))
        for vid, rel in views_np.items()
    }
    out = jax.jit(lambda tt, views: fn(tt, views))(tt, views)
    assert not bool(out.overflow), "capacity overflow in test plan"
    return E.to_numpy(out), fn.out_columns


def _oracle(plan, store, views_np=None):
    rel = R.execute(plan, store, views_np or {})
    return rel


def assert_same(plan, store, views_np=None, view_cards=None, use_pallas=False):
    got_rows, got_cols = _run_plan(plan, store, views_np, view_cards, use_pallas)
    want = _oracle(plan, store, views_np)
    assert tuple(got_cols) == tuple(want.cols)
    got_set = {tuple(r) for r in got_rows.tolist()}
    want_set = want.as_set()
    assert got_set == want_set, (
        f"mismatch: extra={list(got_set - want_set)[:5]}, missing={list(want_set - got_set)[:5]}"
    )


def test_scan_patterns(uni):
    d = uni.dictionary
    t = Const(uni.type_id)
    student = Const(d.lookup("ub:GraduateStudent"))
    takes = Const(d.lookup("ub:takesCourse"))
    x, y = Var("x"), Var("y")
    for atom in [
        Atom(x, t, student),
        Atom(x, takes, y),
        Atom(x, Var("p"), y),
    ]:
        assert_same(TTScan(atom), uni.store)


def test_self_join_atom():
    # pattern (?x ?p ?x): rows with s == o
    t = np.array([[1, 2, 1], [1, 2, 3], [4, 5, 4]], np.int32)
    ts = TripleStore(t)
    plan = TTScan(Atom(Var("x"), Var("p"), Var("x")))
    assert_same(plan, ts)


def test_filter_and_project(uni):
    d = uni.dictionary
    takes = Const(d.lookup("ub:takesCourse"))
    x, y = Var("x"), Var("y")
    scan = TTScan(Atom(x, takes, y))
    some_course = int(uni.store.scan(None, takes.id, None)[0, 2])
    assert_same(Filter(scan, "y", some_course), uni.store)
    assert_same(Project(Filter(scan, "y", some_course), ("x",)), uni.store)


def test_join_two_atoms(uni):
    d = uni.dictionary
    t = Const(uni.type_id)
    grad = Const(d.lookup("ub:GraduateStudent"))
    takes = Const(d.lookup("ub:takesCourse"))
    x, y = Var("x"), Var("y")
    plan = EquiJoin(
        TTScan(Atom(x, t, grad)), TTScan(Atom(x, takes, y)), (("x", "x"),)
    )
    assert_same(plan, uni.store)


def test_multi_column_join(uni):
    # join on two shared vars: (x advisor y)(x memberOf z) vs (x advisor y)(y worksFor z)
    d = uni.dictionary
    adv = Const(d.lookup("ub:advisor"))
    works = Const(d.lookup("ub:worksFor"))
    member = Const(d.lookup("ub:memberOf"))
    x, y, z = Var("x"), Var("y"), Var("z")
    left = EquiJoin(TTScan(Atom(x, adv, y)), TTScan(Atom(y, works, z)), (("y", "y"),))
    right = EquiJoin(TTScan(Atom(x, member, z)), TTScan(Atom(x, adv, y)), (("x", "x"),))
    plan = EquiJoin(left, right, (("x", "x"), ("y", "y"), ("z", "z")))
    assert_same(plan, uni.store)


def test_full_workload_plans(uni):
    for q in lubm_workload(uni.dictionary):
        assert_same(plan_for_cq(q), uni.store)


def test_view_ref_and_rewriting_shape(uni):
    d = uni.dictionary
    takes = Const(d.lookup("ub:takesCourse"))
    x, y = Var("x"), Var("y")
    cq = CQ((x, y), (Atom(x, takes, y),), name="v0")
    ext = R.evaluate_cq(cq, uni.store)
    views_np = {0: ext}
    plan = Project(ViewRef(0, ("x", "y")), ("x",))
    assert_same(plan, uni.store, views_np)


def test_overflow_flag():
    t = np.stack([np.zeros(600, np.int32), np.ones(600, np.int32),
                  np.arange(600, dtype=np.int32)], axis=1)
    ts = TripleStore(t)
    plan = TTScan(Atom(Var("x"), Const(1), Var("y")))
    fn = E.build_executor(plan, ts.stats, {}, cap_override=lambda n, r: 128)
    out = fn(E.tt_device_indexes(ts), {})
    assert bool(out.overflow)
    assert int(out.n) == 128


def test_empty_results(uni):
    d = uni.dictionary
    t = Const(uni.type_id)
    plan = TTScan(Atom(Var("x"), t, Const(d.encode("ub:NoSuchClass"))))
    got, _ = _run_plan(plan, uni.store)
    assert len(got) == 0
