"""Streaming incremental view maintenance (repro.maintenance).

Correctness bar: after ANY insert/delete stream, the incrementally
maintained extents and answers must equal a full re-materialization
over the final store — property-tested on the host oracle and on the
device maintainer, with a deterministic twin for the device path.
Serving bar: answers are never more than the staleness budget stale,
and injected drift triggers an automatic retune.
"""
import numpy as np
import pytest

from repro.core.queries import CQ, Atom, Const, Var
from repro.kernels import ops as kops
from repro.maintenance import (Delta, MaintenanceConfig, UpdateStream,
                               ViewMaintainer, build_delta_plans)
from repro.query import engine as E
from repro.query import ref_engine as R
from repro.rdf.triples import TripleStore, triple_keys, triples_in
from repro.views.maintenance import apply_delta, effective_delta

PREDS = [1, 2, 3, 4, 5]


def _random_store(rng, n=600, n_ids=60):
    tt = np.stack([rng.integers(0, n_ids, n), rng.choice(PREDS, n),
                   rng.integers(0, n_ids, n)], axis=1).astype(np.int32)
    return TripleStore(tt)


def _random_batch(rng, n, n_ids=60):
    return np.stack([rng.integers(0, n_ids, n), rng.choice(PREDS, n),
                     rng.integers(0, n_ids, n)], axis=1).astype(np.int32)


def _chain_cq(name, p1, p2):
    x, y, z = Var("x"), Var("y"), Var("z")
    return CQ(name=name, head=(x, y, z),
              atoms=(Atom(x, Const(p1), y), Atom(y, Const(p2), z)))


def _extent_oracle(cq, store):
    rows = R.evaluate_cq(cq, store).rows.reshape(-1, len(cq.head))
    return np.unique(np.asarray(rows, np.int32), axis=0)


def _session(store, workload):
    from repro.api import TuningSession

    s = TuningSession(store, workload=workload)
    s.retune()
    s.apply()
    return s


# ----------------------------------------------------------------------
# primitives
# ----------------------------------------------------------------------
def test_triple_keys_wide_ids_fallback():
    # ids beyond the 21-bit packing range (and negative) must still key
    # correctly through the structured-dtype fallback
    big = np.array([[1 << 22, 5, -3], [7, 8, 9]], np.int32)
    ref = np.array([[7, 8, 9], [1 << 22, 5, -3]], np.int32)
    assert triples_in(big, ref).all()
    assert not triples_in(np.array([[1 << 22, 5, 3]], np.int32), ref).any()
    assert len(np.unique(triple_keys(big))) == 2


def test_update_stream_coalesce_and_counts():
    s = UpdateStream()
    s.push(Delta.of(np.array([[1, 2, 3]], np.int32), None))
    s.push(Delta.of(np.array([[4, 5, 6]], np.int32),
                    np.array([[1, 2, 3]], np.int32)))
    s.push(Delta.of(None, None))  # empty: ignored
    assert s.pending_batches == 2 and s.pending_triples == 3
    merged = s.coalesce()
    assert s.pending_batches == 0 and s.pending_triples == 0
    # sequential semantics: the later delete of [1,2,3] overrides the
    # earlier insert in the net batch
    assert triples_in(np.array([[1, 2, 3]], np.int32), merged.deletes).all()
    assert merged.inserts.tolist() == [[4, 5, 6]]


def test_effective_delta_tie_goes_to_insert():
    store = TripleStore(np.array([[1, 1, 1], [2, 2, 2]], np.int32))
    ins = np.array([[1, 1, 1], [3, 3, 3]], np.int32)   # [1,1,1] is a dup
    dels = np.array([[1, 1, 1], [9, 9, 9]], np.int32)  # [9,9,9] absent
    eff_ins, eff_del = effective_delta(store, ins, dels)
    assert eff_ins.tolist() == [[3, 3, 3]]
    assert len(eff_del) == 0  # present, but re-inserted in the same batch


def test_scatter_append_kernel_matches_numpy():
    rng = np.random.default_rng(7)
    for cap, n, dcap, k, w in [(128, 0, 64, 0, 3), (128, 100, 64, 28, 3),
                               (256, 5, 128, 128, 2), (128, 127, 128, 1, 4)]:
        buf = np.full((cap, w), -1, np.int32)
        rows = rng.integers(0, 99, (n, w)).astype(np.int32)
        buf[:n] = rows
        batch = rng.integers(0, 99, (dcap, w)).astype(np.int32)
        out = np.asarray(kops.scatter_append(buf, n, batch, k))
        want = buf.copy()
        want[n:n + k] = batch[:k]
        np.testing.assert_array_equal(out, want)


def test_scatter_append_rejects_overflow():
    buf = np.zeros((128, 3), np.int32)
    with pytest.raises(ValueError):
        kops.scatter_append(buf, 120, np.zeros((16, 3), np.int32), 16)


# ----------------------------------------------------------------------
# host oracle: property test against full re-evaluation
# ----------------------------------------------------------------------
def test_oracle_apply_delta_property():
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    @settings(max_examples=25, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(seed=st.integers(0, 10**6), steps=st.integers(1, 4))
    def run(seed, steps):
        rng = np.random.default_rng(seed)
        cq = _chain_cq("v", int(rng.choice(PREDS)), int(rng.choice(PREDS)))
        store = _random_store(rng, n=250, n_ids=25)
        extent = _extent_oracle(cq, store)
        for _ in range(steps):
            ins = _random_batch(rng, int(rng.integers(0, 40)), n_ids=25)
            n_del = int(rng.integers(0, 30))
            dels = store.triples[rng.choice(
                len(store.triples), min(n_del, len(store.triples)),
                replace=False)]
            extent, store = apply_delta(cq, extent, store, ins, dels)
        np.testing.assert_array_equal(extent, _extent_oracle(cq, store))

    run()


# ----------------------------------------------------------------------
# device maintainer: deterministic twin + property test
# ----------------------------------------------------------------------
def _stream_and_check(seed, steps=5, batch=48, engine="auto"):
    rng = np.random.default_rng(seed)
    store = _random_store(rng)
    sess = _session(store, [_chain_cq("q1", 1, 2), _chain_cq("q2", 2, 3)])
    m = ViewMaintainer(sess.executor,
                       MaintenanceConfig(delta_cap=64, insert_engine=engine))
    for _ in range(steps):
        ins = _random_batch(rng, batch)
        n_del = int(rng.integers(0, batch))
        cur = sess.executor.store.triples
        dels = cur[rng.choice(len(cur), min(n_del, len(cur)), replace=False)]
        m.apply(Delta.of(ins, dels))
    ex = sess.executor
    for vid, view in ex.state.views.items():
        m.check_alignment(vid)  # host mirror == device valid prefix
        got = np.unique(ex.extents[vid].rows, axis=0)
        np.testing.assert_array_equal(got, _extent_oracle(view.cq, ex.store))
    for q in sess.workload:  # fused answers == oracle over final store
        assert sess.answer(q.name) == ex.answer_group_direct(q.name)
    return m


def test_maintainer_deterministic_twin():
    m = _stream_and_check(seed=1234)
    t = m.telemetry()
    # steady state must not recompile the delta program per batch
    assert t["delta_recompiles"] == 0
    assert t["measured_views"] >= 1  # costs were observed


def test_maintainer_device_engine_matches_host():
    # the fused-program insert engine (the accelerator path) must agree
    # with the vectorized host engine and stay recompile-free
    m = _stream_and_check(seed=1234, steps=3, batch=32, engine="device")
    t = m.telemetry()
    assert t["insert_engine"] == "device"
    assert t["delta_compiles"] == 1 and t["delta_recompiles"] == 0


def test_maintainer_property_random_streams():
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    # few examples: each replays a full device stream (the compile cache
    # makes later examples cheap — same capacity classes)
    @settings(max_examples=4, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(seed=st.integers(0, 10**6))
    def run(seed):
        _stream_and_check(seed, steps=3, batch=32)

    run()


def test_maintainer_delete_only_and_insert_only_batches():
    rng = np.random.default_rng(9)
    store = _random_store(rng)
    sess = _session(store, [_chain_cq("q1", 1, 2)])
    m = ViewMaintainer(sess.executor, MaintenanceConfig())
    cur = sess.executor.store.triples
    r1 = m.apply(Delta.of(None, cur[:64]))
    assert r1.eff_deletes > 0 and r1.eff_inserts == 0
    r2 = m.apply(Delta.of(_random_batch(rng, 64), None))
    assert r2.eff_inserts > 0 and r2.eff_deletes == 0
    ex = sess.executor
    for vid, view in ex.state.views.items():
        got = np.unique(ex.extents[vid].rows, axis=0)
        np.testing.assert_array_equal(got, _extent_oracle(view.cq, ex.store))


def test_delete_pass_scans_only_inverted_index_candidates():
    rng = np.random.default_rng(21)
    store = _random_store(rng)
    sess = _session(store, [_chain_cq("q1", 1, 2), _chain_cq("q2", 3, 4)])
    m = ViewMaintainer(sess.executor, MaintenanceConfig())
    ex = sess.executor
    maintained = set(ex.state.views) - m.plans.oracle_vids

    def expected_scans(preds):
        cand = set(m._wild_vids)
        for p in preds:
            cand |= m._pred_vids.get(p, set())
        return len(cand - m.plans.oracle_vids)

    # pred-5 deletes: NO view mentions predicate 5, so only views with a
    # variable-predicate atom can lose a row — everything else is never
    # even iterated (sub-linear in the view count)
    only5 = store.triples[store.triples[:, 1] == 5][:16]
    r5 = m.apply(Delta.of(None, only5))
    assert r5.extents_scanned == expected_scans({5})
    assert r5.extents_scanned < len(maintained)

    # pred-1 deletes: exactly the pred-1 views plus the wild ones
    cur = ex.store.triples
    only1 = cur[cur[:, 1] == 1][:16]
    r1 = m.apply(Delta.of(None, only1))
    assert r1.extents_scanned == expected_scans({1})
    assert m.telemetry()["delete_scans"] == \
        r5.extents_scanned + r1.extents_scanned
    # sub-linear bookkeeping never trades away correctness
    for vid, view in ex.state.views.items():
        got = np.unique(ex.extents[vid].rows, axis=0)
        np.testing.assert_array_equal(got, _extent_oracle(view.cq, ex.store))


# ----------------------------------------------------------------------
# serving: staleness budget, drift retune, measured costs
# ----------------------------------------------------------------------
def test_staleness_budget_bounds_served_lag():
    rng = np.random.default_rng(3)
    store = _random_store(rng)
    sess = _session(store, [_chain_cq("q1", 1, 2)])
    budget = 40
    srv = sess.serve(maintenance=MaintenanceConfig(staleness_budget=budget))
    for _ in range(6):
        srv.submit(inserts=_random_batch(rng, 16))
        srv.answer("q1")
        assert srv.stream.pending_triples <= budget
    assert srv.stats.max_staleness_served <= budget
    assert srv.stats.refreshes >= 1  # the budget forced maintenance
    srv.flush()
    assert srv.stream.pending_triples == 0
    # flushed answers equal the oracle over the final store
    assert srv.answer("q1") == sess.executor.answer_group_direct("q1")


def test_zero_budget_serves_fresh():
    rng = np.random.default_rng(4)
    sess = _session(_random_store(rng), [_chain_cq("q1", 1, 2)])
    srv = sess.serve(maintenance=True)  # default budget: 0
    srv.submit(inserts=_random_batch(rng, 8))
    srv.submit(inserts=_random_batch(rng, 8))
    srv.answer("q1")
    assert srv.stats.max_staleness_served == 0
    assert srv.stats.backlog_triples == 0


def test_drift_triggers_auto_retune():
    rng = np.random.default_rng(5)
    sess = _session(_random_store(rng),
                    [_chain_cq("q1", 1, 2), _chain_cq("q2", 2, 3)])
    srv = sess.serve(maintenance=MaintenanceConfig(
        staleness_budget=0, drift_window=3, drift_rate_factor=2.0,
        drift_min_triples=32))
    for _ in range(4):  # baseline rate: small batches
        srv.submit(inserts=_random_batch(rng, 4))
        srv.answer("q1")
    for _ in range(6):  # drift: 40x the rate, one hot predicate
        b = _random_batch(rng, 160)
        b[:, 1] = 5
        srv.submit(inserts=b)
        srv.answer("q1")
    assert srv.stats.drift_retunes >= 1
    # after the retune the server still answers correctly
    assert srv.answer("q2") == sess.executor.answer_group_direct("q2")


def test_measured_costs_flow_into_retune_objective():
    from repro.core.quality import MaintenanceCostModel, quality
    from repro.core.quality import QualityWeights

    rng = np.random.default_rng(6)
    sess = _session(_random_store(rng), [_chain_cq("q1", 1, 2)])
    sess.ingest(inserts=_random_batch(rng, 32),
                deletes=sess.store.triples[:16])
    assert len(sess.maintenance_costs) >= 1
    # the session's search config now carries the measured model
    assert sess._search_cfg().maint_model is sess.maintenance_costs
    # and a (sufficiently different) measured cost changes the objective
    stats = sess.store.stats
    state = sess.best
    base = quality(state, stats, QualityWeights())
    loaded = MaintenanceCostModel()
    for v in state.views.values():
        loaded.observe(v.cq, 1e4)
    heavy = quality(state, stats, QualityWeights(), loaded)
    assert heavy.total != base.total


def test_rebind_survives_retune_hot_swap():
    rng = np.random.default_rng(8)
    sess = _session(_random_store(rng), [_chain_cq("q1", 1, 2)])
    srv = sess.serve(maintenance=True)
    srv.submit(inserts=_random_batch(rng, 16))
    srv.answer("q1")
    srv.retune_online(add=[_chain_cq("q3", 3, 4)])
    # maintainer rebound to the new view set: streaming keeps working
    srv.submit(inserts=_random_batch(rng, 16))
    assert srv.answer("q3") == sess.executor.answer_group_direct("q3")
    for vid in sess.executor.state.views:
        srv.maintainer.check_alignment(vid)


# ----------------------------------------------------------------------
# delta planner + analyzer
# ----------------------------------------------------------------------
def test_delta_plans_share_isomorphic_leaves():
    from repro.core.state import initial_state

    # q1 and q2 share the (x, P2, y) atom shape: one delta leaf
    state = initial_state([_chain_cq("q1", 1, 2), _chain_cq("q2", 2, 3)])
    plans = build_delta_plans(state)
    assert len(plans.plans) == 4         # 2 views x 2 atoms
    assert len(plans.leaves) == 3        # P1, P2 (shared), P3
    assert not plans.oracle_vids
    assert plans.dag is not None


def test_non_full_projection_goes_to_oracle():
    from repro.core.state import View, initial_state

    x, y, z = Var("x"), Var("y"), Var("z")
    proj = CQ(name="p", head=(x, z),
              atoms=(Atom(x, Const(1), y), Atom(y, Const(2), z)))
    state = initial_state([_chain_cq("q1", 1, 2)])
    vid = max(state.views) + 1
    state.views[vid] = View(vid, proj)
    plans = build_delta_plans(state)
    assert vid in plans.oracle_vids


def test_maintenance_analyzer_static_defaults_clean():
    from repro.analysis import analyze_maintenance

    rng = np.random.default_rng(11)
    sess = _session(_random_store(rng, n=2000),
                    [_chain_cq("q1", 1, 2), _chain_cq("q2", 2, 3)])
    assert analyze_maintenance(sess.best, sess.store.stats) == []


def test_maintenance_analyzer_flags_hazards():
    from types import SimpleNamespace

    from repro.analysis import analyze_maintenance
    from repro.analysis.maintenance_check import _check_delta_cap

    rng = np.random.default_rng(12)
    sess = _session(_random_store(rng, n=400), [_chain_cq("q1", 1, 2)])

    # non-power-of-two delta cap cannot be built through the validated
    # config; the rule still guards hand-rolled configs
    bad = _check_delta_cap(SimpleNamespace(delta_cap=100, expected_batch=8))
    assert any(f.rule == "maint/delta-cap" and f.severity == "error"
               for f in bad)

    # expected batch far above the delta class: chunked-pass warning
    split = analyze_maintenance(
        sess.best, sess.store.stats,
        MaintenanceConfig(delta_cap=128, expected_batch=4096))
    assert any(f.rule == "maint/delta-cap" and f.severity == "warning"
               for f in split)

    # an absurd update rate outruns every headroom envelope
    hot = analyze_maintenance(sess.best, sess.store.stats,
                              update_rate=1e9)
    rules = {f.rule for f in hot}
    assert "maint/extent-headroom" in rules and "maint/tt-headroom" in rules


def test_maintenance_analyzer_live_mode():
    from repro.analysis import analyze_maintenance

    rng = np.random.default_rng(13)
    sess = _session(_random_store(rng, n=2000), [_chain_cq("q1", 1, 2)])
    m = sess.maintainer()
    sess.ingest(inserts=_random_batch(rng, 32))
    assert analyze_maintenance(maintainer=m) == []
    hot = analyze_maintenance(maintainer=m, update_rate=1e9)
    assert any(f.rule == "maint/tt-headroom" for f in hot)


def test_verify_session_covers_maintenance():
    rng = np.random.default_rng(14)
    sess = _session(_random_store(rng, n=2000), [_chain_cq("q1", 1, 2)])
    sess.ingest(inserts=_random_batch(rng, 16))
    report = sess.verify()
    assert report.checked.get("maint_views", 0) >= 1
    assert report.ok
