"""Dry-run machinery tests.

1) A light lowering pass on an 8-device (2,4) mesh inside a subprocess —
   exercises make_cell/jit/lower/compile + roofline extraction per kind.
2) Completeness of the full 512-chip artifacts checked into
   artifacts/dryrun (produced by `python -m repro.launch.dryrun`).
"""
import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ART = os.path.join(ROOT, "artifacts", "dryrun")

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
import numpy as np
from repro.launch.mesh import make_mesh
from repro.launch.shapes import make_cell, rules_for
from repro.launch import roofline as RL
from repro.configs import get_smoke_config
import dataclasses

mesh = make_mesh((2, 4), ("data", "model"))

# small shapes on smoke configs: one cell per kind x representative arch
CASES = [
    ("qwen2.5-32b", "train_4k", dict(seq=64, batch=8)),
    ("zamba2-1.2b", "decode_32k", dict(seq=128, batch=8)),
    ("whisper-base", "prefill_32k", dict(seq=64, batch=4)),
    ("granite-moe-1b-a400m", "train_4k", dict(seq=64, batch=8)),
]
import repro.launch.shapes as shapes_mod
for arch, shape, override in CASES:
    saved = dict(shapes_mod.SHAPES[shape])
    shapes_mod.SHAPES[shape].update(override)
    try:
        cfg = get_smoke_config(arch)
        cell = make_cell(arch, shape, mesh, cfg=cfg)
        jitted = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                         donate_argnums=cell.donate)
        compiled = jitted.lower(*cell.args).compile()
        mem = compiled.memory_analysis()
        roof = RL.extract(compiled, None, 8, model_flops=1e9)
        assert roof.flops > 0, (arch, shape)
        assert roof.hbm_bytes > 0, (arch, shape)
        assert roof.bottleneck in ("compute", "memory", "collective")
        print(f"ok {arch} {shape} coll_ops={sorted(roof.collectives.count_by_op)}")
    finally:
        shapes_mod.SHAPES[shape] = saved

# collective parsing sanity on a hand-built program
from jax.sharding import NamedSharding, PartitionSpec as P
import jax.numpy as jnp
def f(x):
    return jax.lax.with_sharding_constraint(
        x.sum(axis=0, keepdims=True), NamedSharding(mesh, P()))
x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
comp = jax.jit(f, in_shardings=NamedSharding(mesh, P("data", "model"))
               ).lower(x).compile()
stats = RL.parse_collectives(comp.as_text())
assert stats.total_bytes > 0, "expected a collective in the sharded sum"
print("ok collective-parse")
"""


def test_dryrun_light_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    res = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                         text=True, env=env, cwd=ROOT, timeout=900)
    assert res.returncode == 0, f"STDOUT:\n{res.stdout}\nSTDERR:\n{res.stderr}"
    assert res.stdout.count("ok ") == 5


@pytest.mark.skipif(not os.path.isdir(ART), reason="dry-run artifacts absent")
def test_full_dryrun_artifacts_complete():
    from repro.launch.shapes import SHAPES, applicable
    from repro.configs import list_archs

    missing, bad = [], []
    for pod in ("pod1", "pod2"):
        for arch in list_archs():
            for shape in SHAPES:
                path = os.path.join(ART, f"{arch}__{shape}__{pod}.json")
                if not os.path.exists(path):
                    missing.append(os.path.basename(path))
                    continue
                with open(path) as f:
                    res = json.load(f)
                ok, _ = applicable(arch, shape)
                want = "ok" if ok else "skipped"
                if res.get("status") != want:
                    bad.append((os.path.basename(path), res.get("status")))
                if res.get("status") == "ok":
                    r = res["roofline"]
                    assert r["flops_per_device"] > 0
                    assert r["bottleneck"] in ("compute", "memory", "collective")
    assert not missing, f"missing artifacts: {missing}"
    assert not bad, f"unexpected statuses: {bad}"


@pytest.mark.skipif(not os.path.isdir(ART), reason="dry-run artifacts absent")
def test_paper_workload_artifacts():
    for pod in ("pod1", "pod2"):
        path = os.path.join(ART, f"rdfviews-query-step__star3__{pod}.json")
        assert os.path.exists(path), path
        with open(path) as f:
            res = json.load(f)
        assert res["status"] == "ok"
        assert res["roofline"]["collective_bytes_per_device"] > 0, \
            "distributed join must exchange data"
